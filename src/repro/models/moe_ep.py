"""Expert-parallel MoE with an explicit all-to-all schedule (shard_map).

The einsum dispatch in ``moe.py`` lets GSPMD pick the collectives, and on a
(data, model) mesh it picks badly: the combine side all-gathers every
expert's output over ``model`` (measured 610 GiB/device for phi3.5-moe
train_4k — EXPERIMENTS.md §Perf pair B, iteration 2, hypothesis refuted).

This module pins the schedule manually:

  tokens shard as (batch over the dp axes) x (sequence over ``model``);
  experts shard over ``model``. Per chip and per MoE layer:

    route local N tokens -> build send buffer [E, C, d]
    all_to_all over `model`  (dispatch — bytes = E*C*d, the roofline floor)
    local expert FFN          (weights local, no gather)
    all_to_all back           (combine)
    scatter-add into y with gate weights

  per-device collective bytes/layer = 2 * E * C * d * bytes(dtype)
  with C = ceil(cf * k * N_loc / E) — independent of the expert count's
  total parameter bytes, which is the point.

Requires E % m == 0, batch % dp == 0, seq % m == 0 (m = model-axis size);
``moe_supports_ep`` guards the fast path, callers fall back to the einsum
formulation otherwise (e.g. mixtral's 8 experts on a 16-wide model axis).
Capacity groups are per-chip token blocks (B/dp x S/m tokens), so drop
behaviour matches the einsum path whenever the grouping coincides and is
the same in expectation otherwise.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sharding
from repro.models.layers import _act


def _dp_size(mesh) -> int:
    n = 1
    for a, v in mesh.shape.items():
        if a != "model":
            n *= v
    return n


def moe_supports_ep(n_experts: int, mesh, batch: int, seq: int) -> bool:
    """Tokens shard as batch over the dp axes x sequence over `model`."""
    if mesh is None or "model" not in mesh.shape:
        return False
    ep = mesh.shape["model"]
    return (n_experts % ep == 0 and batch % _dp_size(mesh) == 0
            and seq % ep == 0)


def _route_local(router_w, xg, k: int, capacity: int, n_experts: int):
    """Local top-k routing with capacity. xg: [N, d] (one chip's tokens).
    Returns (gates [N,k], expert idx [N,k], slot [N,k], keep [N,k], aux)."""
    logits = xg.astype(jnp.float32) @ router_w                   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                         # [N, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)   # [N,k,E]
    flat = onehot.reshape(-1, n_experts)                         # [N*k, E]
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(onehot.shape)
    slot = jnp.einsum("nke,nke->nk", pos, onehot).astype(jnp.int32)
    keep = slot < capacity

    frac_tokens = jnp.mean(jnp.max(onehot, axis=1), axis=0)      # [E]
    frac_probs = jnp.mean(probs, axis=0)                         # [E]
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return gates, idx, slot, keep, aux


def moe_apply_ep(p, x, *, k: int, act: str = "silu",
                 capacity_factor: float = 1.25,
                 mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in for ``moe_apply`` under a (pod?, data, model) mesh.

    x: [B, S, d] global. Output matches the einsum path up to dropped-token
    tie-breaking order (tests compare allclose on capacity-slack configs).
    """
    B, S, d = x.shape
    E = p["w_gate"].shape[0]
    ep = mesh.shape["model"]
    e_loc = E // ep
    dp = tuple(a for a in mesh.shape if a != "model")
    n_loc = (B // _dp_size(mesh)) * (S // ep)
    cap = max(int(capacity_factor * k * n_loc / E), 1)
    wdtype = p["w_gate"].dtype

    def inner(router_w, w_gate, w_up, w_down, x_loc):
        # x_loc: [B/dp, S/ep, d]; expert weights local: [e_loc, d, f]
        xg = x_loc.reshape(-1, d)                                # [N, d]
        gates, idx, slot, keep, aux = _route_local(router_w, xg, k, cap, E)

        # ---- build send buffer [E, cap, d] ----
        send = jnp.zeros((E, cap, d), wdtype)
        tok = jnp.broadcast_to(jnp.arange(xg.shape[0])[:, None], idx.shape)
        e_idx = jnp.where(keep, idx, E)          # overflow -> OOB row drop
        send = send.at[e_idx.reshape(-1),
                       jnp.where(keep, slot, 0).reshape(-1)].set(
            xg[tok.reshape(-1)].astype(wdtype), mode="drop")

        # ---- dispatch a2a: [E, cap, d] -> [ep, e_loc, cap, d] ----
        recv = jax.lax.all_to_all(
            send.reshape(ep, e_loc, cap, d), "model", 0, 0, tiled=True)
        # recv: [ep * e_loc, cap, d] where the leading dim interleaves
        # (source chip, local expert)
        recv = recv.reshape(ep, e_loc, cap, d)

        # ---- local expert FFN ----
        h = jnp.einsum("pecd,edf->pecf", recv, w_gate)
        h = _act(h, act) * jnp.einsum("pecd,edf->pecf", recv, w_up)
        out = jnp.einsum("pecf,efd->pecd", h, w_down)            # [ep,e_loc,cap,d]

        # ---- combine a2a back: each source chip gets its tokens ----
        back = jax.lax.all_to_all(
            out.reshape(ep * e_loc, cap, d), "model", 0, 0, tiled=True)
        back = back.reshape(E, cap, d)                           # my tokens

        # ---- weighted scatter back to token order ----
        vals = back[e_idx.reshape(-1),
                    jnp.where(keep, slot, 0).reshape(-1)]        # [N*k, d]
        vals = vals.reshape(*idx.shape, d) * \
            jnp.where(keep, gates, 0.0).astype(wdtype)[..., None]
        y = jnp.sum(vals, axis=1)                                # [N, d]

        aux = jax.lax.pmean(aux, dp + ("model",))
        return y.reshape(x_loc.shape).astype(x_loc.dtype), aux

    shmap = sharding.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"),
                  P(dp, "model", None)),
        out_specs=(P(dp, "model", None), P()),
        check=False)
    return shmap(p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"], x)
