"""Sharding rules: parameter PartitionSpecs by pytree path + activation
constraints, with divisibility-aware fallback.

Conventions (single pod mesh = (data, model); multi-pod adds a leading pod
axis used for data parallelism by default):
  - FSDP: weight input dims shard over ``data``.
  - TP (megatron): head/ffn/expert output dims shard over ``model``.
  - Activations: batch over ``data`` (+ ``pod``), residual sequence over
    ``model`` (sequence parallelism, needed for the biggest archs' remat
    footprint).
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check: bool = False):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes it at top level with ``check_vma`` / ``axis_names``
    (the set of *manual* axes); older releases have
    ``jax.experimental.shard_map.shard_map`` with ``check_rep`` / ``auto``
    (the complementary set of axes left automatic). Partially-manual
    ``auto`` subgroups CHECK-fail inside old XLA's SPMD partitioner, so the
    legacy path runs fully manual instead: axes the caller wanted automatic
    must then not appear in any spec, and their compute stays local and
    replicated — numerically identical, just without GSPMD re-sharding.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return native(f, **kw)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


# ---------------------------------------------------------------------------
# activation-constraint context
# ---------------------------------------------------------------------------

_ACTIVATION_CTX: Dict[str, Any] = {"mesh": None, "rules": {}}


@contextlib.contextmanager
def activation_rules(mesh: Optional[Mesh], rules: Dict[str, P]):
    """Install activation sharding constraints used by ``constrain``."""
    old = dict(_ACTIVATION_CTX)
    _ACTIVATION_CTX.update(mesh=mesh, rules=rules)
    try:
        yield
    finally:
        _ACTIVATION_CTX.update(old)


def constrain(x, name: str):
    mesh, rules = _ACTIVATION_CTX["mesh"], _ACTIVATION_CTX["rules"]
    if mesh is None or name not in rules:
        return x
    spec = _fit_spec(rules[name], x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def ctx_mesh():
    """Mesh of the installed activation rules (None outside a mesh ctx)."""
    return _ACTIVATION_CTX["mesh"]


def ctx_flag(name: str) -> bool:
    """Boolean feature flags riding the activation-rule context (e.g.
    ``moe_ep`` switches the MoE layer to the shard_map expert-parallel
    schedule)."""
    return bool(_ACTIVATION_CTX["rules"].get(name, False))


# ---------------------------------------------------------------------------
# divisibility-aware spec fitting
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (or that don't
    exist); pad the spec with None up to the rank. Tuple axes degrade by
    trimming trailing axes (e.g. batch 256 on a 512-chip ('pod','data',
    'model') spec falls back to ('pod','data') rather than replicating)."""
    out = []
    for i, dim in enumerate(shape):
        axis = spec[i] if i < len(spec) else None
        if isinstance(axis, (tuple, list)):
            axis = tuple(axis)
            while axis and dim % _axis_size(mesh, axis) != 0:
                axis = axis[:-1]
            axis = axis or None
        elif axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (regex on the '/'-joined tree path, spec WITHOUT the stacked-layer dim)
_PARAM_RULES = [
    # embeddings / heads
    (r"embed/table$", {2: P("model", None), 3: P(None, "model", None)}),
    (r"lm_head/w$", {2: P("data", "model"), 3: P(None, "data", "model")}),
    # attention
    (r"mix/wq/w$", P("data", "model")),
    (r"mix/wk/w$", P("data", "model")),
    (r"mix/wv/w$", P("data", "model")),
    (r"mix/wo/w$", P("model", "data")),
    (r"mix/w[qkv]/b$", P("model")),
    # dense MLP
    (r"mlp/w_gate/w$", P("data", "model")),
    (r"mlp/w_up/w$", P("data", "model")),
    (r"mlp/w_down/w$", P("model", "data")),
    # MoE — expert dim over model when divisible, else shard d/f dims
    (r"mlp/router/w$", P(None, None)),
    (r"mlp/w_gate$", P("model", "data", None)),
    (r"mlp/w_up$", P("model", "data", None)),
    (r"mlp/w_down$", P("model", None, "data")),
    # RG-LRU
    (r"mix/in_gate/w$", P("data", "model")),
    (r"mix/in_rec/w$", P("data", "model")),
    (r"mix/w_[ax]/w$", P("data", "model")),
    (r"mix/w_[ax]/b$", P("model")),
    (r"mix/conv$", P(None, "model")),
    (r"mix/lam$", P("model")),
    (r"mix/out/w$", P("model", "data")),
    # xLSTM
    (r"mix/up_[lr]/w$", P("data", "model")),
    (r"mix/up/w$", P("data", "model")),
    (r"mix/up_gate/w$", P("data", "model")),
    (r"mix/w[qkvifzo]/w$", P("data", "model")),
    (r"mix/w_[ifzo]/w$", P("data", "model")),
    (r"mix/down/w$", P("model", "data")),
    (r"mix/r_[ifzo]$", P(None, None, None)),
    # bottleneck heads (core/bottleneck.py)
    (r"down/w$", P("data", "model")),
    (r"up/w$", P("model", "data")),
    # paper LSTM PoC (tiny — replicate)
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _moe_alt_spec(name: str, shape, mesh: Mesh) -> Optional[P]:
    """MoE expert weights when E doesn't divide ``model``: shard d/f dims."""
    E = shape[-3] if len(shape) >= 3 else 0
    if E and E % _axis_size(mesh, "model") != 0:
        if name.endswith("w_down"):
            return P(*([None] * (len(shape) - 3)), None, "model", "data")
        return P(*([None] * (len(shape) - 3)), None, "data", "model")
    return None


def param_pspecs(params, mesh: Mesh, *, stacked_layers: bool = True,
                 tp_scope: str = "all"):
    """Pytree of PartitionSpecs matching ``params``.

    ``stacked_layers``: params under 'layers/' carry a leading L dim
    (homogeneous scan archs) that stays unsharded.
    ``tp_scope``: 'all' (megatron TP everywhere) or 'ffn' (attention/mixer
    weights replicated over ``model`` — removes the attention TP all-reduce
    at the cost of replicated attention-weight storage; a §Perf hillclimb
    knob, best for archs whose attention weights are small relative to FFN).
    """
    def rule_for(path, leaf):
        name = _path_str(path)
        in_layers = name.startswith("layers/")
        stacked = stacked_layers and in_layers and not re.match(
            r"layers/\d", name)
        shape = leaf.shape
        base_rank = len(shape) - (1 if stacked else 0)
        for pat, spec in _PARAM_RULES:
            if re.search(pat, name):
                if isinstance(spec, dict):
                    spec = spec.get(base_rank, P())
                if "mlp/w_" in name and not name.endswith("/w"):
                    alt = _moe_alt_spec(name, shape, mesh)
                    if alt is not None:
                        spec = P(*alt[-base_rank:])
                if tp_scope == "ffn" and "mix/" in name:
                    spec = P(*(None if a == "model" else a for a in spec))
                if stacked:
                    spec = P(None, *spec)
                return _fit_spec(spec, shape, mesh)
        return P()  # replicate (norms, small params, LSTM PoC)

    return jax.tree_util.tree_map_with_path(rule_for, params)


# ---------------------------------------------------------------------------
# activation / batch rules
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh):
    """Mesh axes used for data parallelism (pod folds into data if present)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def all_axes(mesh: Mesh):
    """Every mesh axis, for fully-data-parallel (ZeRO-3-style) activations."""
    return tuple(mesh.shape.keys())


def batch_pspec(mesh: Mesh, rank: int, batch_size: int,
                act_policy: str = "seq") -> P:
    dp = all_axes(mesh) if act_policy == "batch2d" else dp_axes(mesh)
    while dp and batch_size % _axis_size(mesh, dp) != 0:
        # long_500k has batch 1 (and batch2d needs batch % chips == 0):
        # drop trailing axes until the batch divides, else replicate
        dp = dp[:-1] or None
    return P(dp, *([None] * (rank - 1)))


def default_activation_rules(mesh: Mesh, *, seq_shard: bool = True,
                             act_policy: Optional[str] = None,
                             moe_ep: bool = False):
    """Residual stream + logits constraints.

    Policies (see EXPERIMENTS.md §Perf for the derivation):
      ``seq``     batch over dp axes + sequence over ``model`` (sequence
                  parallelism: bounds the per-chip remat footprint, but XLA
                  inserts relayout all-gathers/all-to-alls at every
                  seq<->head-sharded transition — collective-heavy).
      ``batch``   batch over dp axes only; weights stay 2D-sharded (ZeRO-3):
                  per-layer weight all-gathers replace activation relayouts.
      ``batch2d`` batch over ALL mesh axes (pure FSDP at chip granularity) —
                  the relayout-free layout when global_batch % chips == 0.
    ``seq_shard=False`` is back-compat for ``batch``.
    """
    policy = act_policy or ("seq" if seq_shard else "batch")
    dp = dp_axes(mesh)
    rules = {"logits": P(dp, None, "model")}
    if policy == "seq":
        rules["resid"] = P(dp, "model", None)
    elif policy == "batch":
        rules["resid"] = P(dp, None, None)
    elif policy == "batch2d":
        axes = all_axes(mesh)
        rules["resid"] = P(axes, None, None)
        rules["logits"] = P(axes, None, None)
    else:
        raise ValueError(f"unknown act_policy {policy!r}")
    if moe_ep:
        rules["moe_ep"] = True
    return rules


# ---------------------------------------------------------------------------
# serving mesh — ('dp', 'mp') data plane for the continuous-batching engine
# ---------------------------------------------------------------------------
#
# Serving shards differently from training: the batch dim IS the slot pool
# (thousands of concurrent sessions), so slots shard over ``dp`` while
# parameters replicate across it; ``mp`` carries megatron tensor parallelism
# (params + KV head dim). dp-only meshes are bit-identical to single-device
# execution (slot sharding is pure data placement); mp > 1 reassociates
# head-dim reductions and is numerically equivalent but not bit-exact — see
# docs/sharding.md.

#: leaf names (last pytree-path component) holding KV caches shaped
#: ``[..., slots, T, n_kv, head_dim]``. Exact-component match on purpose:
#: ``endswith`` would also catch e.g. the rglru ``conv`` state.
_KV_LEAF_NAMES = frozenset({"k", "v", "k_s", "v_s"})


def serving_mesh(dp: int, mp: int = 1, *, devices=None) -> Mesh:
    """Build the serving ``('dp', 'mp')`` mesh from the first ``dp * mp``
    devices (or an explicit device subset, e.g. an EdgeCluster replica's
    slice)."""
    devices = list(jax.devices() if devices is None else devices)
    if dp < 1 or mp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp} mp={mp}")
    need = dp * mp
    if need > len(devices):
        raise ValueError(
            f"mesh ({dp} x {mp}) needs {need} devices, only "
            f"{len(devices)} available — on CPU, set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    arr = np.array(devices[:need], dtype=object).reshape(dp, mp)
    return Mesh(arr, ("dp", "mp"))


def _rename_spec(spec: P, mapping: Dict[Optional[str], Optional[str]]) -> P:
    out = []
    for axis in spec:
        if isinstance(axis, (tuple, list)):
            renamed = tuple(mapping.get(a, a) for a in axis)
            renamed = tuple(a for a in renamed if a is not None)
            axis = renamed if len(renamed) > 1 else (
                renamed[0] if renamed else None)
        else:
            axis = mapping.get(axis, axis)
        out.append(axis)
    return P(*out)


def serving_param_pspecs(params, mesh: Mesh, **kwargs):
    """Parameter specs on the serving mesh: TP dims over ``mp``, FSDP dims
    replicated (every dp row serves every slot, so weights replicate over
    ``dp``). Reuses the training ``_PARAM_RULES`` via a proxy mesh with the
    training axis names, then renames ``model -> mp`` / drops ``data``."""
    proxy = Mesh(mesh.devices, ("data", "model"))
    specs = param_pspecs(params, proxy, **kwargs)
    ren = {"data": None, "model": "mp"}
    return jax.tree.map(lambda s: _rename_spec(s, ren), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _pool_spec(path, shape, mesh: Mesh, slot_axis: int) -> P:
    spec = [None] * len(shape)
    if len(shape) > slot_axis:
        spec[slot_axis] = "dp"
    last = _path_str(path).split("/")[-1]
    if last in _KV_LEAF_NAMES and len(shape) == slot_axis + 4:
        # [..., slots, T, n_kv, head_dim] — head groups over mp
        spec[slot_axis + 2] = "mp"
    return _fit_spec(P(*spec), shape, mesh)


def pool_pspecs(states, mesh: Mesh, *, slot_axis: int):
    """Slot-pool specs: slot axis over ``dp``, KV head groups over ``mp``;
    non-dividing dims fall back to replicated (``_fit_spec``). ``slot_axis``
    is 1 for stacked homogeneous states ``[L, S, ...]`` and the paged arena
    ``[L, pages, ...]`` (pages are that pool's slot axis), 0 for
    heterogeneous per-layer states ``[S, ...]``."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: _pool_spec(p, leaf.shape, mesh, slot_axis), states)


def pool_shardings(states, mesh: Mesh, *, slot_axis: int):
    """``NamedSharding`` tree matching ``pool_pspecs`` (handy for
    ``jax.jit`` in_shardings / ``device_put``)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, _pool_spec(p, leaf.shape, mesh, slot_axis)), states)


def shard_pool(states, mesh: Mesh, *, slot_axis: int):
    """Place a slot-pool state tree onto the serving mesh."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: jax.device_put(leaf, NamedSharding(
            mesh, _pool_spec(p, leaf.shape, mesh, slot_axis))), states)


def constrain_batch(x, mesh: Optional[Mesh], *, axis: int = 0):
    """Constrain one array's batch/slot ``axis`` over ``dp`` (no-op when
    unsharded or non-dividing)."""
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[axis] = "dp"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _fit_spec(P(*spec), x.shape, mesh)))


def shard_batch(x, mesh: Optional[Mesh], *, axis: int = 0):
    """``device_put`` one array with its batch/slot ``axis`` over ``dp``
    (the committed-placement counterpart of :func:`constrain_batch`;
    no-op when unsharded or non-dividing)."""
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[axis] = "dp"
    return jax.device_put(
        x, NamedSharding(mesh, _fit_spec(P(*spec), x.shape, mesh)))


def shard_params(params, mesh: Optional[Mesh], **kwargs):
    """Place a parameter tree with :func:`serving_param_pspecs` shardings
    (no-op without a mesh)."""
    if mesh is None:
        return params
    specs = serving_param_pspecs(params, mesh, **kwargs)
    return jax.tree.map(
        lambda leaf, s: jax.device_put(leaf, NamedSharding(mesh, s)),
        params, specs)


def replicate(tree, mesh: Optional[Mesh]):
    """Place every leaf fully replicated on the mesh (params/bank in the
    serving engine; no-op without a mesh)."""
    if mesh is None:
        return tree
    sh = NamedSharding(mesh, P())
    return jax.tree.map(lambda leaf: jax.device_put(leaf, sh), tree)


def state_pspecs(states, mesh: Mesh, batch: int, *, stacked: bool) -> Any:
    """Decode-state (KV cache / recurrent state) specs: batch over data; KV
    heads over model when divisible, else cache time dim over model."""
    dp = dp_axes(mesh)
    bdp = dp if batch % _axis_size(mesh, dp) == 0 else None

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        off = 1 if stacked else 0           # leading L dim
        spec = [None] * len(shape)
        if len(shape) - off >= 1:
            spec[off] = bdp                 # batch dim
        if name.endswith(("k", "v", "k_s", "v_s")) and len(shape) - off == 4:
            # [*,B,T,n_kv,hd]
            n_kv, T = shape[off + 2], shape[off + 1]
            m = _axis_size(mesh, "model")
            if n_kv % m == 0:
                spec[off + 2] = "model"
            elif T % m == 0:
                spec[off + 1] = "model"
        elif name.endswith("C") and len(shape) - off == 4:
            spec[off + 1] = "model" if shape[off + 1] % _axis_size(
                mesh, "model") == 0 else None
        return _fit_spec(P(*spec), shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, states)
