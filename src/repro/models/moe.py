"""GShard-style mixture-of-experts layer (top-k router, capacity-based
dispatch/combine einsums).

The dispatch/combine formulation is the TPU-native realization: expert weights
carry a leading E dim that shards over the ``model`` mesh axis, so the
dispatch einsum lowers to the expert-parallel all-to-all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.layers import _act, _normal


def moe_init(key, d: int, d_ff: int, n_experts: int, *, dtype=jnp.bfloat16):
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d).item() if False else d ** -0.5
    return {
        "router": {"w": _normal(kr, (d, n_experts), d ** -0.5, jnp.float32)},
        "w_gate": _normal(kg, (n_experts, d, d_ff), d ** -0.5, dtype),
        "w_up": _normal(ku, (n_experts, d, d_ff), d ** -0.5, dtype),
        "w_down": _normal(kd, (n_experts, d_ff, d), d_ff ** -0.5, dtype),
    }


def moe_apply(p, x, *, k: int, act: str = "silu",
              capacity_factor: float = 1.25,
              group_size: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d]  ->  (y [B, S, d], aux_loss scalar).

    Tokens are routed within groups (default: one group per batch row;
    ``group_size`` splits rows further — smaller groups cut the quadratic
    dispatch-einsum cost, a hillclimb knob).
    """
    B, S, d = x.shape
    E = p["w_gate"].shape[0]
    if group_size and S % group_size == 0 and S > group_size:
        g = S // group_size
        xg = x.reshape(B * g, group_size, d)
    else:
        xg = x
    G, N, _ = xg.shape

    logits = xg.astype(jnp.float32) @ p["router"]["w"]          # [G,N,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                        # [G,N,k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)      # renormalize

    cap = max(int(capacity_factor * k * N / E), 1)

    # one-hot expert choice per (token, slot): [G, N, k, E]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    # position of each (token, slot) inside its expert buffer, priority by
    # (token index, slot index):
    flat = onehot.reshape(G, N * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                       # [G,N*k,E]
    pos = pos.reshape(G, N, k, E)
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch [G,N,E,C] (bool-ish), combine [G,N,E,C] (gate-weighted)
    dispatch = jnp.einsum("gnke,gnkec->gnec", onehot * keep, pos_oh)
    combine = jnp.einsum("gnk,gnke,gnkec->gnec", gates, onehot * keep, pos_oh)

    # NOTE: annotating xin/out with an expert-sharded constraint here was
    # tried and REFUTED (EXPERIMENTS.md §Perf pair B iter 2): GSPMD lowers
    # the combine side to a full expert-output all-gather (610 GiB/dev).
    # The einsum formulation is kept as the portable fallback; the fast
    # path is the explicit shard_map schedule in ``moe_ep.py``.
    xin = jnp.einsum("gnec,gnd->gecd", dispatch, xg.astype(jnp.float32))
    xin = xin.astype(p["w_gate"].dtype)                         # [G,E,C,d]
    h = _act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"]), act) \
        * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])          # [G,E,C,d]
    y = jnp.einsum("gnec,gecd->gnd", combine, out.astype(jnp.float32))

    # Switch/GShard load-balance auxiliary loss
    frac_tokens = jnp.mean(onehot[..., 0, :] if k == 1 else
                           jnp.max(onehot, axis=2), axis=1)     # [G,E]
    frac_probs = jnp.mean(probs, axis=1)                        # [G,E]
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    return y.reshape(B, S, d).astype(x.dtype), aux
