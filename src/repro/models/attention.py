"""GQA multi-head attention with causal / sliding-window masking and a
decode-time KV cache (rolling buffer for SWA/local-attention archs).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, d: int, n_q: int, n_kv: int, hd: int, *,
              qkv_bias: bool = False, dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, n_q * hd, bias=qkv_bias, dtype=dtype),
        "wk": dense_init(kk, d, n_kv * hd, bias=qkv_bias, dtype=dtype),
        "wv": dense_init(kv, d, n_kv * hd, bias=qkv_bias, dtype=dtype),
        "wo": dense_init(ko, n_q * hd, d, dtype=dtype),
    }


def _project_qkv(p, x, n_q, n_kv, hd):
    B, S = x.shape[:2]
    q = (x @ p["wq"]["w"]).reshape(B, S, n_q, hd)
    k = (x @ p["wk"]["w"]).reshape(B, S, n_kv, hd)
    v = (x @ p["wv"]["w"]).reshape(B, S, n_kv, hd)
    if "b" in p["wq"]:
        q = q + p["wq"]["b"].reshape(n_q, hd)
        k = k + p["wk"]["b"].reshape(n_kv, hd)
        v = v + p["wv"]["b"].reshape(n_kv, hd)
    return q, k, v


def _gqa_scores(q, k):
    """q: [B,S,nq,hd], k: [B,T,nkv,hd] -> [B,nkv,G,S,T] without materializing
    repeated KV heads."""
    B, S, n_q, hd = q.shape
    n_kv = k.shape[2]
    g = n_q // n_kv
    qg = q.reshape(B, S, n_kv, g, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(probs, v):
    """probs: [B,nkv,G,S,T], v: [B,T,nkv,hd] -> [B,S,nq*hd]."""
    B, n_kv, g, S, T = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, n_kv * g * v.shape[-1])


# sequences at or above this length use the blocked online-softmax path
# (bounded memory — the pure-JAX analogue of flash/splash attention, which is
# what a real TPU deployment would run for 32k prefill)
BLOCKED_ATTN_THRESHOLD = 2048
_BLOCK_Q = 512
_BLOCK_K = 512


def _dense_attention(q, k, v, positions, hd, window):
    scores = _gqa_scores(q, k) / math.sqrt(hd)   # [B,kv,G,S,T] fp32
    i = positions[:, None, None, :, None]        # query pos
    j = positions[:, None, None, None, :]        # key pos
    mask = j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v)


def _blocked_attention(q, k, v, positions, hd, window,
                       block_q: int = _BLOCK_Q, block_k: int = _BLOCK_K):
    """Online-softmax attention over [block_q x block_k] tiles; peak memory
    is O(S * block_k) instead of O(S^2)."""
    B, S, n_q_heads, _ = q.shape
    n_kv = k.shape[2]
    g = n_q_heads // n_kv
    nq, nk = S // block_q, S // block_k
    qb = q.reshape(B, nq, block_q, n_kv, g, hd)
    kb = k.reshape(B, nk, block_k, n_kv, hd)
    vb = v.reshape(B, nk, block_k, n_kv, hd)
    pos_q = positions.reshape(B, nq, block_q)
    pos_k = positions.reshape(B, nk, block_k)
    scale = 1.0 / math.sqrt(hd)

    def q_block(qi, q_i, pq_i):
        # q_i: [B, block_q, n_kv, g, hd]; pq_i: [B, block_q]
        qf = q_i.astype(jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_j, v_j, pk_j = inp                 # [B,block_k,n_kv,hd], pos
            s = jnp.einsum("bqkgh,btkh->bkgqt", qf,
                           k_j.astype(jnp.float32)) * scale
            i_ = pq_i[:, None, None, :, None]
            j_ = pk_j[:, None, None, None, :]
            mask = j_ <= i_
            if window:
                mask &= j_ > i_ - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p, v_j.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, n_kv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, n_kv, g, block_q), jnp.float32)
        a0 = jnp.zeros((B, n_kv, g, block_q, hd), jnp.float32)
        kv_xs = (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pos_k.swapaxes(0, 1))
        step = jax.checkpoint(kv_step,
                              policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), kv_xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B,kv,g,bq,hd]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, block_q, n_kv * g * hd)

    outs = jax.lax.map(
        lambda i: q_block(i, qb[:, i], pos_q[:, i]), jnp.arange(nq))
    # [nq, B, block_q, n_heads*hd] -> [B, S, n_heads*hd]
    return outs.swapaxes(0, 1).reshape(B, S, n_q_heads * hd)


def full_attention(p, x, positions, *, n_q: int, n_kv: int, hd: int,
                   rope_theta: float, window: int = 0):
    """Train / prefill path: full causal (optionally sliding-window) attention.

    x: [B, S, d]; positions: [B, S] absolute token positions.
    """
    S = x.shape[1]
    q, k, v = _project_qkv(p, x, n_q, n_kv, hd)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if S >= BLOCKED_ATTN_THRESHOLD and S % _BLOCK_Q == 0 \
            and S % _BLOCK_K == 0:
        out = _blocked_attention(q, k, v, positions, hd, window)
    else:
        out = _dense_attention(q, k, v, positions, hd, window)
    return out.astype(x.dtype) @ p["wo"]["w"]


def prefill_attention(p, x, positions, cache, *, n_q: int, n_kv: int,
                      hd: int, rope_theta: float, window: int = 0,
                      lengths=None):
    """Full-sequence prefill that also populates the decode cache.

    Runs causal (optionally sliding-window) attention over the whole prompt
    in ONE pass and scatters each sequence's K/V rows into its rolling cache
    slots — the batched replacement for feeding the prompt through
    ``decode_attention`` token by token.

    x: [B, S, d]; positions: [B, S]; ``lengths``: optional [B] true prompt
    lengths when the batch is right-padded to a bucket length (pad positions
    are never written to the cache and, being *after* every real position,
    are masked out of real queries by causality).
    Returns (out [B, S, d], populated cache).
    """
    B, S = x.shape[:2]
    clen = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, n_q, n_kv, hd)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    quantized = "k_s" in cache
    if quantized:
        from repro.core import quant as Q
        kq, ks = Q.quantize(k, 8)
        vq, vs = Q.quantize(v, 8)
        # attend the dequantized values so prefill matches what decode will
        # read back from the int8 cache
        k_att = (kq.astype(jnp.float32) * ks).astype(k.dtype)
        v_att = (vq.astype(jnp.float32) * vs).astype(v.dtype)
    else:
        k_att, v_att = k, v

    # decode can only ever see the last ``clen`` positions, so cap the
    # prefill window to the cache (clen == window for SWA archs by
    # construction; full-attention archs rely on the engine's capacity rule
    # to keep S <= clen)
    w_eff = min(window, clen) if window else window
    if S >= BLOCKED_ATTN_THRESHOLD and S % _BLOCK_Q == 0 \
            and S % _BLOCK_K == 0:
        out = _blocked_attention(q, k_att, v_att, positions, hd, w_eff)
    else:
        out = _dense_attention(q, k_att, v_att, positions, hd, w_eff)

    # scatter each row's last min(len, clen) REAL positions into its rolling
    # cache slot; invalid rows get the out-of-bounds index clen, which the
    # scatter drops — identical end state to sequential per-token writes
    keep = min(S, clen)
    idx = lengths[:, None] - keep + jnp.arange(keep)[None, :]     # [B, keep]
    valid = idx >= 0
    idx_c = jnp.clip(idx, 0, S - 1)
    pos_g = jnp.take_along_axis(positions, idx_c, axis=1)
    slot = jnp.where(valid, jnp.mod(pos_g, clen), clen)
    b_ix = jnp.arange(B)[:, None]

    def gather_rows(a):
        return jnp.take_along_axis(a, idx_c[:, :, None, None], axis=1)

    def scatter(buf, rows):
        return buf.at[b_ix, slot].set(rows, mode="drop")

    if quantized:
        new_cache = {
            "k": scatter(cache["k"], gather_rows(kq)),
            "k_s": scatter(cache["k_s"], gather_rows(ks)),
            "v": scatter(cache["v"], gather_rows(vq)),
            "v_s": scatter(cache["v_s"], gather_rows(vs)),
        }
    else:
        new_cache = {"k": scatter(cache["k"], gather_rows(k)),
                     "v": scatter(cache["v"], gather_rows(v))}
    return out.astype(x.dtype) @ p["wo"]["w"], new_cache


def init_cache(batch: int, n_kv: int, hd: int, cache_len: int,
               dtype=jnp.bfloat16, kv_bits: int = 0):
    """Per-layer rolling KV cache. ``cache_len`` = window for SWA archs,
    full context otherwise.

    ``kv_bits=8``: store int8 codes + per-(pos, head) fp32 scales instead of
    bf16 — halves the decode memory-roofline term, which dominates the
    32k-decode shapes (EXPERIMENTS.md §Perf decode addendum). The decode
    path dispatches on the presence of the scale leaves."""
    if kv_bits == 0:
        return {
            "k": jnp.zeros((batch, cache_len, n_kv, hd), dtype=dtype),
            "v": jnp.zeros((batch, cache_len, n_kv, hd), dtype=dtype),
        }
    assert kv_bits == 8, kv_bits
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, hd), dtype=jnp.int8),
        "k_s": jnp.zeros((batch, cache_len, n_kv, 1), dtype=jnp.float32),
        "v": jnp.zeros((batch, cache_len, n_kv, hd), dtype=jnp.int8),
        "v_s": jnp.zeros((batch, cache_len, n_kv, 1), dtype=jnp.float32),
    }


def paged_prefill_attention(p, x, positions, arena, block_table, *,
                            n_q: int, n_kv: int, hd: int, rope_theta: float,
                            lengths=None):
    """Full-sequence prefill that scatters K/V rows through a block table
    into a paged arena instead of ``mod(pos, cache_len)`` rolling slots.

    ``arena``: per-layer ``{"k","v"}`` leaves of shape
    ``[n_pages, page_len, n_kv, hd]`` shared by every slot; ``block_table``:
    ``[B, nb]`` page ids, one row per sequence, covering at least
    ``ceil(length / page_len)`` pages. Pad rows (``s >= lengths[b]``) get an
    out-of-bounds page index and are dropped by the scatter, mirroring the
    dense prefill's drop trick. Attention itself never reads the cache, so
    the output is identical to :func:`prefill_attention` on the same prompt.
    """
    B, S = x.shape[:2]
    n_pages, plen = arena["k"].shape[:2]
    nb = block_table.shape[1]
    q, k, v = _project_qkv(p, x, n_q, n_kv, hd)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    if S >= BLOCKED_ATTN_THRESHOLD and S % _BLOCK_Q == 0 \
            and S % _BLOCK_K == 0:
        out = _blocked_attention(q, k, v, positions, hd, 0)
    else:
        out = _dense_attention(q, k, v, positions, hd, 0)

    valid = jnp.arange(S)[None, :] < lengths[:, None]             # [B, S]
    pg_ix = jnp.clip(positions // plen, 0, nb - 1)
    pg = jnp.where(valid,
                   block_table[jnp.arange(B)[:, None], pg_ix], n_pages)
    row = jnp.mod(positions, plen)
    new_arena = {"k": arena["k"].at[pg, row].set(k, mode="drop"),
                 "v": arena["v"].at[pg, row].set(v, mode="drop")}
    return out.astype(x.dtype) @ p["wo"]["w"], new_arena


def paged_decode_attention(p, x, arena, block_table, cur_pos, *, n_q: int,
                           n_kv: int, hd: int, rope_theta: float):
    """One-token decode against a paged arena through a block table.

    x: [B, 1, d]; cur_pos: [B] per-sequence absolute positions; ``arena``
    leaves ``[n_pages, page_len, n_kv, hd]``; ``block_table`` ``[B, nb]``.
    The caller guarantees the page holding row ``cur_pos`` is allocated for
    every live sequence; idle sequences carry all-zero block-table rows, so
    their drifting writes land in the reserved scratch page 0 (never read
    unmasked). Gathers the table's pages into logical row order — row ``t``
    is absolute position ``t``; full attention never wraps — and applies
    the exact dense-path score/mask/softmax ops, so on equal logical
    capacity the output is bit-identical to :func:`decode_attention`.
    Returns (out [B,1,d], updated arena).
    """
    B = x.shape[0]
    plen = arena["k"].shape[1]
    nb = block_table.shape[1]
    q, k, v = _project_qkv(p, x, n_q, n_kv, hd)
    pos = jnp.asarray(cur_pos, dtype=jnp.int32).reshape(B, 1)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    pg = block_table[jnp.arange(B), jnp.clip(pos[:, 0] // plen, 0, nb - 1)]
    row = jnp.mod(pos[:, 0], plen)
    new_arena = {"k": arena["k"].at[pg, row].set(k[:, 0]),
                 "v": arena["v"].at[pg, row].set(v[:, 0])}

    from repro.kernels import ops as K
    if K.paged_kernel_eligible(n_q=n_q, n_kv=n_kv, hd=hd, page_len=plen):
        ctx = K.paged_attention_op(q[:, 0], new_arena["k"], new_arena["v"],
                                   block_table, pos[:, 0])
        out = ctx.reshape(B, 1, n_q * hd).astype(x.dtype)
    else:
        ck = new_arena["k"][block_table].reshape(B, nb * plen, n_kv, hd)
        cv = new_arena["v"][block_table].reshape(B, nb * plen, n_kv, hd)
        scores = _gqa_scores(q, ck) / math.sqrt(hd)   # [B,kv,G,1,T]
        t = jnp.arange(nb * plen)
        n_fill = jnp.minimum(pos[:, 0] + 1, nb * plen)
        written = t[None, :] < n_fill[:, None]            # [B, T]
        scores = jnp.where(written[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, cv).astype(x.dtype)
    return out @ p["wo"]["w"], new_arena


def decode_attention(p, x, cache, cur_pos, *, n_q: int, n_kv: int, hd: int,
                     rope_theta: float, window: int = 0):
    """One-token decode against the cache.

    x: [B, 1, d]; cur_pos: scalar int32 (all sequences aligned, as in
    synchronous batched serving) or a [B] vector of per-sequence absolute
    positions (continuous batching: each slot is at its own depth).
    Returns (out [B,1,d], updated cache).
    """
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, n_q, n_kv, hd)
    cur_pos = jnp.asarray(cur_pos, dtype=jnp.int32)
    ragged = cur_pos.ndim == 1
    pos = cur_pos.reshape(B, 1) if ragged \
        else jnp.full((B, 1), cur_pos, dtype=jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    slot = jnp.mod(pos[:, 0], cache_len) if ragged \
        else jnp.mod(cur_pos, cache_len)          # rolling for SWA

    def store(buf, new):
        """Write the new token's row at each sequence's own cache slot."""
        if ragged:
            return buf.at[jnp.arange(B), slot].set(new[:, 0])
        return jax.lax.dynamic_update_slice_in_dim(buf, new, slot, axis=1)

    quantized = "k_s" in cache
    if quantized:
        from repro.core import quant as Q
        kq, ks = Q.quantize(k, 8)
        vq, vs = Q.quantize(v, 8)
        new_cache = {
            "k": store(cache["k"], kq),
            "k_s": store(cache["k_s"], ks),
            "v": store(cache["v"], vq),
            "v_s": store(cache["v_s"], vs),
        }
        ck = (new_cache["k"].astype(jnp.float32) * new_cache["k_s"]
              ).astype(k.dtype)
        cv = (new_cache["v"].astype(jnp.float32) * new_cache["v_s"]
              ).astype(v.dtype)
    else:
        ck = store(cache["k"], k)
        cv = store(cache["v"], v)

    scores = _gqa_scores(q, ck) / math.sqrt(hd)   # [B,kv,G,1,T]
    # slot t holds absolute position: t if t<=slot else t + cache_len*(n_wraps)
    # validity: a slot is attendable iff its absolute position is in
    # (cur_pos - effective_window, cur_pos]. With the rolling cache of size
    # cache_len == min(window, ctx) every written slot is within the window
    # by construction, so the mask reduces to "has been written".
    t = jnp.arange(cache_len)
    n_fill = jnp.minimum(pos[:, 0] + 1, cache_len)    # valid slots per seq
    written = t[None, :] < n_fill[:, None]            # [B, T]
    scores = jnp.where(written[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, cv).astype(x.dtype)
    return out @ p["wo"]["w"], (new_cache if quantized
                                else {"k": ck, "v": cv})
