"""The paper's proof-of-concept model (Fig. 6): LSTM encoder +
time-distributed Dense decoder, with the phase-2 bottleneck LSTM and decoder
adapter layer of Algorithm 1.

Mode 0 ("z"):  x -> LSTM1 -> LSTM2 -> z = H_T^(2) -> Decoder1
Mode 1 ("z'"): x -> LSTM1 -> LSTM2 -> LSTM3(bottleneck) -> z' = H_T^(3)
               -> adapter (layer B) -> Decoder1

The decoder tiles the received latent across T timesteps and applies
time-distributed dense layers producing a per-timestep throughput class
(tanh hidden activation — the double-saturating family the IB literature
associates with the compression phase).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LSTMConfig
from repro.models.layers import dense_apply, dense_init


# ---------------------------------------------------------------------------
# LSTM cell / layer
# ---------------------------------------------------------------------------

def lstm_layer_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "wx": dense_init(k1, d_in, 4 * d_hidden, bias=True, dtype=dtype),
        "wh": dense_init(k2, d_hidden, 4 * d_hidden, dtype=dtype),
    }


def lstm_layer_apply(p, x):
    """x: [B,S,d_in] -> all hidden states [B,S,d_hidden]."""
    B, S, _ = x.shape
    dh = p["wh"]["w"].shape[0]
    xw = dense_apply(p["wx"], x)                   # [B,S,4dh]

    def step(carry, xw_t):
        h, c = carry
        z = xw_t + h @ p["wh"]["w"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, dh), x.dtype), jnp.zeros((B, dh), x.dtype))
    _, hs = jax.lax.scan(step, init, xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)                       # [B,S,dh]


# ---------------------------------------------------------------------------
# paper model
# ---------------------------------------------------------------------------

def init_params(key, cfg: LSTMConfig) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict = {"enc": [], "dec": []}
    d_in = cfg.n_features
    for i, n in enumerate(cfg.enc_cells):
        params["enc"].append(lstm_layer_init(ks[i], d_in, n))
        d_in = n
    z_dim = cfg.enc_cells[-1]
    d = z_dim
    for i, n in enumerate(cfg.dec_hidden):
        params["dec"].append(dense_init(ks[3 + i], d, n, bias=True,
                                        dtype=jnp.float32))
        d = n
    params["dec_out"] = dense_init(ks[5], d, cfg.n_classes, bias=True,
                                   dtype=jnp.float32)
    # phase-2 additions (Algorithm 1 lines 3-4): bottleneck LSTM (layer A)
    # + decoder adapter (layer B) mapping z' back to Decoder1's input width.
    params["bneck"] = lstm_layer_init(ks[6], z_dim, cfg.bottleneck_cells)
    params["adapter"] = dense_init(ks[7], cfg.bottleneck_cells, z_dim,
                                   bias=True, dtype=jnp.float32)
    return params


def encoder_apply(params, x, mode: int) -> Tuple[jnp.ndarray, Dict]:
    """Returns (latent code [B, z_dim], activations dict for IB analysis)."""
    acts = {}
    h = x
    for i, layer in enumerate(params["enc"]):
        h = lstm_layer_apply(layer, h)
        acts[f"H{i + 1}"] = h                      # [B,S,cells]
    z = h[:, -1, :]                                # H_T^(2)
    if mode == 0:
        return z, acts
    h3 = lstm_layer_apply(params["bneck"], h)
    acts["H3"] = h3
    zp = h3[:, -1, :]                              # z' = H_T^(3)
    return zp, acts


def decoder_apply(params, latent, seq_len: int, mode: int) -> jnp.ndarray:
    """latent: mode 0 -> z [B, z_dim]; mode 1 -> z' [B, bneck]."""
    if mode == 1:
        latent = jnp.tanh(dense_apply(params["adapter"], latent))  # layer B
    h = jnp.repeat(latent[:, None, :], seq_len, axis=1)            # tile T
    for layer in params["dec"]:
        h = jnp.tanh(dense_apply(layer, h))
    return dense_apply(params["dec_out"], h)       # [B,S,n_classes]


def forward(params, x, cfg: LSTMConfig, mode: int = 0):
    z, acts = encoder_apply(params, x, mode)
    logits = decoder_apply(params, z, cfg.seq_len, mode)
    acts["latent"] = z
    acts["logits"] = logits
    return logits, acts


def loss_fn(params, batch, cfg: LSTMConfig, mode: int = 0):
    logits, _ = forward(params, batch["x"], cfg, mode)
    labels = batch["y"]                            # [B,S] int
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return jnp.mean(nll), {"acc": acc}


# Algorithm 1 freeze partition: phase 1 trains enc/dec/dec_out;
# phase 2 trains ONLY bneck + adapter.
PHASE1_KEYS = ("enc", "dec", "dec_out")
PHASE2_KEYS = ("bneck", "adapter")


def phase_mask(params, phase: int):
    """Pytree of bools: True = trainable in this phase."""
    def mark(key_name, sub):
        trainable = (key_name in (PHASE1_KEYS if phase == 1 else PHASE2_KEYS))
        return jax.tree.map(lambda _: trainable, sub)
    return {k: mark(k, v) for k, v in params.items()}
