"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections) [arXiv:2405.04517].

Both are linear-time recurrences implemented with ``jax.lax.scan`` over time
(exact recurrent form with the max-stabilizer m); decode is a single step with
carried state. d_ff=0 in the assigned config: the blocks carry their own
up/down projections (pre-up-projection mLSTM ×2, post-up-projection sLSTM 4/3)
per the paper's block design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, _normal
from repro.models.scan_utils import chunked_scan

_MLSTM_PF = 2.0    # mLSTM up-projection factor
_SLSTM_PF = 4.0 / 3.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d: int, n_heads: int, *, dtype=jnp.bfloat16):
    di = int(_MLSTM_PF * d)
    ks = jax.random.split(key, 8)
    return {
        "up_l": dense_init(ks[0], d, di, dtype=dtype),    # mlstm path
        "up_r": dense_init(ks[1], d, di, dtype=dtype),    # output gate path
        "wq": dense_init(ks[2], di, di, dtype=dtype),
        "wk": dense_init(ks[3], di, di, dtype=dtype),
        "wv": dense_init(ks[4], di, di, dtype=dtype),
        "wi": dense_init(ks[5], di, n_heads, bias=True, dtype=dtype),
        "wf": dense_init(ks[6], di, n_heads, bias=True, dtype=dtype),
        "down": dense_init(ks[7], di, d, dtype=dtype),
    }


def _mlstm_qkvif(p, x, n_heads):
    """x: [B,S,d] -> q,k,v [B,S,H,hd] fp32; i,f preacts [B,S,H] fp32."""
    xl = dense_apply(p["up_l"], x)
    B, S, di = xl.shape
    hd = di // n_heads
    q = (xl @ p["wq"]["w"]).reshape(B, S, n_heads, hd).astype(jnp.float32)
    k = (xl @ p["wk"]["w"]).reshape(B, S, n_heads, hd).astype(jnp.float32)
    k = k / jnp.sqrt(float(hd))
    v = (xl @ p["wv"]["w"]).reshape(B, S, n_heads, hd).astype(jnp.float32)
    i_pre = dense_apply(p["wi"], xl).astype(jnp.float32)
    f_pre = dense_apply(p["wf"], xl).astype(jnp.float32)
    return xl, q, k, v, i_pre, f_pre


def mlstm_state_init(batch: int, d: int, n_heads: int):
    di = int(_MLSTM_PF * d)
    hd = di // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32),
    }


def _mlstm_cell(state, qkvif):
    q, k, v, i_pre, f_pre = qkvif          # per-timestep: [B,H,hd]x3, [B,H]x2
    C, n, m = state["C"], state["n"], state["m"]
    log_f = -jax.nn.softplus(-f_pre)       # log sigmoid(f̃)
    m_new = jnp.maximum(log_f + m, i_pre)
    m_new = jnp.where(jnp.isfinite(m_new), m_new, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    f_g = jnp.where(jnp.isfinite(m), f_g, 0.0)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    h_num = jnp.einsum("bhij,bhj->bhi", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = h_num / h_den[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def _mlstm_scan_op(q, k, v, i_pre, f_pre, state, valid):
    """Decomposed mLSTM recurrence routing the normalizer ``n`` — the one
    sub-recurrence of the exact ``h = a*h + b`` form — through
    ``ops.rglru_scan_op`` (Pallas on TPU, plain scan on CPU).

    Decomposition, bit-identical to scanning ``_mlstm_cell`` (pinned by
    tests): (1) the max-stabilizer ``m`` is a tiny [B, H]-carry sequential
    scan emitting each step's carried/candidate pair; (2) the normalized
    gates ``i_g``/``f_g`` then fall out elementwise in parallel; (3) the
    normalizer recurrence ``n = f_g*n + i_g*k`` runs through the scan op
    with pad steps masked to identity (a=1, b=0) and ``state["n"]`` as h0;
    (4) only the [B, H, hd, hd] matrix memory ``C`` remains in the
    ``chunked_scan``, with the candidate ``n`` values it needs for the
    output recomputed in parallel from the op's carries. The per-step h is
    computed from candidate (pre-mask) state exactly like ``_mlstm_cell``,
    pad positions included. Returns (final_state, h [B, S, H, hd] f32).
    """
    from repro.kernels import ops as kops

    B, S, H = i_pre.shape
    hd = k.shape[-1]
    log_f = -jax.nn.softplus(-f_pre)                      # [B, S, H]

    def mstep(m, t):
        lf_t, ip_t, ok_t = t
        m_cand = jnp.maximum(lf_t + m, ip_t)
        m_cand = jnp.where(jnp.isfinite(m_cand), m_cand, ip_t)
        return jnp.where(ok_t[:, None], m_cand, m), (m, m_cand)

    m_last, (m_prev, m_cand) = jax.lax.scan(
        mstep, state["m"],
        (log_f.swapaxes(0, 1), i_pre.swapaxes(0, 1), valid.swapaxes(0, 1)))
    m_prev = m_prev.swapaxes(0, 1)                        # carried m at t
    m_cand = m_cand.swapaxes(0, 1)                        # candidate m_new
    i_g = jnp.exp(i_pre - m_cand)
    f_g = jnp.exp(log_f + m_prev - m_cand)
    f_g = jnp.where(jnp.isfinite(m_prev), f_g, 0.0)

    ok = valid[:, :, None, None]
    a_n = jnp.broadcast_to(jnp.where(ok, f_g[..., None], 1.0),
                           (B, S, H, hd))
    b_n = jnp.where(ok, i_g[..., None] * k, 0.0)
    n_seq = kops.rglru_scan_op(
        a_n.reshape(B, S, H * hd), b_n.reshape(B, S, H * hd),
        h0=state["n"].reshape(B, H * hd)).reshape(B, S, H, hd)
    n_prev = jnp.concatenate([state["n"][:, None], n_seq[:, :-1]], axis=1)
    n_cand = f_g[..., None] * n_prev + i_g[..., None] * k

    def cstep(C, t):
        kt, vt, qt, igt, fgt, nct, okt = t
        C_new = fgt[..., None, None] * C \
            + igt[..., None, None] * (vt[..., :, None] * kt[..., None, :])
        h_num = jnp.einsum("bhij,bhj->bhi", C_new, qt)
        h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", nct, qt)), 1.0)
        h = h_num / h_den[..., None]
        return jnp.where(okt.reshape(-1, 1, 1, 1), C_new, C), h

    C_last, hs = chunked_scan(
        cstep, state["C"],
        (k.swapaxes(0, 1), v.swapaxes(0, 1), q.swapaxes(0, 1),
         i_g.swapaxes(0, 1), f_g.swapaxes(0, 1), n_cand.swapaxes(0, 1),
         valid.swapaxes(0, 1)), chunk=64)
    final = {"C": C_last, "n": n_seq[:, -1], "m": m_last}
    return final, hs.swapaxes(0, 1)


def mlstm_full(p, x, n_heads: int, *, train: bool = False):
    """Full-sequence mLSTM block. x: [B,S,d] -> [B,S,d].

    Default (eval) path: the decomposed recurrence of ``_mlstm_scan_op``.
    ``train=True`` keeps the fused-cell ``chunked_scan`` (the scan op's
    Pallas kernel has no VJP; the cell path remats per chunk)."""
    xl, q, k, v, i_pre, f_pre = _mlstm_qkvif(p, x, n_heads)
    B, S = x.shape[:2]
    state = mlstm_state_init(B, x.shape[-1], n_heads)

    if train:
        def step(st, t):
            qt, kt, vt, it, ft = t
            return _mlstm_cell(st, (qt, kt, vt, it, ft))

        xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
              i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
        # small chunks: the [B,H,hd,hd] matrix memory is the dominant
        # residual, saved once per chunk (outer) and once per step within
        # the chunk being differentiated — 64 balances the two (DESIGN.md)
        _, hs = chunked_scan(step, state, xs, chunk=64)   # hs: [S,B,H,hd]
        h = hs.swapaxes(0, 1).reshape(B, S, -1).astype(x.dtype)
    else:
        _, hs = _mlstm_scan_op(q, k, v, i_pre, f_pre, state,
                               jnp.ones((B, S), bool))
        h = hs.reshape(B, S, -1).astype(x.dtype)
    gate = jax.nn.silu(dense_apply(p["up_r"], x))
    return dense_apply(p["down"], h * gate)


def _keep_state(valid_b, new, old):
    """Select per-batch-row between updated and carried state leaves."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            valid_b.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, old)


def mlstm_prefill(p, x, state, n_heads: int, lengths=None, *,
                  use_scan_op: bool = True):
    """Full-sequence mLSTM that also returns the final recurrent state —
    the batched replacement for looping ``mlstm_step``. ``lengths``:
    optional [B] true lengths for right-padded batches (pad steps keep the
    carried state). The normalizer recurrence runs through
    ``ops.rglru_scan_op`` (see ``_mlstm_scan_op``); ``use_scan_op=False``
    keeps the legacy fused-cell scan — the parity oracle the op path is
    pinned bit-identical against in tests. Returns (y [B, S, d],
    final_state)."""
    xl, q, k, v, i_pre, f_pre = _mlstm_qkvif(p, x, n_heads)
    B, S = x.shape[:2]
    valid = (jnp.ones((B, S), bool) if lengths is None
             else jnp.arange(S)[None, :] < jnp.asarray(lengths)[:, None])

    if use_scan_op:
        final, hs = _mlstm_scan_op(q, k, v, i_pre, f_pre, state, valid)
        h = hs.reshape(B, S, -1).astype(x.dtype)
    else:
        def step(st, t):
            qt, kt, vt, it, ft, ok = t
            new, h = _mlstm_cell(st, (qt, kt, vt, it, ft))
            return _keep_state(ok, new, st), h

        xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
              i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1),
              valid.swapaxes(0, 1))
        final, hs = chunked_scan(step, state, xs, chunk=64)
        h = hs.swapaxes(0, 1).reshape(B, S, -1).astype(x.dtype)
    gate = jax.nn.silu(dense_apply(p["up_r"], x))
    return dense_apply(p["down"], h * gate), final


def mlstm_step(p, x, state, n_heads: int):
    """One decode step. x: [B,1,d]."""
    xl, q, k, v, i_pre, f_pre = _mlstm_qkvif(p, x, n_heads)
    new_state, h = _mlstm_cell(
        state, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]))
    B = x.shape[0]
    h = h.reshape(B, 1, -1).astype(x.dtype)
    gate = jax.nn.silu(dense_apply(p["up_r"], x))
    return dense_apply(p["down"], h * gate), new_state


# ---------------------------------------------------------------------------
# sLSTM — stays on the fused-cell chunked_scan: h_{t-1} feeds every gate
# preactivation through the recurrent r_* matrices, so the recurrence is NOT
# of the h = a*h + b form the rglru_scan kernel accelerates.
# ---------------------------------------------------------------------------

def slstm_init(key, d: int, n_heads: int, *, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 11)
    hd = d // n_heads
    di = int(_SLSTM_PF * d)
    p = {"down": dense_init(ks[8], di, d, dtype=dtype),
         "up": dense_init(ks[9], d, di, dtype=dtype),
         "up_gate": dense_init(ks[10], d, di, dtype=dtype)}
    for j, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = dense_init(ks[j], d, d, bias=True, dtype=dtype)
        # recurrent block-diagonal connection, stored per head [H, hd, hd]
        p[f"r_{g}"] = _normal(ks[4 + j if j < 4 else j], (n_heads, hd, hd),
                              hd ** -0.5, dtype)
    return p


def slstm_state_init(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
            "h": z}


def _slstm_cell(p, state, x_t, n_heads):
    """x_t: [B,d] preact inputs; recurrent connections use h_{t-1}."""
    B, d = x_t.shape
    hd = d // n_heads
    h_prev = state["h"].reshape(B, n_heads, hd)

    def pre(g):
        wx = dense_apply(p[f"w_{g}"], x_t).astype(jnp.float32)
        rh = jnp.einsum("bhi,hij->bhj", h_prev,
                        p[f"r_{g}"].astype(jnp.float32)).reshape(B, d)
        return wx + rh

    i_pre, f_pre, z_pre, o_pre = pre("i"), pre("f"), pre("z"), pre("o")
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    m_new = jnp.where(jnp.isfinite(m_new), m_new, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    f_g = jnp.where(jnp.isfinite(state["m"]), f_g, 0.0)
    c = f_g * state["c"] + i_g * jnp.tanh(z_pre)
    n = f_g * state["n"] + i_g
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}, h


def slstm_full(p, x, n_heads: int):
    """Full-sequence sLSTM block. x: [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    state = slstm_state_init(B, d)

    def step(st, x_t):
        return _slstm_cell(p, st, x_t, n_heads)

    _, hs = chunked_scan(step, state,
                         x.swapaxes(0, 1).astype(jnp.float32))
    h = hs.swapaxes(0, 1).astype(x.dtype)          # [B,S,d]
    u = jax.nn.gelu(dense_apply(p["up"], h)) * dense_apply(p["up_gate"], h)
    return dense_apply(p["down"], u)


def slstm_prefill(p, x, state, n_heads: int, lengths=None):
    """Full-sequence sLSTM returning the final recurrent state — the batched
    replacement for looping ``slstm_step``. ``lengths`` as in
    ``mlstm_prefill``. Returns (y [B, S, d], final_state)."""
    B, S, d = x.shape
    valid = (jnp.ones((B, S), bool) if lengths is None
             else jnp.arange(S)[None, :] < jnp.asarray(lengths)[:, None])

    def step(st, t):
        x_t, ok = t
        new, h = _slstm_cell(p, st, x_t, n_heads)
        return _keep_state(ok, new, st), h

    final, hs = chunked_scan(step, state,
                             (x.swapaxes(0, 1).astype(jnp.float32),
                              valid.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    u = jax.nn.gelu(dense_apply(p["up"], h)) * dense_apply(p["up_gate"], h)
    return dense_apply(p["down"], u), final


def slstm_step(p, x, state, n_heads: int):
    """One decode step. x: [B,1,d]."""
    new_state, h = _slstm_cell(p, state, x[:, 0].astype(jnp.float32), n_heads)
    h = h[:, None, :].astype(x.dtype)
    u = jax.nn.gelu(dense_apply(p["up"], h)) * dense_apply(p["up_gate"], h)
    return dense_apply(p["down"], u), new_state
