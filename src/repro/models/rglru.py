"""Griffin / RecurrentGemma recurrent block: gated-linear-unit wrapper around
the RG-LRU (real-gated linear recurrent unit) with a short causal depthwise
conv [arXiv:2402.19427].

Full-sequence path uses ``jax.lax.associative_scan`` (log-depth, TPU-friendly);
decode is a single recurrence step with carried state. The Pallas kernel in
``repro.kernels.rglru_scan`` provides the blocked-VMEM version of the same
recurrence; this module is the jnp reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, _normal
from repro.models.scan_utils import chunked_scan

_C = 8.0          # RG-LRU gate exponent constant
_CONV_W = 4       # temporal conv width


def rglru_init(key, d: int, d_rnn: int, *, dtype=jnp.bfloat16):
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    # Λ init so that a = sigmoid(Λ) ∈ (0.9, 0.999) as in the paper
    lam = jnp.log(jnp.linspace(0.9, 0.999, d_rnn)) \
        - jnp.log1p(-jnp.linspace(0.9, 0.999, d_rnn))
    return {
        "in_gate": dense_init(k1, d, d_rnn, dtype=dtype),       # GLU gate branch
        "in_rec": dense_init(k2, d, d_rnn, dtype=dtype),        # recurrence branch
        "conv": _normal(k3, (_CONV_W, d_rnn), _CONV_W ** -0.5, dtype),
        "w_a": dense_init(k4, d_rnn, d_rnn, bias=True, dtype=dtype),
        "w_x": dense_init(k5, d_rnn, d_rnn, bias=True, dtype=dtype),
        "lam": lam.astype(jnp.float32),
        "out": dense_init(k6, d_rnn, d, dtype=dtype),
    }


def _gates(p, u):
    """u: [..., d_rnn] fp32 -> (log_a, gated input) both fp32."""
    r = jax.nn.sigmoid(dense_apply(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_x"], u).astype(jnp.float32))
    log_a = _C * r * (-jax.nn.softplus(-p["lam"]))  # log sigmoid(Λ) = -softplus(-Λ)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, b


def _causal_conv(p, u):
    """Depthwise causal conv width 4 over time. u: [B,S,d_rnn]."""
    w = p["conv"].astype(jnp.float32)
    pad = jnp.pad(u, ((0, 0), (_CONV_W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(_CONV_W))
    return out


def rglru_full(p, x, *, act: str = "gelu", use_assoc_scan: bool = False,
               train: bool = False):
    """Full-sequence Griffin recurrent block. x: [B,S,d] -> [B,S,d].

    Default (eval) path: ``ops.rglru_scan_op`` — the Pallas blocked-VMEM
    kernel on TPU, the plain ``lax.scan`` reference on CPU, which is
    bit-identical to the ``chunked_scan`` cell path it replaced (same f32
    multiply-add chain; pinned by tests). ``train=True`` keeps the
    ``chunked_scan`` path: the Pallas kernel has no VJP, and training wants
    the per-chunk remat structure anyway. ``use_assoc_scan``: log-depth
    associative scan — lower latency on real hardware but O(S log S)
    rematerialization in the backward pass (perf knob, see EXPERIMENTS.md).
    """
    from repro.kernels import ops as kops

    gate = jax.nn.gelu(dense_apply(p["in_gate"], x))
    u = dense_apply(p["in_rec"], x).astype(jnp.float32)
    u = _causal_conv(p, u)
    a, b = _gates(p, u)

    if use_assoc_scan:
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    elif train:
        def cell(carry, ab):
            at, bt = ab
            hh = at * carry + bt
            return hh, hh

        B, S, dr = a.shape
        _, h = chunked_scan(cell, jnp.zeros((B, dr), jnp.float32),
                            (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        h = h.swapaxes(0, 1)
    else:
        h = kops.rglru_scan_op(a, b)
    y = (h.astype(x.dtype) * gate)
    return dense_apply(p["out"], y)


def rglru_prefill(p, x, state, *, act: str = "gelu", lengths=None,
                  use_scan_op: bool = True):
    """Full-sequence pass that also returns the decode state the sequence
    leaves behind — the batched replacement for looping ``rglru_step``.

    x: [B, S, d]; ``state`` is the (usually fresh) carry from
    ``rglru_state_init``. ``lengths``: optional [B] true lengths for
    right-padded batches — pad steps are identity updates (a=1, b=0), so the
    final state is exactly the state after each row's own last real token.
    The recurrence runs through ``ops.rglru_scan_op`` (Pallas on TPU, plain
    scan on CPU) with the carried ``state["h"]`` as h0; ``use_scan_op=False``
    keeps the legacy ``chunked_scan`` path — the parity oracle the op path
    is pinned bit-identical against in tests. Returns (y [B, S, d],
    new_state).
    """
    from repro.kernels import ops as kops

    B, S, _ = x.shape
    gate = jax.nn.gelu(dense_apply(p["in_gate"], x))
    u_pre = dense_apply(p["in_rec"], x).astype(jnp.float32)     # [B, S, dr]
    # continue the carried conv history (zeros for a fresh prompt)
    hist = jnp.concatenate([state["conv"].astype(jnp.float32), u_pre], axis=1)
    w = p["conv"].astype(jnp.float32)
    u_c = sum(hist[:, i:i + S, :] * w[i] for i in range(_CONV_W))
    a, b = _gates(p, u_c)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    valid = (jnp.arange(S)[None, :] < lengths[:, None])[..., None]
    a = jnp.where(valid, a, 1.0)
    b = jnp.where(valid, b, 0.0)

    if use_scan_op:
        h = kops.rglru_scan_op(a, b, h0=state["h"])
        h_last = h[:, -1]          # pad steps are identity, so this IS the
    else:                          # carry after each row's last real token
        def cell(carry, ab):
            at, bt = ab
            hh = at * carry + bt
            return hh, hh

        h_last, h = chunked_scan(cell, state["h"],
                                 (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        h = h.swapaxes(0, 1)
    y = dense_apply(p["out"], h.astype(x.dtype) * gate)
    # conv state after len steps = last CONV_W-1 rows of
    # [carried history, u_0 .. u_{len-1}] = hist[len : len + CONV_W - 1]
    hist_idx = lengths[:, None] + jnp.arange(_CONV_W - 1)[None, :]
    hist_rows = jnp.take_along_axis(hist, hist_idx[..., None], axis=1)
    return y, {"h": h_last, "conv": hist_rows.astype(state["conv"].dtype)}


def rglru_state_init(batch: int, d_rnn: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, d_rnn), dtype=jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, d_rnn), dtype=dtype),
    }


def rglru_step(p, x, state, *, act: str = "gelu"):
    """One decode step. x: [B,1,d]; returns (y [B,1,d], new state)."""
    gate = jax.nn.gelu(dense_apply(p["in_gate"], x))            # [B,1,dr]
    u = dense_apply(p["in_rec"], x).astype(jnp.float32)         # [B,1,dr]
    hist = jnp.concatenate([state["conv"].astype(jnp.float32), u], axis=1)
    w = p["conv"].astype(jnp.float32)
    u_c = jnp.einsum("btd,td->bd", hist, w)[:, None, :]         # [B,1,dr]
    a, b = _gates(p, u_c)
    h = a[:, 0] * state["h"] + b[:, 0]                          # [B,dr]
    y = (h[:, None, :].astype(x.dtype) * gate)
    new_state = {"h": h, "conv": hist[:, 1:, :].astype(state["conv"].dtype)}
    return dense_apply(p["out"], y), new_state
