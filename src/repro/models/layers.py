"""Shared neural-network building blocks (pure JAX, functional style).

Every module follows the ``init(key, ...) -> params`` / ``apply(params, x)``
convention; params are plain dicts of jnp arrays so they compose into pytrees
that pjit/shard_map can shard.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, *, dtype=jnp.bfloat16):
    return {"table": _normal(key, (vocab, d), 1.0, dtype)}


def embed_apply(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def embed_logits(p, x):
    """Tied read-out: x @ table^T (fp32 accumulation for the softmax)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm", *, dtype=jnp.bfloat16):
    p = {"scale": jnp.ones((d,), dtype=dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=dtype)
    return p


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (llama-style)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, *, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype=dtype),
        "w_up": dense_init(k2, d, d_ff, dtype=dtype),
        "w_down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind!r}")


def mlp_apply(p, x, act: str = "silu"):
    g = _act(dense_apply(p["w_gate"], x), act)
    u = dense_apply(p["w_up"], x)
    return dense_apply(p["w_down"], g * u)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)
