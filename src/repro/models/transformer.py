"""Unified decoder-only transformer covering all assigned architecture
families (dense / MoE / VLM / audio / hybrid RG-LRU / xLSTM).

Homogeneous attention stacks (dense, moe, vlm, audio) use stacked layer
params + ``jax.lax.scan`` with per-layer remat; heterogeneous block patterns
(recurrentgemma, xlstm) use an unrolled loop over per-layer param tuples.

The split-learning machinery in ``repro.core.split`` slices the same layer
params into encoder/decoder halves, so every forward path here is expressed
through ``run_layers`` / ``run_layers_decode``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding
from repro.models.attention import (attn_init, decode_attention, full_attention,
                                    init_cache, paged_decode_attention,
                                    paged_prefill_attention, prefill_attention)
from repro.models.layers import (dense_apply, dense_init, embed_apply,
                                 embed_init, mlp_apply, mlp_init, norm_apply,
                                 norm_init)
from repro.models.moe import moe_apply, moe_init
from repro.models.moe_ep import moe_apply_ep, moe_supports_ep
from repro.models.rglru import (rglru_full, rglru_init, rglru_prefill,
                                rglru_state_init, rglru_step)
from repro.models.xlstm import (mlstm_full, mlstm_init, mlstm_prefill,
                                mlstm_state_init, mlstm_step, slstm_full,
                                slstm_init, slstm_prefill, slstm_state_init,
                                slstm_step)

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def model_dtype(cfg: ModelConfig):
    return _DTYPES[cfg.dtype]


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str):
    dt = model_dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.d_model, cfg.norm, dtype=dt)}
    if kind == "attn":
        p["mix"] = attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, qkv_bias=cfg.qkv_bias, dtype=dt)
    elif kind == "rglru":
        p["mix"] = rglru_init(k1, cfg.d_model, cfg.d_rnn or cfg.d_model,
                              dtype=dt)
    elif kind == "mlstm":
        p["mix"] = mlstm_init(k1, cfg.d_model, cfg.n_heads, dtype=dt)
    elif kind == "slstm":
        p["mix"] = slstm_init(k1, cfg.d_model, cfg.n_heads, dtype=dt)
    else:
        raise ValueError(kind)
    if kind in ("attn", "rglru") and cfg.d_ff:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype=dt)
        if cfg.is_moe:
            p["mlp"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dtype=dt)
        else:
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dt)
    return p


def _attn_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window or cfg.local_window


def full_attention_arch(cfg: ModelConfig) -> bool:
    """True if any layer attends the full context (no window): the KV cache
    is addressed by absolute position, so serving must keep
    ``prompt_len + max_new_tokens <= cache_len`` or the rolling write
    (``pos % cache_len``) silently evicts early prompt context."""
    return (not _attn_window(cfg)) and any(
        cfg.block_kind(i) == "attn" for i in range(cfg.n_layers))


def check_cache_capacity(cfg: ModelConfig, pos: int, n: int, cache_len: int,
                         what: str = "generation") -> None:
    """The full-attention capacity rule, shared by every dense serving
    entry point (sync engine prefill / decode and the launcher loop):
    ``pos + n`` must not exceed ``cache_len`` or the rolling write would
    silently evict early prompt context. Windowed / recurrent archs wrap by
    design and always pass; the paged pool replaces this rule with
    page-budget admission. Raises ``ValueError`` with the offending spans.
    """
    if full_attention_arch(cfg) and pos + n > cache_len:
        raise ValueError(
            f"{what} of {n} tokens from position {pos} exceeds cache_len "
            f"{cache_len} for a full-attention arch (the rolling cache "
            f"would overwrite prompt context)")


def block_apply_full(p, x, positions, cfg: ModelConfig, kind: str,
                     train: bool = False):
    """Full-sequence block. Returns (x, aux_loss). ``train`` keeps the
    recurrent families on their remat-friendly ``chunked_scan`` paths (the
    Pallas scan op has no VJP); eval routes them through
    ``ops.rglru_scan_op``."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind == "attn":
        mix = full_attention(p["mix"], h, positions, n_q=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, hd=cfg.head_dim,
                             rope_theta=cfg.rope_theta,
                             window=_attn_window(cfg))
    elif kind == "rglru":
        mix = rglru_full(p["mix"], h, act=cfg.act, train=train)
    elif kind == "mlstm":
        mix = mlstm_full(p["mix"], h, cfg.n_heads, train=train)
    elif kind == "slstm":
        mix = slstm_full(p["mix"], h, cfg.n_heads)
    else:
        raise ValueError(kind)
    x = x + mix
    if "mlp" in p:
        h = norm_apply(p["norm2"], x, cfg.norm)
        if cfg.is_moe:
            mesh = sharding.ctx_mesh()
            if sharding.ctx_flag("moe_ep") and moe_supports_ep(
                    cfg.n_experts, mesh, h.shape[0], h.shape[1]):
                m, aux = moe_apply_ep(p["mlp"], h, k=cfg.experts_per_tok,
                                      act=cfg.act, mesh=mesh)
            else:
                m, aux = moe_apply(p["mlp"], h, k=cfg.experts_per_tok,
                                   act=cfg.act)
        else:
            m = mlp_apply(p["mlp"], h, cfg.act)
        x = x + m
    return x, aux


def block_apply_decode(p, x, state, cur_pos, cfg: ModelConfig, kind: str,
                       block_table=None):
    """One-token decode. Returns (x, new_state). With ``block_table`` the
    attention state is a paged arena indexed through the table instead of a
    dense per-slot rolling cache."""
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind == "attn" and block_table is not None:
        mix, new_state = paged_decode_attention(
            p["mix"], h, state, block_table, cur_pos, n_q=cfg.n_heads,
            n_kv=cfg.n_kv_heads, hd=cfg.head_dim, rope_theta=cfg.rope_theta)
    elif kind == "attn":
        mix, new_state = decode_attention(
            p["mix"], h, state, cur_pos, n_q=cfg.n_heads, n_kv=cfg.n_kv_heads,
            hd=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=_attn_window(cfg))
    elif kind == "rglru":
        mix, new_state = rglru_step(p["mix"], h, state, act=cfg.act)
    elif kind == "mlstm":
        mix, new_state = mlstm_step(p["mix"], h, state, cfg.n_heads)
    elif kind == "slstm":
        mix, new_state = slstm_step(p["mix"], h, state, cfg.n_heads)
    else:
        raise ValueError(kind)
    x = x + mix
    if "mlp" in p:
        h = norm_apply(p["norm2"], x, cfg.norm)
        if cfg.is_moe:
            m, _ = moe_apply(p["mlp"], h, k=cfg.experts_per_tok, act=cfg.act)
        else:
            m = mlp_apply(p["mlp"], h, cfg.act)
        x = x + m
    return x, new_state


def block_apply_prefill(p, x, positions, state, cfg: ModelConfig, kind: str,
                        lengths=None, block_table=None):
    """Full-sequence block that also populates the decode state (KV cache or
    recurrent carry) — one forward instead of S sequential decode steps.
    Returns (x, new_state). With ``block_table`` the attention rows scatter
    into a paged arena through the table."""
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind == "attn" and block_table is not None:
        mix, new_state = paged_prefill_attention(
            p["mix"], h, positions, state, block_table, n_q=cfg.n_heads,
            n_kv=cfg.n_kv_heads, hd=cfg.head_dim, rope_theta=cfg.rope_theta,
            lengths=lengths)
    elif kind == "attn":
        mix, new_state = prefill_attention(
            p["mix"], h, positions, state, n_q=cfg.n_heads,
            n_kv=cfg.n_kv_heads, hd=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=_attn_window(cfg), lengths=lengths)
    elif kind == "rglru":
        mix, new_state = rglru_prefill(p["mix"], h, state, act=cfg.act,
                                       lengths=lengths)
    elif kind == "mlstm":
        mix, new_state = mlstm_prefill(p["mix"], h, state, cfg.n_heads,
                                       lengths=lengths)
    elif kind == "slstm":
        mix, new_state = slstm_prefill(p["mix"], h, state, cfg.n_heads,
                                       lengths=lengths)
    else:
        raise ValueError(kind)
    x = x + mix
    if "mlp" in p:
        h = norm_apply(p["norm2"], x, cfg.norm)
        if cfg.is_moe:
            # the plain (non-EP) expert path, matching what decode runs —
            # routing is per token, so results are identical either way
            m, _ = moe_apply(p["mlp"], h, k=cfg.experts_per_tok, act=cfg.act)
        else:
            m = mlp_apply(p["mlp"], h, cfg.act)
        x = x + m
    return x, new_state


def block_state_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     kv_bits: int = 0):
    dt = model_dtype(cfg)
    if kind == "attn":
        w = _attn_window(cfg)
        clen = min(cache_len, w) if w else cache_len
        return init_cache(batch, cfg.n_kv_heads, cfg.head_dim, clen,
                          dtype=dt, kv_bits=kv_bits)
    if kind == "rglru":
        return rglru_state_init(batch, cfg.d_rnn or cfg.d_model, dtype=dt)
    if kind == "mlstm":
        return mlstm_state_init(batch, cfg.d_model, cfg.n_heads)
    if kind == "slstm":
        return slstm_state_init(batch, cfg.d_model)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dt = model_dtype(cfg)
    k_emb, k_layers, k_head, k_bneck = jax.random.split(key, 4)
    params: Dict[str, Any] = {}

    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        keys = jax.random.split(k_emb, cfg.n_codebooks)
        params["embed"] = {"table": jnp.stack(
            [embed_init(k, cfg.vocab_size, cfg.d_model, dtype=dt)["table"]
             for k in keys])}                      # [K, V, d]
    else:
        params["embed"] = embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                                     dtype=dt)

    if cfg.homogeneous:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: block_init(k, cfg, "attn"))(keys)   # stacked [L, ...]
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = tuple(
            block_init(keys[i], cfg, cfg.block_kind(i))
            for i in range(cfg.n_layers))

    params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dtype=dt)
    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        keys = jax.random.split(k_head, cfg.n_codebooks)
        params["lm_head"] = {"w": jnp.stack(
            [dense_init(k, cfg.d_model, cfg.vocab_size, dtype=dt)["w"]
             for k in keys])}                      # [K, d, V]
    elif not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                       dtype=dt)
    return params


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      kv_bits: int = 0):
    """Per-layer decode state (stacked for homogeneous archs).
    ``kv_bits=8``: int8 KV cache (attention blocks only)."""
    if cfg.homogeneous:
        one = block_state_init(cfg, "attn", batch, cache_len, kv_bits)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    return tuple(block_state_init(cfg, cfg.block_kind(i), batch, cache_len,
                                  kv_bits if cfg.block_kind(i) == "attn"
                                  else 0)
                 for i in range(cfg.n_layers))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig,
                 embeddings: Optional[jnp.ndarray] = None):
    """tokens: [B,S] int32, or [B,K,S] for audio. ``embeddings`` is the
    stubbed modality-frontend output ([B,Nv,d] vision prefix)."""
    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        # sum codebook embeddings: table [K,V,d], tokens [B,K,S]
        x = jnp.sum(jnp.take_along_axis(
            params["embed"]["table"][None],            # [1,K,V,d]
            tokens[..., None].astype(jnp.int32), axis=2), axis=1)
    else:
        x = embed_apply(params["embed"], tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend == "vision" and embeddings is not None:
        x = jnp.concatenate([embeddings.astype(x.dtype), x], axis=1)
    return x


def norm_apply_final(params, x, cfg: ModelConfig):
    return norm_apply(params["final_norm"], x, cfg.norm)


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        return jnp.einsum("bsd,kdv->bksv", x.astype(jnp.float32),
                          params["lm_head"]["w"].astype(jnp.float32))
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                          params["embed"]["table"].astype(jnp.float32))
    return x.astype(jnp.float32) @ params["lm_head"]["w"].astype(jnp.float32)


def decode_tail_tokens(params, x, cfg: ModelConfig):
    """Fused decode tail: final norm -> LM head -> argmax in one kernel
    (``ops.decode_tail_op``), replacing the three separate HLO groups the
    legacy ``norm_apply + lm_logits + jnp.argmax`` chain emits per tick.
    On CPU the op's reference path is expression-identical to that chain,
    so tokens cannot move; multi-codebook audio heads keep the legacy chain
    (the per-codebook argmax is not a single head gather).

    x: [B, S, d] decoder output (pre final norm). Returns int32 tokens
    [B, S] ([B, K, S] audio)."""
    from repro.kernels import ops as kops

    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        xn = norm_apply(params["final_norm"], x, cfg.norm)
        return jnp.argmax(lm_logits(params, xn, cfg), axis=-1).astype(
            jnp.int32)
    fn = params["final_norm"]
    if cfg.tie_embeddings:
        heads, tied = params["embed"]["table"][None], True
    else:
        heads, tied = params["lm_head"]["w"][None], False
    return kops.decode_tail_op(x, fn["scale"], fn.get("bias"), heads,
                               norm_kind=cfg.norm, tied=tied)


# ---------------------------------------------------------------------------
# layer runners (shared by the full model and the split encoder/decoder)
# ---------------------------------------------------------------------------

def run_layers(layers, x, positions, cfg: ModelConfig, *, train: bool,
               kinds: Optional[Tuple[str, ...]] = None):
    """Full-sequence pass through a group of layers.

    ``layers``: stacked pytree (homogeneous) or tuple of per-layer pytrees.
    Returns (x, aux_loss_sum).
    """
    if cfg.homogeneous:
        def body(carry, lp):
            h, aux = carry
            h = sharding.constrain(h, "resid")
            h, a = block_apply_full(lp, h, positions, cfg, "attn", train)
            return (h, aux + a), None
        f = jax.checkpoint(body) if train else body
        (x, aux), _ = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)), layers)
        return x, aux

    kinds = kinds or tuple(cfg.block_kind(i) for i in range(len(layers)))
    aux = jnp.zeros((), jnp.float32)
    for lp, kind in zip(layers, kinds):
        x = sharding.constrain(x, "resid")
        fn = functools.partial(block_apply_full, cfg=cfg, kind=kind,
                               train=train)
        if train:
            fn = jax.checkpoint(fn)
        x, a = fn(lp, x, positions)
        aux = aux + a
    return x, aux


def run_layers_decode(layers, x, states, cur_pos, cfg: ModelConfig,
                      kinds: Optional[Tuple[str, ...]] = None,
                      block_table=None):
    """One-token decode through a group of layers. Returns (x, new_states).
    ``block_table`` (paged serving) is shared by every attention layer —
    the scan closes over it while the per-layer arenas ride the carry."""
    if cfg.homogeneous:
        def body(h, inp):
            lp, st = inp
            h, new_st = block_apply_decode(lp, h, st, cur_pos, cfg, "attn",
                                           block_table)
            return h, new_st
        x, new_states = jax.lax.scan(body, x, (layers, states))
        return x, new_states

    kinds = kinds or tuple(cfg.block_kind(i) for i in range(len(layers)))
    new_states = []
    for lp, st, kind in zip(layers, states, kinds):
        x, ns = block_apply_decode(lp, x, st, cur_pos, cfg, kind, block_table)
        new_states.append(ns)
    return x, tuple(new_states)


def run_layers_prefill(layers, x, positions, states, cfg: ModelConfig,
                       kinds: Optional[Tuple[str, ...]] = None, lengths=None,
                       block_table=None):
    """Full-sequence pass through a group of layers that also populates the
    per-layer decode states. Returns (x, new_states)."""
    if cfg.homogeneous:
        def body(h, inp):
            lp, st = inp
            h, ns = block_apply_prefill(lp, h, positions, st, cfg, "attn",
                                        lengths, block_table)
            return h, ns
        x, new_states = jax.lax.scan(body, x, (layers, states))
        return x, new_states

    kinds = kinds or tuple(cfg.block_kind(i) for i in range(len(layers)))
    new_states = []
    for lp, st, kind in zip(layers, states, kinds):
        x, ns = block_apply_prefill(lp, x, positions, st, cfg, kind, lengths,
                                    block_table)
        new_states.append(ns)
    return x, tuple(new_states)


# ---------------------------------------------------------------------------
# top-level forwards
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, *, train: bool = False,
            embeddings: Optional[jnp.ndarray] = None):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x = embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = run_layers(params["layers"], x, positions, cfg, train=train)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = sharding.constrain(lm_logits(params, x, cfg), "logits")
    return logits, aux


def prefill(params, tokens, cfg: ModelConfig, states, lengths=None,
            embeddings: Optional[jnp.ndarray] = None, block_table=None):
    """Batched full-sequence prefill: run the whole prompt in ONE forward
    pass while populating ``states`` (KV caches scattered at their rolling
    slots, recurrent carries advanced to each row's last real token).

    tokens: [B, S] (or [B, K, S] audio), right-padded to a common bucket
    length; ``lengths``: optional [B] true prompt lengths (None: all S).
    With vision ``embeddings`` the prefix is concatenated exactly as in
    :func:`forward`, and ``lengths`` refer to the concatenated sequence.
    Returns (logits at each row's last real position, shaped like
    ``decode_step`` output, new_states).
    """
    x = embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    x, new_states = run_layers_prefill(params["layers"], x, positions,
                                       states, cfg, lengths=lengths,
                                       block_table=block_table)
    last = (lengths - 1 if lengths is not None
            else jnp.full((B,), S - 1, jnp.int32))
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)       # [B, 1, d]
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return lm_logits(params, x, cfg), new_states


def decode_step(params, token, states, cur_pos, cfg: ModelConfig,
                embeddings: Optional[jnp.ndarray] = None, block_table=None,
                return_tokens: bool = False):
    """One new token against the decode state. token: [B,1] (or [B,K,1]
    audio). Returns (logits for the new position, new states); with
    ``return_tokens`` the fused decode tail replaces the logits with argmax
    int32 tokens (shaped like the token input) and the [B, V] logits never
    materialize."""
    x = embed_tokens(params, token, cfg, None)
    x, new_states = run_layers_decode(params["layers"], x, states, cur_pos,
                                      cfg, block_table=block_table)
    if return_tokens:
        return decode_tail_tokens(params, x, cfg), new_states
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return lm_logits(params, x, cfg), new_states


def lm_loss(logits, labels, mask=None):
    """Cross-entropy over the vocab axis; labels int [B,S] or [B,K,S]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
