from repro.models import (attention, layers, lstm, moe, rglru, sharding,
                          transformer, xlstm)

__all__ = ["attention", "layers", "lstm", "moe", "rglru", "sharding",
           "transformer", "xlstm"]
