"""Chunked-remat time scans for recurrent blocks.

A naive ``lax.scan`` over T timesteps saves every per-step intermediate for
the backward pass — for mLSTM that is the [B,H,hd,hd] matrix memory PER STEP
(terabytes at train_4k scale). Chunking the scan and rematerializing inside
each chunk bounds the saved state to one recurrent state per chunk, which is
the standard TPU memory/recompute tradeoff (and mirrors what the Pallas
rglru kernel does in VMEM).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def chunked_scan(cell: Callable, state0, xs, *, chunk: int = 256):
    """scan(cell, state0, xs) with per-chunk remat.

    cell(state, x_t) -> (state, y_t); xs leaves have leading dim T.
    Saved residuals: one recurrent state per chunk instead of per step.
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    if T <= chunk or T % chunk != 0:
        return jax.lax.scan(cell, state0, xs)
    n = T // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(state, xc):
        return jax.lax.scan(cell, state, xc)

    state, ys = jax.lax.scan(outer, state0, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return state, ys
