"""Mixtral-8x7B, 8 experts top-2, sliding-window attention [arXiv:2401.04088].

SWA (window 4096) makes decode over very long contexts O(window) — this arch
runs the ``long_500k`` shape with a rolling KV cache.
"""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,        # GQA kv=8
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    split=SplitConfig(split_at=16, d_bottleneck=1024, quant_bits=8),
    source="arXiv:2401.04088",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_experts=4, experts_per_tok=2, sliding_window=64,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
