"""Granite-8B-Code, llama-architecture dense decoder [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,        # GQA kv=8
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    split=SplitConfig(split_at=18, d_bottleneck=1024, quant_bits=8),
    source="arXiv:2405.04324",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
        vocab_size=512,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
