"""StableLM-3B dense decoder [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,       # GQA kv=32 (full MHA)
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    act="silu",
    split=SplitConfig(split_at=16, d_bottleneck=640, quant_bits=8),
    source="hf:stabilityai/stablelm-2-1_6b",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
        vocab_size=512,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
