"""Config registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Arch ids match the assignment table; ``lumos5g-lstm`` is the paper's own PoC.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import LSTMConfig, ModelConfig, ShapeConfig, SplitConfig, TrainConfig
from repro.configs.shapes import SHAPES, get_shape

_MODULES: Dict[str, str] = {
    "musicgen-large": "repro.configs.musicgen_large",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "granite-8b": "repro.configs.granite_8b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "lumos5g-lstm": "repro.configs.lumos5g_lstm",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "lumos5g-lstm"]


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_reduced(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).reduced()


__all__ = [
    "ARCH_IDS", "SHAPES", "LSTMConfig", "ModelConfig", "ShapeConfig",
    "SplitConfig", "TrainConfig", "get_config", "get_reduced", "get_shape",
]
