"""Model / shape / split-learning configuration dataclasses.

Every assigned architecture gets a ``ModelConfig`` in its own module; the
paper's LSTM proof-of-concept uses ``LSTMConfig``. Configs are frozen
dataclasses so they can be closed over by jitted functions safely.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class SplitConfig:
    """The paper's technique: where to cut the model and how to compress the
    boundary latent.

    ``split_at``       cut after this many blocks (encoder = blocks[:split_at]).
    ``d_bottleneck``   width of the phase-2 bottleneck code z' (0 disables).
    ``quant_bits``     transmitted-code quantization (8 or 4; 0 = bf16 as-is).
    ``modes``          named (layer, width) exits; mode 0 is always the
                       full-width phase-1 code z.
    """
    split_at: int = 0
    d_bottleneck: int = 0
    quant_bits: int = 8
    # Each extra mode adds a cascade phase: (bottleneck_width, quant_bits).
    extra_modes: Tuple[Tuple[int, int], ...] = ()

    @property
    def n_modes(self) -> int:
        return 1 + (1 if self.d_bottleneck else 0) + len(self.extra_modes)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    # --- attention details ---
    qkv_bias: bool = False
    sliding_window: int = 0   # 0 = full attention
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "silu"         # silu | gelu  (gated MLP)
    tie_embeddings: bool = False
    # --- heterogeneous block pattern, cycled over layers ---
    # entries: "attn" | "rglru" | "slstm" | "mlstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    d_rnn: int = 0            # RG-LRU width (lru_width)
    local_window: int = 0     # local attention window for hybrid archs
    # --- modality frontend stubs (embeddings provided by input_specs) ---
    frontend: str = "none"    # none | audio | vision
    n_codebooks: int = 0      # musicgen EnCodec streams
    n_vision_tokens: int = 0  # llava anyres patch-embedding prefix length
    # --- split-learning (the paper's technique) ---
    split: SplitConfig = field(default_factory=SplitConfig)
    # --- numerics ---
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.split.split_at == 0:
            object.__setattr__(
                self, "split",
                dataclasses.replace(self.split, split_at=self.n_layers // 2))

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def homogeneous(self) -> bool:
        return len(set(self.block_pattern)) == 1 and self.block_pattern[0] == "attn"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode over very long contexts is O(window) / O(1)-state."""
        attn_layers = [k for k in self.block_pattern if k == "attn"]
        if not attn_layers:
            return True  # pure recurrent
        if self.sliding_window or self.local_window:
            return True
        return len(set(self.block_pattern)) > 1 and self.local_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline, not allocation)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.frontend == "audio" and self.n_codebooks > 1:
            total += (self.n_codebooks - 1) * self.vocab_size * d
        for layer in range(self.n_layers):
            kind = self.block_kind(layer)
            total += 2 * d  # two norms per block
            if kind == "attn":
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                if self.qkv_bias:
                    total += (n_q + 2 * n_kv) * hd
            elif kind == "rglru":
                dr = self.d_rnn or d
                # linear in/out + gates (recurrence + input) + conv1d(4) + a-param
                total += 2 * d * dr + 2 * dr * dr + 4 * dr + dr
            elif kind in ("slstm", "mlstm"):
                # 4 gates projections + output
                total += 4 * d * d + d * d
            if kind in ("attn", "rglru"):  # blocks followed by an MLP
                if self.is_moe:
                    total += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                elif self.d_ff:
                    total += 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses experts_per_tok of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.d_ff
        return dense + self.n_layers * self.experts_per_tok * 3 * d * self.d_ff


@dataclass(frozen=True)
class LSTMConfig:
    """The paper's proof-of-concept model (Fig. 6)."""
    name: str = "lumos5g-lstm"
    n_features: int = 11          # Lumos5G features [6, Table 1]
    seq_len: int = 20             # T = 20 timesteps
    n_classes: int = 3            # throughput class (low/med/high), per Lumos5G
    enc_cells: Tuple[int, ...] = (128, 128)   # phase-1 encoder LSTMs
    bottleneck_cells: int = 32    # phase-2 added LSTM layer (layer A)
    dec_hidden: Tuple[int, ...] = (64,)       # time-distributed dense decoder
    learning_rate: float = 1e-2   # paper Sec. VI
    batch_size: int = 256         # paper Sec. VI
    dtype: str = "float32"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
