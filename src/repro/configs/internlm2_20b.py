"""InternLM2-20B dense decoder, GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,        # GQA kv=8
    d_ff=16384,
    vocab_size=92544,
    split=SplitConfig(split_at=24, d_bottleneck=1536, quant_bits=8),
    source="arXiv:2403.17297",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, d_ff=512,
        vocab_size=512,
        split=SplitConfig(split_at=1, d_bottleneck=48, quant_bits=8))
