"""Qwen2.5-3B dense decoder with QKV bias and aggressive GQA [hf:Qwen/Qwen2.5-0.5B family]."""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,        # GQA kv=2
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    split=SplitConfig(split_at=18, d_bottleneck=512, quant_bits=8),
    source="hf:Qwen/Qwen2.5-0.5B",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
        vocab_size=512,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
