"""The paper's own proof-of-concept config (Sec. V-VI, Fig. 6).

LSTM encoder (2 x 128 cells) + time-distributed Dense decoder; phase-2
bottleneck LSTM of 32 cells; T=20 timesteps, 11 Lumos5G features,
lr=1e-2, batch=256.
"""
from repro.configs.base import LSTMConfig

CONFIG = LSTMConfig()


def reduced() -> LSTMConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, enc_cells=(32, 32), bottleneck_cells=8, dec_hidden=(16,),
        seq_len=8)
