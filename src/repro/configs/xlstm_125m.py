"""xLSTM-125M: alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0 per the assignment — xLSTM blocks carry their own up/down projections
inside the block (pre-up-projection mLSTM, post-up-projection sLSTM); there is
no separate transformer MLP. Pure recurrent -> runs ``long_500k`` with O(1)
state.
"""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,        # (GQA kv=4) — heads of the mLSTM matrix memory
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),   # 1:1 alternation
    norm="layernorm",
    act="gelu",
    split=SplitConfig(split_at=6, d_bottleneck=192, quant_bits=8),
    source="arXiv:2405.04517",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        vocab_size=512,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
