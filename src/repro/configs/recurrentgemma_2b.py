"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427].

Hybrid sub-quadratic arch — runs the ``long_500k`` shape with O(1) recurrent
state + O(window) local-attention cache. The split-learning boundary payload
for this family includes the RG-LRU recurrent state (beyond-paper extension
recorded in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,        # MQA (GQA kv=1)
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    block_pattern=("rglru", "rglru", "attn"),   # Griffin 2 recurrent : 1 attn
    d_rnn=2560,
    local_window=2048,
    tie_embeddings=True,
    split=SplitConfig(split_at=12, d_bottleneck=640, quant_bits=8),
    source="arXiv:2402.19427",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
        vocab_size=512, head_dim=32, d_rnn=128, local_window=32,
        split=SplitConfig(split_at=2, d_bottleneck=32, quant_bits=8))
