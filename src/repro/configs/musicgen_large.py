"""MusicGen-Large decoder backbone over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec / mel frontend is STUBBED per assignment:
``input_specs`` provides precomputed frame token ids per codebook; the model
embeds each of the 4 codebook streams and sums them (MusicGen's "delay"
interleave collapses to a sum of codebook embeddings at the backbone input).
"""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,        # MHA (GQA kv=32)
    d_ff=8192,
    vocab_size=2048,      # EnCodec codebook size
    act="gelu",
    norm="layernorm",
    frontend="audio",
    n_codebooks=4,
    split=SplitConfig(split_at=24, d_bottleneck=512, quant_bits=8,
                      extra_modes=((128, 8),)),
    source="arXiv:2306.05284",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, n_codebooks=2,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
