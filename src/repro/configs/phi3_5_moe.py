"""Phi-3.5-MoE 42B (6.6B active), 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,        # GQA kv=8
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    experts_per_tok=2,
    split=SplitConfig(split_at=16, d_bottleneck=1024, quant_bits=8),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_experts=4, experts_per_tok=2,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
