"""LLaVA-NeXT-34B VLM backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf family].

anyres tiling: the SigLIP/ViT vision tower + projector is STUBBED per
assignment — ``input_specs`` provides precomputed patch embeddings of shape
(batch, n_vision_tokens, d_model) that the backbone consumes as a prefix
before the text tokens.
"""
from repro.configs.base import ModelConfig, SplitConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,        # GQA kv=8
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    n_vision_tokens=576,  # one anyres base tile (24x24 patches)
    split=SplitConfig(split_at=30, d_bottleneck=1792, quant_bits=8),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384,
        vocab_size=512, n_vision_tokens=16,
        split=SplitConfig(split_at=1, d_bottleneck=32, quant_bits=8))
