from repro.data import lumos5g, tokens

__all__ = ["lumos5g", "tokens"]
