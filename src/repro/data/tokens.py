"""Synthetic token / multimodal-embedding pipeline for the transformer
architectures: deterministic, seekable (step -> batch), and host-shardable.

``make_batch`` mirrors ``launch.dryrun.input_specs`` exactly — the arrays it
materializes have the same shapes/dtypes as the specs the dry-run lowers
with, so smoke tests and the real trainer share one code path.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def token_batch_shapes(cfg: ModelConfig, batch: int, seq: int,
                       kind: str) -> Dict[str, tuple]:
    """Shape dict for one batch (decode kinds use seq=1 new token)."""
    s = 1 if kind == "decode" else seq
    if cfg.frontend == "audio":
        shapes = {"tokens": (batch, cfg.n_codebooks, s),
                  "labels": (batch, cfg.n_codebooks, s)}
    elif cfg.frontend == "vision" and kind != "decode":
        text = max(s - cfg.n_vision_tokens, 1)
        shapes = {"tokens": (batch, text), "labels": (batch, text),
                  "embeddings": (batch, cfg.n_vision_tokens, cfg.d_model)}
    else:
        shapes = {"tokens": (batch, s), "labels": (batch, s)}
    return shapes


def make_batch(cfg: ModelConfig, batch: int, seq: int, kind: str = "train",
               step: int = 0, seed: int = 0) -> Dict[str, np.ndarray]:
    """Materialize one deterministic batch matching ``token_batch_shapes``."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    out: Dict[str, np.ndarray] = {}
    for name, shape in token_batch_shapes(cfg, batch, seq, kind).items():
        if name == "embeddings":
            out[name] = rng.normal(0, 1, shape).astype(np.float32)
        else:
            out[name] = rng.integers(0, cfg.vocab_size, shape,
                                     dtype=np.int32)
    return out


class MarkovTokenSource:
    """Slightly-structured synthetic LM stream (order-1 Markov over a small
    alphabet embedded in the full vocab) so training losses actually go down
    in the end-to-end examples instead of sitting at log V."""

    def __init__(self, cfg: ModelConfig, seed: int = 0, alphabet: int = 256):
        self.cfg = cfg
        self.alphabet = min(alphabet, cfg.vocab_size)
        rng = np.random.default_rng(seed)
        logits = rng.normal(0, 1.5, (self.alphabet, self.alphabet))
        p = np.exp(logits - logits.max(1, keepdims=True))
        self.trans = p / p.sum(1, keepdims=True)

    def batch(self, batch: int, seq: int, step: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(step + 17)
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.alphabet, batch)
        u = rng.random((batch, seq))
        cum = np.cumsum(self.trans, axis=1)
        for t in range(seq):
            toks[:, t + 1] = (u[:, t, None]
                              < cum[toks[:, t]]).argmax(axis=1)
        if self.cfg.frontend == "audio":
            k = self.cfg.n_codebooks
            return {"tokens": np.repeat(toks[:, None, :-1], k, 1),
                    "labels": np.repeat(toks[:, None, 1:], k, 1)}
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
