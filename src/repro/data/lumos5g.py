"""Synthetic Lumos5G twin.

The real dataset [Narayanan et al., IMC 2020] is not available offline
(repro gate noted in DESIGN.md §2). This generator reproduces its published
schema and qualitative structure: ~70k timestamped samples collected while
walking/driving a 1300 m loop in downtown Minneapolis, 11 features
(longitude, latitude, moving speed, compass direction, and six LTE/NR signal
strength measurements), and a perceived mmWave throughput target that
correlates with position on the loop (beam coverage zones), mobility, and
radio measurements, with abrupt blockage events — the variability that
motivates the paper's adaptive encoding.

Throughput is discretized into ``n_classes`` balanced classes (the paper's
decoder "provides a classification for 20 timesteps").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

LOOP_METERS = 1300.0
N_FEATURES = 11
SAMPLE_SECONDS = 1.0     # dataset sampling interval (~1 Hz in Lumos5G)


@dataclass
class Lumos5GConfig:
    n_samples: int = 70_000
    seq_len: int = 20
    n_classes: int = 3
    seed: int = 0
    test_frac: float = 0.10      # paper Sec. VI: 10% test split


def _smooth_field(n_knots: int, length: int, rng, amp: float = 1.0):
    """Periodic smooth random field over the loop (beam coverage zones)."""
    knots = rng.normal(0, amp, n_knots)
    xs = np.linspace(0, 1, length, endpoint=False)
    field = np.zeros(length)
    for k, a in enumerate(knots):
        field += a * np.cos(2 * np.pi * (k + 1) * xs + rng.uniform(0, 2 * np.pi))
    return field / np.sqrt(n_knots)


def generate(cfg: Optional[Lumos5GConfig] = None) -> Dict[str, np.ndarray]:
    """Returns dict with x [N,T,11] float32, y [N,T] int32 class labels,
    tput [N,T] float32 raw Mbps."""
    # NOTE: the default must be constructed per call — a dataclass instance
    # in the signature would be shared and mutable across all callers.
    cfg = cfg if cfg is not None else Lumos5GConfig()
    rng = np.random.default_rng(cfg.seed)
    total_ticks = cfg.n_samples + cfg.seq_len + 1

    # --- trajectory along the loop (1 m/s avg walk with speed variation) ---
    speed = np.clip(1.4 + 0.6 * _smooth_field(8, total_ticks, rng)
                    + 0.2 * rng.normal(0, 1, total_ticks), 0.0, 4.0)
    pos = np.cumsum(speed) % LOOP_METERS
    frac = pos / LOOP_METERS
    # Minneapolis-ish loop coordinates (rectangle-ish loop)
    theta = 2 * np.pi * frac
    lon = -93.273 + 0.0018 * np.cos(theta) + 1e-5 * rng.normal(0, 1, total_ticks)
    lat = 44.977 + 0.0012 * np.sin(theta) + 1e-5 * rng.normal(0, 1, total_ticks)
    compass = (np.degrees(theta) + 90.0) % 360.0

    # --- radio environment: spatial beam field + LoS/NLoS blockage chain ---
    beam = _smooth_field(12, 4096, rng, amp=1.2)       # field over loop bins
    beam_at = beam[(frac * 4096).astype(int) % 4096]
    blocked = np.zeros(total_ticks, bool)
    b = False
    for t in range(total_ticks):
        b = (rng.random() < 0.25) if b else (rng.random() < 0.02)
        blocked[t] = b
    nr_rsrp = -85 + 12 * beam_at - 25 * blocked + rng.normal(0, 2, total_ticks)
    nr_rsrq = -10 + 3 * beam_at - 6 * blocked + rng.normal(0, 1, total_ticks)
    nr_snr = 18 + 8 * beam_at - 18 * blocked + rng.normal(0, 1.5, total_ticks)
    lte_rsrp = -95 + 4 * _smooth_field(6, total_ticks, rng) \
        + rng.normal(0, 2, total_ticks)
    lte_rsrq = -11 + 1.5 * _smooth_field(6, total_ticks, rng) \
        + rng.normal(0, 1, total_ticks)
    lte_snr = 12 + 4 * _smooth_field(6, total_ticks, rng) \
        + rng.normal(0, 1.5, total_ticks)

    # --- perceived throughput (Mbps): beam-dependent, mobility-penalized ---
    tput = np.clip(
        900 + 550 * beam_at - 820 * blocked - 60 * (speed - 1.4)
        + 12 * (nr_snr - 18) + 80 * rng.normal(0, 1, total_ticks),
        1.0, 2200.0)
    # AR(1) smoothing (TCP ramp dynamics)
    for t in range(1, total_ticks):
        tput[t] = 0.7 * tput[t - 1] + 0.3 * tput[t]

    feats = np.stack([lon, lat, speed, compass, lte_rsrp, lte_rsrq, lte_snr,
                      nr_rsrp, nr_rsrq, nr_snr,
                      blocked.astype(float)], axis=1)   # 11 features
    # normalize features
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-9)

    # class labels by global terciles (balanced classes)
    edges = np.quantile(tput, np.linspace(0, 1, cfg.n_classes + 1)[1:-1])
    labels = np.digitize(tput, edges).astype(np.int32)

    # sliding windows
    idx = np.arange(cfg.n_samples)[:, None] + np.arange(cfg.seq_len)[None, :]
    return {
        "x": feats[idx].astype(np.float32),            # [N,T,11]
        "y": labels[idx],                              # [N,T]
        "tput": tput[idx].astype(np.float32),
    }


def throughput_series_mbps(n_seconds: int, seed: int = 0) -> np.ndarray:
    """Raw perceived-throughput walk [n_seconds] in Mbps at ~1 Hz.

    This is the un-windowed time series behind ``generate()["tput"]`` —
    the channel-facing view of the dataset (signal features dropped).
    """
    if n_seconds < 1:
        raise ValueError("n_seconds must be >= 1")
    data = generate(Lumos5GConfig(n_samples=n_seconds, seq_len=1, seed=seed))
    return data["tput"][:, 0].astype(np.float64)


def capacity_traces_bps(n_ues: int, n_ticks: int, *,
                        tick_seconds: float = 0.1,
                        seed: int = 0,
                        stagger_seconds: float = 30.0) -> np.ndarray:
    """Per-UE link-capacity traces [n_ues, n_ticks] in **bytes/second**,
    resampled from the 1 Hz Lumos5G throughput walk to channel ticks.

    Each UE replays a window of one long walk of the loop, offset by a
    random start time (UEs traverse the same city at different times), so
    one O(seconds) generation pass serves an arbitrarily large fleet.
    Linear interpolation bridges the 1 Hz samples down to ``tick_seconds``;
    Mbps converts to bytes/s via *1e6/8.
    """
    if n_ues < 1 or n_ticks < 1:
        raise ValueError("n_ues and n_ticks must be >= 1")
    if tick_seconds <= 0:
        raise ValueError("tick_seconds must be > 0")
    span_s = n_ticks * tick_seconds
    need = int(np.ceil(span_s / SAMPLE_SECONDS)) + 2
    total = max(2 * need, int(np.ceil(stagger_seconds / SAMPLE_SECONDS))
                * min(n_ues, 128) + need)
    series = throughput_series_mbps(total, seed=seed)
    rng = np.random.default_rng(seed + 1)
    offsets = rng.uniform(0.0, (total - need) * SAMPLE_SECONDS, size=n_ues)
    # shared source series: one flattened interp covers the whole fleet
    t = offsets[:, None] + np.arange(n_ticks) * tick_seconds       # seconds
    sample_t = np.arange(total) * SAMPLE_SECONDS
    mbps = np.interp(t.ravel(), sample_t, series).reshape(n_ues, n_ticks)
    return mbps * 1e6 / 8.0


def train_test_split(data: Dict[str, np.ndarray], cfg: Lumos5GConfig):
    n = data["x"].shape[0]
    n_test = int(n * cfg.test_frac)
    rng = np.random.default_rng(cfg.seed + 1)
    perm = rng.permutation(n)
    te, tr = perm[:n_test], perm[n_test:]
    split = lambda ix: {k: v[ix] for k, v in data.items()}
    return split(tr), split(te)


def batch_iterator(data: Dict[str, np.ndarray], batch_size: int,
                   seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = data["x"].shape[0]
    while True:
        ix = rng.choice(n, batch_size, replace=False)
        yield {"x": data["x"][ix], "y": data["y"][ix]}
