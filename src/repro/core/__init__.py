"""The paper's primary contribution: dynamic split-learning encoding/decoding
with IB-guided multi-mode bottlenecks (Algorithm 1 cascade + orchestrator)."""
from repro.core import (bottleneck, cascade, channel, ib, orchestrator,
                        pipeline, quant, split)

__all__ = ["bottleneck", "cascade", "channel", "ib", "orchestrator",
           "pipeline", "quant", "split"]
