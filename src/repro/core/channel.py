"""UE <-> edge link simulation.

The paper's orchestrator reacts to time-varying network conditions; this
module provides (i) a Gauss-Markov (AR(1)) capacity trace calibrated to
mmWave-like variability, (ii) a two-state (LoS/NLoS) Markov blockage overlay
— mmWave beams are highly directional and blockage-prone (paper Sec. V) —
and (iii) byte/latency accounting for latent-code transfers.

Deterministic given a seed: tests and the orchestrator bench replay traces.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

#: Default request/response round-trip added to every boundary transfer.
#: ``Orchestrator.choose_modes`` and ``tx_seconds`` must use the same value
#: or the vectorized and scalar feasibility paths would disagree.
RTT_SECONDS = 0.004


@dataclass
class ChannelConfig:
    mean_mbps: float = 800.0       # mmWave-grade uplink
    std_mbps: float = 350.0
    corr: float = 0.95             # AR(1) coefficient per tick
    blockage_prob: float = 0.03    # P(LoS -> NLoS) per tick
    recovery_prob: float = 0.25    # P(NLoS -> LoS) per tick
    nlos_factor: float = 0.08      # capacity multiplier when blocked
    min_mbps: float = 5.0
    tick_seconds: float = 0.1
    seed: int = 0


class Channel:
    """Stateful simulated link; ``step()`` advances one tick and returns the
    current capacity in bytes/second.

    ``cfg`` defaults to a *fresh* ``ChannelConfig`` per instance — a shared
    default-argument instance would alias the (mutable) config across every
    default-constructed channel.
    """

    def __init__(self, cfg: Optional[ChannelConfig] = None):
        self.cfg = cfg if cfg is not None else ChannelConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self._x = 0.0              # AR(1) state (zero-mean)
        self.blocked = False
        self.t = 0.0

    def step(self) -> float:
        """Advance the live channel state by ONE tick (AR(1) fade + blockage
        Markov chain) and return the new capacity in bytes/second. Every call
        mutates ``self`` — replaying a tick is not possible; reconstruct the
        channel from the same config/seed instead."""
        c = self.cfg
        self._x = c.corr * self._x + np.sqrt(1 - c.corr ** 2) * \
            self.rng.normal(0.0, c.std_mbps)
        if self.blocked:
            if self.rng.random() < c.recovery_prob:
                self.blocked = False
        else:
            if self.rng.random() < c.blockage_prob:
                self.blocked = True
        mbps = max(c.mean_mbps + self._x, c.min_mbps)
        if self.blocked:
            mbps = max(mbps * c.nlos_factor, c.min_mbps)
        self.t += c.tick_seconds
        return mbps * 1e6 / 8.0    # bytes/s

    def trace(self, n_ticks: int) -> np.ndarray:
        """Capacities (bytes/s) for the next ``n_ticks`` ticks.

        This ADVANCES the live channel state (it calls :meth:`step`
        ``n_ticks`` times): after ``trace(n)`` the channel sits ``n`` ticks
        later, and interleaving ``trace`` with ``step`` continues the same
        realization. For a side-effect-free preview, build a second
        ``Channel`` from the same config (same seed) and trace that."""
        return np.array([self.step() for _ in range(n_ticks)])


class TraceChannel(Channel):
    """A link that replays a prescribed capacity trace (bytes/s per tick).

    Deterministic by construction — both sides of an A/B policy comparison
    (e.g. adaptive vs admission-frozen mode selection in
    ``benchmarks/bench_serving.py``) see the *identical* capacity sequence.
    After the trace is exhausted, ``step`` holds the last value, or cycles
    from the start when ``cycle=True``.
    """

    def __init__(self, capacities_bps: Sequence[float], *,
                 cycle: bool = False, cfg: Optional[ChannelConfig] = None):
        super().__init__(cfg)
        self.capacities = np.asarray(capacities_bps, np.float64)
        if self.capacities.size == 0:
            raise ValueError("TraceChannel needs a non-empty trace")
        self.cycle = cycle
        self._i = 0

    def step(self) -> float:
        """Advance the live replay cursor one tick and return that tick's
        scripted capacity in bytes/second (mutates ``self`` like
        ``Channel.step``)."""
        n = self.capacities.size
        i = self._i % n if self.cycle else min(self._i, n - 1)
        self._i += 1
        self.t += self.cfg.tick_seconds
        return float(self.capacities[i])


class MobilityChannel(Channel):
    """A UE that moves *between cells* while its session is live.

    ``cells`` scripts which physical cell the UE sits in at each channel
    tick (hold-last after the script ends, or cycle); ``cell_caps_bps``
    gives each cell's uplink capacity when the UE is served *by that cell's
    edge replica*. The serving side is explicit: :class:`EdgeCluster` (or
    any caller) sets :attr:`serving_cell` at admission and again when a
    migration lands. Whenever the UE's physical cell differs from its
    serving cell — it crossed a cell boundary but its session still lives
    on the old edge server — the returned capacity is multiplied by
    ``detach_factor`` (inter-cell backhaul detour / degraded beam), which
    is exactly the "stay-and-degrade" cost a handover policy weighs against
    migrating the decode state.

    Crossings are *events*: ``step()`` records each boundary crossing in
    ``handover_ticks`` and leaves the new cell id in ``pending_handover``
    until the serving side acknowledges it (``ack_handover``). Handover
    latency is measured in channel ticks: crossing tick -> the tick at
    which ``serving_cell`` matches the physical cell again
    (``handover_latencies``).

    Deterministic by construction, like :class:`TraceChannel` — both sides
    of a migrate-vs-stay A/B replay the identical cell-crossing script.
    """

    def __init__(self, cells: Sequence[int], cell_caps_bps: Sequence[float],
                 *, detach_factor: float = 0.05, cycle: bool = False,
                 cfg: Optional[ChannelConfig] = None):
        super().__init__(cfg)
        self.cells = np.asarray(cells, np.int64)
        if self.cells.size == 0:
            raise ValueError("MobilityChannel needs a non-empty cell script")
        self.cell_caps = np.asarray(cell_caps_bps, np.float64)
        if int(self.cells.max()) >= self.cell_caps.size:
            raise ValueError("cell script references a cell with no capacity")
        self.detach_factor = float(detach_factor)
        self.cycle = cycle
        self._i = 0
        self.serving_cell: Optional[int] = None
        self.pending_handover: Optional[int] = None
        self.handover_ticks: list = []       # channel tick of each crossing
        self.handover_latencies: list = []   # ticks from crossing to re-home
        self._crossed_at: Optional[int] = None

    def _cell_at(self, i: int) -> int:
        n = self.cells.size
        return int(self.cells[i % n if self.cycle else min(i, n - 1)])

    @property
    def current_cell(self) -> int:
        """The UE's physical cell at the *next* tick (no state advance) —
        what a placement policy should route against."""
        return self._cell_at(self._i)

    @property
    def last_cell(self) -> int:
        """The physical cell of the most recently *stepped* tick (falls
        back to the script's first cell before any step)."""
        return self._cell_at(max(self._i - 1, 0))

    @property
    def detached(self) -> bool:
        """True when the UE has started transmitting and its last-stepped
        physical cell differs from its serving cell — it is paying
        ``detach_factor`` regardless of whether a crossing *event* is
        still pending (a session placed off-cell at admission is detached
        without ever having crossed)."""
        return (self._i > 0 and self.serving_cell is not None
                and self.last_cell != self.serving_cell)

    def ack_handover(self, serving_cell: int):
        """The serving side re-homed this session (migration landed, or a
        drop-and-replay re-admitted it). Clears the pending event and logs
        the handover latency if the new home matches the physical cell."""
        self.serving_cell = serving_cell
        self.pending_handover = None
        if self._crossed_at is not None and serving_cell == self.last_cell:
            self.handover_latencies.append(self._i - self._crossed_at)
            self._crossed_at = None

    def step(self) -> float:
        """Advance one tick: move the UE along its cell script, flag a
        boundary crossing, and return the capacity the *current serving
        arrangement* delivers (mutates ``self`` like ``Channel.step``)."""
        prev = self._cell_at(max(self._i - 1, 0)) if self._i else None
        cell = self._cell_at(self._i)
        if self.serving_cell is None:        # un-homed: assume co-located
            self.serving_cell = cell
        if prev is not None and cell != prev:
            self.pending_handover = cell
            self.handover_ticks.append(self._i)
            if self._crossed_at is None:
                self._crossed_at = self._i
        self._i += 1
        self.t += self.cfg.tick_seconds
        cap = float(self.cell_caps[cell])
        if cell != self.serving_cell:
            cap = max(cap * self.detach_factor, 1.0)
        return cap


def channel_fleet(n: int, cfg: Optional[ChannelConfig] = None, *,
                  seed: int = 0, mean_spread: float = 0.5) -> list:
    """``n`` independent per-user links for continuous-batching serving.

    Each user gets their own AR(1)/blockage process (distinct sub-seed) and a
    mean uplink drawn log-uniformly within ``[1-mean_spread, 1+mean_spread]``
    of the base config — cell-edge users coexist with beam-center users, so
    a mixed decode batch genuinely wants mixed bottleneck modes.

    Every fleet member owns a *distinct* ``ChannelConfig``
    (``dataclasses.replace`` of the base), and the caller's ``cfg`` is never
    mutated — mutating one member's config cannot leak into another member
    or into later fleets built from the same base.
    """
    base = cfg if cfg is not None else ChannelConfig()
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        scale = float(np.exp(rng.uniform(np.log(max(1 - mean_spread, 0.05)),
                                         np.log(1 + mean_spread))))
        out.append(Channel(dataclasses.replace(
            base,
            mean_mbps=base.mean_mbps * scale,
            std_mbps=base.std_mbps * scale,
            # scale the capacity floor down with the mean, else the floor
            # clamps every cell-edge user to the same capacity
            min_mbps=base.min_mbps * min(scale, 1.0),
            seed=seed * 1_000_003 + i + 1)))
    return out


def tx_seconds(payload_bytes: int, capacity_bps: float,
               rtt_seconds: float = RTT_SECONDS) -> float:
    """Transfer latency for one boundary payload."""
    return payload_bytes / max(capacity_bps, 1.0) + rtt_seconds
