"""UE <-> edge link simulation.

The paper's orchestrator reacts to time-varying network conditions; this
module provides (i) a Gauss-Markov (AR(1)) capacity trace calibrated to
mmWave-like variability, (ii) a two-state (LoS/NLoS) Markov blockage overlay
— mmWave beams are highly directional and blockage-prone (paper Sec. V) —
and (iii) byte/latency accounting for latent-code transfers.

Deterministic given a seed: tests and the orchestrator bench replay traces.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

#: Default request/response round-trip added to every boundary transfer.
#: ``Orchestrator.choose_modes`` and ``tx_seconds`` must use the same value
#: or the vectorized and scalar feasibility paths would disagree.
RTT_SECONDS = 0.004


@dataclass
class ChannelConfig:
    mean_mbps: float = 800.0       # mmWave-grade uplink
    std_mbps: float = 350.0
    corr: float = 0.95             # AR(1) coefficient per tick
    blockage_prob: float = 0.03    # P(LoS -> NLoS) per tick
    recovery_prob: float = 0.25    # P(NLoS -> LoS) per tick
    nlos_factor: float = 0.08      # capacity multiplier when blocked
    min_mbps: float = 5.0
    tick_seconds: float = 0.1
    seed: int = 0


class Channel:
    """Stateful simulated link; ``step()`` advances one tick and returns the
    current capacity in bytes/second.

    ``cfg`` defaults to a *fresh* ``ChannelConfig`` per instance — a shared
    default-argument instance would alias the (mutable) config across every
    default-constructed channel.
    """

    def __init__(self, cfg: Optional[ChannelConfig] = None):
        self.cfg = cfg if cfg is not None else ChannelConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self._x = 0.0              # AR(1) state (zero-mean)
        self.blocked = False
        self.t = 0.0

    def step(self) -> float:
        """Advance the live channel state by ONE tick (AR(1) fade + blockage
        Markov chain) and return the new capacity in bytes/second. Every call
        mutates ``self`` — replaying a tick is not possible; reconstruct the
        channel from the same config/seed instead."""
        c = self.cfg
        self._x = c.corr * self._x + np.sqrt(1 - c.corr ** 2) * \
            self.rng.normal(0.0, c.std_mbps)
        if self.blocked:
            if self.rng.random() < c.recovery_prob:
                self.blocked = False
        else:
            if self.rng.random() < c.blockage_prob:
                self.blocked = True
        mbps = max(c.mean_mbps + self._x, c.min_mbps)
        if self.blocked:
            mbps = max(mbps * c.nlos_factor, c.min_mbps)
        self.t += c.tick_seconds
        return mbps * 1e6 / 8.0    # bytes/s

    def trace(self, n_ticks: int) -> np.ndarray:
        """Capacities (bytes/s) for the next ``n_ticks`` ticks.

        This ADVANCES the live channel state (it calls :meth:`step`
        ``n_ticks`` times): after ``trace(n)`` the channel sits ``n`` ticks
        later, and interleaving ``trace`` with ``step`` continues the same
        realization. For a side-effect-free preview, build a second
        ``Channel`` from the same config (same seed) and trace that."""
        return np.array([self.step() for _ in range(n_ticks)])


class TraceChannel(Channel):
    """A link that replays a prescribed capacity trace (bytes/s per tick).

    Deterministic by construction — both sides of an A/B policy comparison
    (e.g. adaptive vs admission-frozen mode selection in
    ``benchmarks/bench_serving.py``) see the *identical* capacity sequence.
    After the trace is exhausted, ``step`` holds the last value, or cycles
    from the start when ``cycle=True``.
    """

    def __init__(self, capacities_bps: Sequence[float], *,
                 cycle: bool = False, cfg: Optional[ChannelConfig] = None):
        super().__init__(cfg)
        self.capacities = np.asarray(capacities_bps, np.float64)
        if self.capacities.size == 0:
            raise ValueError("TraceChannel needs a non-empty trace")
        self.cycle = cycle
        self._i = 0

    def step(self) -> float:
        """Advance the live replay cursor one tick and return that tick's
        scripted capacity in bytes/second (mutates ``self`` like
        ``Channel.step``)."""
        n = self.capacities.size
        i = self._i % n if self.cycle else min(self._i, n - 1)
        self._i += 1
        self.t += self.cfg.tick_seconds
        return float(self.capacities[i])


def channel_fleet(n: int, cfg: Optional[ChannelConfig] = None, *,
                  seed: int = 0, mean_spread: float = 0.5) -> list:
    """``n`` independent per-user links for continuous-batching serving.

    Each user gets their own AR(1)/blockage process (distinct sub-seed) and a
    mean uplink drawn log-uniformly within ``[1-mean_spread, 1+mean_spread]``
    of the base config — cell-edge users coexist with beam-center users, so
    a mixed decode batch genuinely wants mixed bottleneck modes.

    Every fleet member owns a *distinct* ``ChannelConfig``
    (``dataclasses.replace`` of the base), and the caller's ``cfg`` is never
    mutated — mutating one member's config cannot leak into another member
    or into later fleets built from the same base.
    """
    base = cfg if cfg is not None else ChannelConfig()
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        scale = float(np.exp(rng.uniform(np.log(max(1 - mean_spread, 0.05)),
                                         np.log(1 + mean_spread))))
        out.append(Channel(dataclasses.replace(
            base,
            mean_mbps=base.mean_mbps * scale,
            std_mbps=base.std_mbps * scale,
            # scale the capacity floor down with the mean, else the floor
            # clamps every cell-edge user to the same capacity
            min_mbps=base.min_mbps * min(scale, 1.0),
            seed=seed * 1_000_003 + i + 1)))
    return out


def tx_seconds(payload_bytes: int, capacity_bps: float,
               rtt_seconds: float = RTT_SECONDS) -> float:
    """Transfer latency for one boundary payload."""
    return payload_bytes / max(capacity_bps, 1.0) + rtt_seconds
