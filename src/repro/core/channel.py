"""UE <-> edge link simulation.

The paper's orchestrator reacts to time-varying network conditions; this
module provides (i) a Gauss-Markov (AR(1)) capacity trace calibrated to
mmWave-like variability, (ii) a two-state (LoS/NLoS) Markov blockage overlay
— mmWave beams are highly directional and blockage-prone (paper Sec. V) —
and (iii) byte/latency accounting for latent-code transfers.

Deterministic given a seed: tests and the orchestrator bench replay traces.

Randomness is *counter-based*: every draw is a pure hash of
``(per-link key, tick, draw site)`` (splitmix64 finalizer, Box-Muller for
normals), so the scalar :class:`Channel` and the array-form
:class:`FleetChannel` evaluate the SAME function and their realizations are
bit-identical — the scalar classes stay the oracle for the vectorized fleet
(``tests/test_fleet_channel.py`` pins this), and a link's stream depends
only on its own key, never on fleet size or stepping order.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

#: Default request/response round-trip added to every boundary transfer.
#: ``Orchestrator.choose_modes`` and ``tx_seconds`` must use the same value
#: or the vectorized and scalar feasibility paths would disagree.
RTT_SECONDS = 0.004


# -- counter-based RNG primitives ---------------------------------------------
# Draws are pure functions of (key, tick, salt): uint64 mixing constants from
# splitmix64 [Steele et al. 2014]. Vectorized over numpy uint64 arrays (which
# wrap silently on overflow — exactly the arithmetic we want); scalar callers
# go through 0-d arrays so no overflow warnings fire.

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
#: draw-site salts — each (key, tick) supports several independent draws
_SALT_FADE_A = np.uint64(0xA5A5A5A5A5A5A5A5)   # Box-Muller radius uniform
_SALT_FADE_B = np.uint64(0x5A5A5A5A5A5A5A5A)   # Box-Muller angle uniform
_SALT_BLOCK = np.uint64(0xC3C3C3C3C3C3C3C3)    # blockage Markov uniform
_U53 = 1.0 / float(1 << 53)


def _finalize(x: np.ndarray) -> np.ndarray:
    """splitmix64 output mixer (bijective on uint64)."""
    x = x ^ (x >> np.uint64(30))
    x = x * _MIX1
    x = x ^ (x >> np.uint64(27))
    x = x * _MIX2
    return x ^ (x >> np.uint64(31))


def _counter_hash(keys, ticks, salt: np.uint64) -> np.ndarray:
    """uint64 hash of ``(key, tick, draw site)`` — the one RNG primitive
    both the scalar and the fleet channel draw through (broadcasts).
    Everything runs as (at least 1-d) uint64 ARRAYS: array ops wrap
    silently on overflow, which is the modular arithmetic we want (scalar
    numpy ops would emit overflow warnings)."""
    k = np.atleast_1d(np.asarray(keys, np.uint64))
    t = np.atleast_1d(np.asarray(ticks, np.uint64))
    return _finalize(_finalize((k * _MIX2) ^ salt) + t * _GAMMA)


def _u01(keys, ticks, salt: np.uint64) -> np.ndarray:
    """Uniform [0, 1) float64 draws (53 mantissa bits of the hash)."""
    return (_counter_hash(keys, ticks, salt) >> np.uint64(11)).astype(
        np.float64) * _U53


def _std_normal(keys, ticks) -> np.ndarray:
    """Standard-normal draws via Box-Muller over two salted uniforms."""
    u1 = _u01(keys, ticks, _SALT_FADE_A)
    u2 = _u01(keys, ticks, _SALT_FADE_B)
    # 1 - u1 in (0, 1] keeps the log finite; u1 == 0 maps to z == 0
    return np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)


def _key_of(seed: int) -> np.uint64:
    return np.uint64(int(seed) & 0xFFFFFFFFFFFFFFFF)


@dataclass
class ChannelConfig:
    mean_mbps: float = 800.0       # mmWave-grade uplink
    std_mbps: float = 350.0
    corr: float = 0.95             # AR(1) coefficient per tick
    blockage_prob: float = 0.03    # P(LoS -> NLoS) per tick
    recovery_prob: float = 0.25    # P(NLoS -> LoS) per tick
    nlos_factor: float = 0.08      # capacity multiplier when blocked
    min_mbps: float = 5.0
    tick_seconds: float = 0.1
    seed: int = 0


class Channel:
    """Stateful simulated link; ``step()`` advances one tick and returns the
    current capacity in bytes/second.

    ``cfg`` defaults to a *fresh* ``ChannelConfig`` per instance — a shared
    default-argument instance would alias the (mutable) config across every
    default-constructed channel.

    Draws are counter-based (see module docstring): tick ``t``'s innovation
    and blockage uniforms are pure hashes of ``(seed, t)``, so N scalar
    channels and one :class:`FleetChannel` over the same seeds realize
    bit-identical capacity sequences.
    """

    #: duck-typed mobility marker (see :func:`is_mobile`)
    mobile = False

    def __init__(self, cfg: Optional[ChannelConfig] = None):
        self.cfg = cfg if cfg is not None else ChannelConfig()
        self._key = _key_of(self.cfg.seed)
        self._tick = 0             # counter-RNG tick index
        self._x = 0.0              # AR(1) state (zero-mean)
        self.blocked = False
        self.t = 0.0

    def step(self) -> float:
        """Advance the live channel state by ONE tick (AR(1) fade + blockage
        Markov chain) and return the new capacity in bytes/second. Every call
        mutates ``self`` — replaying a tick is not possible; reconstruct the
        channel from the same config/seed instead."""
        c = self.cfg
        z = float(_std_normal(self._key, self._tick)[0])
        u = float(_u01(self._key, self._tick, _SALT_BLOCK)[0])
        self._tick += 1
        self._x = c.corr * self._x + \
            np.sqrt(1 - c.corr ** 2) * c.std_mbps * z
        if self.blocked:
            if u < c.recovery_prob:
                self.blocked = False
        else:
            if u < c.blockage_prob:
                self.blocked = True
        mbps = max(c.mean_mbps + self._x, c.min_mbps)
        if self.blocked:
            mbps = max(mbps * c.nlos_factor, c.min_mbps)
        self.t += c.tick_seconds
        return mbps * 1e6 / 8.0    # bytes/s

    def trace(self, n_ticks: int) -> np.ndarray:
        """Capacities (bytes/s) for the next ``n_ticks`` ticks.

        This ADVANCES the live channel state (it calls :meth:`step`
        ``n_ticks`` times): after ``trace(n)`` the channel sits ``n`` ticks
        later, and interleaving ``trace`` with ``step`` continues the same
        realization. For a side-effect-free preview, build a second
        ``Channel`` from the same config (same seed) and trace that."""
        return np.array([self.step() for _ in range(n_ticks)])


class TraceChannel(Channel):
    """A link that replays a prescribed capacity trace (bytes/s per tick).

    Deterministic by construction — both sides of an A/B policy comparison
    (e.g. adaptive vs admission-frozen mode selection in
    ``benchmarks/bench_serving.py``) see the *identical* capacity sequence.
    After the trace is exhausted, ``step`` holds the last value, or cycles
    from the start when ``cycle=True``.
    """

    def __init__(self, capacities_bps: Sequence[float], *,
                 cycle: bool = False, cfg: Optional[ChannelConfig] = None):
        super().__init__(cfg)
        self.capacities = np.asarray(capacities_bps, np.float64)
        if self.capacities.size == 0:
            raise ValueError("TraceChannel needs a non-empty trace")
        self.cycle = cycle
        self._i = 0

    def step(self) -> float:
        """Advance the live replay cursor one tick and return that tick's
        scripted capacity in bytes/second (mutates ``self`` like
        ``Channel.step``)."""
        n = self.capacities.size
        i = self._i % n if self.cycle else min(self._i, n - 1)
        self._i += 1
        self.t += self.cfg.tick_seconds
        return float(self.capacities[i])


class MobilityChannel(Channel):
    """A UE that moves *between cells* while its session is live.

    ``cells`` scripts which physical cell the UE sits in at each channel
    tick (hold-last after the script ends, or cycle); ``cell_caps_bps``
    gives each cell's uplink capacity when the UE is served *by that cell's
    edge replica*. The serving side is explicit: :class:`EdgeCluster` (or
    any caller) sets :attr:`serving_cell` at admission and again when a
    migration lands. Whenever the UE's physical cell differs from its
    serving cell — it crossed a cell boundary but its session still lives
    on the old edge server — the returned capacity is multiplied by
    ``detach_factor`` (inter-cell backhaul detour / degraded beam), which
    is exactly the "stay-and-degrade" cost a handover policy weighs against
    migrating the decode state.

    Crossings are *events*: ``step()`` records each boundary crossing in
    ``handover_ticks`` and leaves the new cell id in ``pending_handover``
    until the serving side acknowledges it (``ack_handover``). Handover
    latency is measured in channel ticks: crossing tick -> the tick at
    which ``serving_cell`` matches the physical cell again
    (``handover_latencies``).

    Deterministic by construction, like :class:`TraceChannel` — both sides
    of a migrate-vs-stay A/B replay the identical cell-crossing script.
    """

    mobile = True

    def __init__(self, cells: Sequence[int], cell_caps_bps: Sequence[float],
                 *, detach_factor: float = 0.05, cycle: bool = False,
                 cfg: Optional[ChannelConfig] = None):
        super().__init__(cfg)
        self.cells = np.asarray(cells, np.int64)
        if self.cells.size == 0:
            raise ValueError("MobilityChannel needs a non-empty cell script")
        self.cell_caps = np.asarray(cell_caps_bps, np.float64)
        if int(self.cells.max()) >= self.cell_caps.size:
            raise ValueError("cell script references a cell with no capacity")
        self.detach_factor = float(detach_factor)
        self.cycle = cycle
        self._i = 0
        self.serving_cell: Optional[int] = None
        self.pending_handover: Optional[int] = None
        self.handover_ticks: list = []       # channel tick of each crossing
        self.handover_latencies: list = []   # ticks from crossing to re-home
        self._crossed_at: Optional[int] = None

    def _cell_at(self, i: int) -> int:
        n = self.cells.size
        return int(self.cells[i % n if self.cycle else min(i, n - 1)])

    @property
    def current_cell(self) -> int:
        """The UE's physical cell at the *next* tick (no state advance) —
        what a placement policy should route against."""
        return self._cell_at(self._i)

    @property
    def last_cell(self) -> int:
        """The physical cell of the most recently *stepped* tick (falls
        back to the script's first cell before any step)."""
        return self._cell_at(max(self._i - 1, 0))

    @property
    def detached(self) -> bool:
        """True when the UE has started transmitting and its last-stepped
        physical cell differs from its serving cell — it is paying
        ``detach_factor`` regardless of whether a crossing *event* is
        still pending (a session placed off-cell at admission is detached
        without ever having crossed)."""
        return (self._i > 0 and self.serving_cell is not None
                and self.last_cell != self.serving_cell)

    def ack_handover(self, serving_cell: int):
        """The serving side re-homed this session (migration landed, or a
        drop-and-replay re-admitted it). Clears the pending event and logs
        the handover latency if the new home matches the physical cell."""
        self.serving_cell = serving_cell
        self.pending_handover = None
        if self._crossed_at is not None and serving_cell == self.last_cell:
            self.handover_latencies.append(self._i - self._crossed_at)
            self._crossed_at = None

    def step(self) -> float:
        """Advance one tick: move the UE along its cell script, flag a
        boundary crossing, and return the capacity the *current serving
        arrangement* delivers (mutates ``self`` like ``Channel.step``)."""
        prev = self._cell_at(max(self._i - 1, 0)) if self._i else None
        cell = self._cell_at(self._i)
        if self.serving_cell is None:        # un-homed: assume co-located
            self.serving_cell = cell
        if prev is not None and cell != prev:
            self.pending_handover = cell
            self.handover_ticks.append(self._i)
            if self._crossed_at is None:
                self._crossed_at = self._i
        self._i += 1
        self.t += self.cfg.tick_seconds
        cap = float(self.cell_caps[cell])
        if cell != self.serving_cell:
            cap = max(cap * self.detach_factor, 1.0)
        return cap


def channel_fleet(n: int, cfg: Optional[ChannelConfig] = None, *,
                  seed: int = 0, mean_spread: float = 0.5) -> list:
    """``n`` independent per-user links for continuous-batching serving.

    Each user gets their own AR(1)/blockage process (distinct sub-seed) and a
    mean uplink drawn log-uniformly within ``[1-mean_spread, 1+mean_spread]``
    of the base config — cell-edge users coexist with beam-center users, so
    a mixed decode batch genuinely wants mixed bottleneck modes.

    Every fleet member owns a *distinct* ``ChannelConfig``
    (``dataclasses.replace`` of the base), and the caller's ``cfg`` is never
    mutated — mutating one member's config cannot leak into another member
    or into later fleets built from the same base.
    """
    base = cfg if cfg is not None else ChannelConfig()
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        scale = float(np.exp(rng.uniform(np.log(max(1 - mean_spread, 0.05)),
                                         np.log(1 + mean_spread))))
        out.append(Channel(dataclasses.replace(
            base,
            mean_mbps=base.mean_mbps * scale,
            std_mbps=base.std_mbps * scale,
            # scale the capacity floor down with the mean, else the floor
            # clamps every cell-edge user to the same capacity
            min_mbps=base.min_mbps * min(scale, 1.0),
            seed=seed * 1_000_003 + i + 1)))
    return out


def is_mobile(ch) -> bool:
    """True when ``ch`` carries the mobility/handover surface (cell script,
    ``serving_cell``, ``pending_handover``, ``ack_handover``) — satisfied by
    :class:`MobilityChannel` AND by a :class:`FleetLane` over a fleet with a
    cell script. The cluster's handover loop dispatches on this instead of
    ``isinstance`` so vectorized fleets ride the same migration machinery."""
    return bool(getattr(ch, "mobile", False))


class FleetLane:
    """One UE's view into a :class:`FleetChannel`.

    Implements the scalar :class:`Channel` protocol (``step``) plus — when
    the fleet has a cell script — the full :class:`MobilityChannel`
    handover surface, WITHOUT owning any simulation state: every attribute
    reads/writes the fleet's arrays. Lanes are what a ``Request.channel``
    carries into the serving engine; the per-fleet capacity math stays
    vectorized underneath (see :meth:`FleetChannel._ensure`).
    """

    __slots__ = ("fleet", "i")

    def __init__(self, fleet: "FleetChannel", i: int):
        self.fleet = fleet
        self.i = int(i)

    # -- the Channel protocol -------------------------------------------------
    @property
    def cfg(self) -> ChannelConfig:
        return self.fleet.cfg

    @property
    def t(self) -> float:
        return float(self.fleet._i[self.i]) * self.fleet.cfg.tick_seconds

    def step(self) -> float:
        return self.fleet._step_lane(self.i)

    def peek(self) -> float:
        """Next tick's capacity under the current serving arrangement,
        WITHOUT advancing the lane — what SLO admission predicts against."""
        return self.fleet._peek_lane(self.i)

    def trace(self, n_ticks: int) -> np.ndarray:
        return np.array([self.step() for _ in range(n_ticks)])

    # -- the MobilityChannel surface (cell-scripted fleets only) --------------
    @property
    def mobile(self) -> bool:
        return self.fleet.cells is not None

    @property
    def cells(self) -> np.ndarray:
        return self.fleet.cells[self.i]

    @property
    def current_cell(self) -> int:
        return self.fleet._cell_at_lane(self.i, int(self.fleet._i[self.i]))

    @property
    def last_cell(self) -> int:
        return self.fleet._cell_at_lane(
            self.i, max(int(self.fleet._i[self.i]) - 1, 0))

    @property
    def serving_cell(self) -> Optional[int]:
        s = int(self.fleet.serving_cell[self.i])
        return None if s < 0 else s

    @serving_cell.setter
    def serving_cell(self, cell: Optional[int]):
        self.fleet.serving_cell[self.i] = -1 if cell is None else int(cell)

    @property
    def pending_handover(self) -> Optional[int]:
        p = int(self.fleet.pending_handover[self.i])
        return None if p < 0 else p

    @pending_handover.setter
    def pending_handover(self, cell: Optional[int]):
        self.fleet.pending_handover[self.i] = -1 if cell is None \
            else int(cell)

    @property
    def detached(self) -> bool:
        f, i = self.fleet, self.i
        return (int(f._i[i]) > 0 and int(f.serving_cell[i]) >= 0
                and self.last_cell != int(f.serving_cell[i]))

    @property
    def handover_ticks(self) -> list:
        return self.fleet.handover_ticks.setdefault(self.i, [])

    @property
    def handover_latencies(self) -> list:
        return self.fleet.handover_latencies.setdefault(self.i, [])

    def ack_handover(self, serving_cell: int):
        self.fleet.ack_handover(self.i, serving_cell)


class FleetChannel:
    """Array-form fleet of UE links: ONE vectorized numpy step advances
    capacity, cell membership, and detach state for every UE.

    The scalar classes are the ORACLE — a seeded fleet realizes
    bit-identical trajectories to ``n`` independent scalar channels
    (``tests/test_fleet_channel.py``), because both sides draw through the
    same counter-based RNG (pure hash of ``(per-UE key, tick)``) — but the
    fleet holds its state as ``[n]`` arrays and computes capacities in
    vectorized time chunks, so a 10k-UE city simulation costs a handful of
    numpy ops per tick instead of 10k Python object steps.

    Three capacity sources (mutually exclusive):

    fade (default)
        Per-UE AR(1)/blockage processes matching :func:`channel_fleet`
        exactly: same per-UE seeds (``seed * 1_000_003 + i + 1``), same
        log-uniform mean spread, same ``ChannelConfig`` dynamics.
    ``traces_bps`` ``[n, T]``
        Per-UE scripted replay (:class:`TraceChannel` semantics:
        hold-last, or ``cycle=True``) — e.g. Lumos5G real-trace capacities
        from :func:`repro.data.lumos5g.capacity_traces_bps`.
    ``cell_caps_bps`` with ``cells``
        Per-cell capacities (:class:`MobilityChannel` semantics).

    ``cells`` ``[n, T]`` adds mobility on top of ``traces_bps`` OR
    ``cell_caps_bps``: per-tick cell membership, crossing events,
    ``detach_factor`` throttling while a session is served off-cell, and
    the ``ack_handover`` latency bookkeeping the cluster's migration loop
    drives. ``traces_bps + cells`` is the city-replay mode (real-trace
    capacity, scripted cell crossings) that has no scalar equivalent.

    Lanes advance independently (each serving slot steps its own UE's
    channel), so the fleet keeps per-UE cursors; fade capacities are
    computed for ALL UEs in vectorized chunks up to the furthest cursor and
    memoized, which is what keeps per-lane ``step()`` O(1).
    """

    def __init__(self, n: int, cfg: Optional[ChannelConfig] = None, *,
                 seed: int = 0, mean_spread: float = 0.5,
                 traces_bps: Optional[np.ndarray] = None,
                 cells: Optional[np.ndarray] = None,
                 cell_caps_bps: Optional[Sequence[float]] = None,
                 detach_factor: float = 0.05, cycle: bool = False):
        if n < 1:
            raise ValueError("FleetChannel needs at least one UE")
        if traces_bps is not None and cell_caps_bps is not None:
            raise ValueError("traces_bps and cell_caps_bps are exclusive "
                             "capacity sources")
        if cell_caps_bps is not None and cells is None:
            raise ValueError("cell_caps_bps needs a cell script")
        self.n = int(n)
        self.cfg = cfg if cfg is not None else ChannelConfig()
        self.cycle = bool(cycle)
        self.detach_factor = float(detach_factor)
        self._i = np.zeros(self.n, np.int64)           # per-lane cursors

        self.traces = None
        if traces_bps is not None:
            self.traces = np.asarray(traces_bps, np.float64)
            if self.traces.ndim != 2 or self.traces.shape[0] != self.n:
                raise ValueError(
                    f"traces_bps must be [n={self.n}, T], got "
                    f"{self.traces.shape}")
            if self.traces.shape[1] == 0:
                raise ValueError("traces_bps needs a non-empty trace")

        self.cells = None
        self.cell_caps = None
        if cells is not None:
            self.cells = np.asarray(cells, np.int64)
            if self.cells.ndim != 2 or self.cells.shape[0] != self.n or \
                    self.cells.shape[1] == 0:
                raise ValueError(
                    f"cells must be a non-empty [n={self.n}, T] script, "
                    f"got {self.cells.shape}")
            if cell_caps_bps is not None:
                self.cell_caps = np.asarray(cell_caps_bps, np.float64)
                if int(self.cells.max()) >= self.cell_caps.size:
                    raise ValueError(
                        "cell script references a cell with no capacity")
            self.serving_cell = np.full(self.n, -1, np.int64)
            self.pending_handover = np.full(self.n, -1, np.int64)
            self._crossed_at = np.full(self.n, -1, np.int64)
            #: sparse per-UE event logs (only crossings allocate entries)
            self.handover_ticks: Dict[int, list] = {}
            self.handover_latencies: Dict[int, list] = {}

        if self.traces is None and self.cell_caps is None:
            # fade mode: replicate channel_fleet's per-member calibration
            # exactly (same numpy Generator draws — a size-n uniform equals
            # n sequential scalar uniforms), so fleet lane i is the same
            # link as channel_fleet(n, cfg, seed=seed)[i]
            base = self.cfg
            rng = np.random.default_rng(seed)
            scale = np.exp(rng.uniform(
                np.log(max(1 - mean_spread, 0.05)),
                np.log(1 + mean_spread), self.n))
            self._mean = base.mean_mbps * scale
            self._min = base.min_mbps * np.minimum(scale, 1.0)
            # the AR(1) innovation coefficient, associated exactly like the
            # scalar step: (sqrt(1-corr^2) * std) * z
            self._coef = np.sqrt(1 - base.corr ** 2) * (base.std_mbps
                                                        * scale)
            self.keys = np.array(
                [_key_of(seed * 1_000_003 + i + 1) for i in range(self.n)],
                np.uint64)
            self._x = np.zeros(self.n, np.float64)
            self.blocked = np.zeros(self.n, bool)
            self._frontier = 0                 # fade ticks computed so far
            self._cap = np.zeros((self.n, 0), np.float64)

        self._lanes: Dict[int, FleetLane] = {}

    # -- index math -----------------------------------------------------------
    def _script_idx(self, t, size: int):
        t = np.asarray(t, np.int64)
        return t % size if self.cycle else np.minimum(t, size - 1)

    def _cell_at(self, t) -> np.ndarray:
        """[k] physical cells at per-UE ticks ``t`` (full-fleet callers
        pass all rows; the script holds-last / cycles like the scalar)."""
        idx = self._script_idx(t, self.cells.shape[1])
        return self.cells[np.arange(len(idx)), idx]

    def _cell_at_lane(self, i: int, t: int) -> int:
        idx = int(self._script_idx(t, self.cells.shape[1]))
        return int(self.cells[i, idx])

    # -- fade-mode chunked computation ---------------------------------------
    def _ensure(self, tmax: int):
        """Materialize fade capacities for ticks ``[_frontier, tmax]`` for
        the WHOLE fleet in one vectorized time loop — per-lane reads then
        index the memo. The recurrence is the scalar ``Channel.step``
        verbatim, over ``[n]`` arrays."""
        if tmax < self._frontier:
            return
        if tmax >= self._cap.shape[1]:
            grow = max(tmax + 1, 2 * max(self._cap.shape[1], 16))
            cap = np.zeros((self.n, grow), np.float64)
            cap[:, :self._cap.shape[1]] = self._cap
            self._cap = cap
        c = self.cfg
        x, blocked = self._x, self.blocked
        for t in range(self._frontier, tmax + 1):
            z = _std_normal(self.keys, t)
            u = _u01(self.keys, t, _SALT_BLOCK)
            x = c.corr * x + self._coef * z
            blocked = np.where(blocked, u >= c.recovery_prob,
                               u < c.blockage_prob)
            mbps = np.maximum(self._mean + x, self._min)
            mbps = np.where(blocked,
                            np.maximum(mbps * c.nlos_factor, self._min),
                            mbps)
            self._cap[:, t] = mbps * 1e6 / 8.0
        self._x, self.blocked = x, blocked
        self._frontier = tmax + 1

    def _base_caps(self, idx: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Capacity (bytes/s) of UEs ``idx`` at their ticks ``t``, BEFORE
        any mobility detach throttling."""
        if self.traces is not None:
            return self.traces[idx, self._script_idx(t,
                                                     self.traces.shape[1])]
        if self.cell_caps is not None:
            ci = self._script_idx(t, self.cells.shape[1])
            return self.cell_caps[self.cells[idx, ci]]
        self._ensure(int(t.max()))
        return self._cap[idx, t]

    # -- stepping -------------------------------------------------------------
    def _advance(self, idx: np.ndarray) -> np.ndarray:
        """Advance UEs ``idx`` one tick each (vectorized): mobility
        bookkeeping mirrors ``MobilityChannel.step`` exactly, then the
        cursors move. Returns delivered capacities [len(idx)] bytes/s."""
        t = self._i[idx]
        caps = self._base_caps(idx, t)
        if self.cells is not None:
            ci = self._script_idx(t, self.cells.shape[1])
            cell = self.cells[idx, ci]
            pi = self._script_idx(np.maximum(t - 1, 0),
                                  self.cells.shape[1])
            prev = self.cells[idx, pi]
            unhomed = self.serving_cell[idx] < 0
            if unhomed.any():
                u = idx[unhomed]
                self.serving_cell[u] = cell[unhomed]
            crossed = (t > 0) & (cell != prev)
            if crossed.any():
                c_idx = idx[crossed]
                self.pending_handover[c_idx] = cell[crossed]
                for j, tick in zip(c_idx, t[crossed]):
                    self.handover_ticks.setdefault(int(j), []).append(
                        int(tick))
                fresh = crossed & (self._crossed_at[idx] < 0)
                self._crossed_at[idx[fresh]] = t[fresh]
            det = cell != self.serving_cell[idx]
            caps = np.where(det,
                            np.maximum(caps * self.detach_factor, 1.0),
                            caps)
        self._i[idx] = t + 1
        return caps

    def step_all(self) -> np.ndarray:
        """ONE vectorized step for the whole fleet: every lane advances a
        tick; returns the delivered capacities ``[n]`` in bytes/second."""
        return self._advance(np.arange(self.n))

    def _step_lane(self, i: int) -> float:
        return float(self._advance(np.array([i]))[0])

    def _peek_lane(self, i: int) -> float:
        """Pure preview of lane ``i``'s next delivered capacity (no cursor
        advance, no event bookkeeping) — un-homed UEs are assumed
        co-located, exactly like the scalar's first step."""
        idx = np.array([i])
        t = self._i[idx]
        cap = float(self._base_caps(idx, t)[0])
        if self.cells is not None:
            cell = self._cell_at_lane(i, int(t[0]))
            serving = int(self.serving_cell[i])
            if serving >= 0 and cell != serving:
                cap = max(cap * self.detach_factor, 1.0)
        return cap

    def peek_all(self) -> np.ndarray:
        """Vectorized :meth:`FleetLane.peek` for the whole fleet — the SLO
        admission controller's batch prediction input."""
        t = self._i
        caps = self._base_caps(np.arange(self.n), t)
        if self.cells is not None:
            cell = self._cell_at(t)
            det = (self.serving_cell >= 0) & (cell != self.serving_cell)
            caps = np.where(det,
                            np.maximum(caps * self.detach_factor, 1.0),
                            caps)
        return caps

    def ack_handover(self, i: int, serving_cell: int):
        """Lane ``i``'s serving side re-homed it (MobilityChannel
        semantics: clears the pending event, logs crossing->re-home
        latency in ticks when the new home matches the physical cell)."""
        self.serving_cell[i] = int(serving_cell)
        self.pending_handover[i] = -1
        if self._crossed_at[i] >= 0 and \
                int(serving_cell) == self._cell_at_lane(
                    i, max(int(self._i[i]) - 1, 0)):
            self.handover_latencies.setdefault(int(i), []).append(
                int(self._i[i] - self._crossed_at[i]))
            self._crossed_at[i] = -1

    def lane(self, i: int) -> FleetLane:
        """The per-UE :class:`Channel`-protocol view serving requests
        carry (cached — one lane object per UE, ever)."""
        if not 0 <= i < self.n:
            raise IndexError(f"lane {i} out of range [0, {self.n})")
        ln = self._lanes.get(i)
        if ln is None:
            ln = self._lanes[i] = FleetLane(self, i)
        return ln

    def lanes(self) -> List[FleetLane]:
        return [self.lane(i) for i in range(self.n)]


def city_grid_cells(n: int, n_ticks: int, n_cells: int, *, seed: int = 0,
                    dwell_ticks: int = 64) -> np.ndarray:
    """Scripted city grid: ``[n, n_ticks]`` cell membership for ``n`` UEs
    random-walking a ring of ``n_cells`` cells (the Lumos5G downtown loop
    topology — each cell fronts one edge replica). Each UE starts in a
    random cell and crosses to a neighbour with probability
    ``1 / dwell_ticks`` per tick; fully vectorized, deterministic per seed.
    """
    if n_cells < 1:
        raise ValueError("need at least one cell")
    rng = np.random.default_rng(seed)
    start = rng.integers(0, n_cells, size=n)
    if n_cells == 1:
        return np.zeros((n, n_ticks), np.int64) + start[:, None]
    move = rng.random((n, n_ticks)) < 1.0 / max(int(dwell_ticks), 1)
    step = rng.integers(0, 2, size=(n, n_ticks)) * 2 - 1
    step = np.where(move, step, 0)
    step[:, 0] = 0                      # tick 0 is the starting cell
    return (start[:, None] + np.cumsum(step, axis=1)) % n_cells


def tx_seconds(payload_bytes: int, capacity_bps: float,
               rtt_seconds: float = RTT_SECONDS) -> float:
    """Transfer latency for one boundary payload."""
    return payload_bytes / max(capacity_bps, 1.0) + rtt_seconds
