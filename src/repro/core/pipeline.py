"""Two-stage pod pipeline: the paper's UE -> edge link mapped onto the
inter-pod ICI axis.

``shard_map`` is manual over the ``pod`` axis only (data/model stay auto, so
GSPMD still applies TP/FSDP inside each stage). Stage 0 (= the UE encoder)
runs the first half of the layer stack on each microbatch, pushes the
boundary activation through the selected bottleneck mode (down-proj + int8
quantization for mode >= 1 — the paper's layer A + wire format), and
``ppermute``s the payload to stage 1 (= the edge decoder), which adapts it
back (layer B) and finishes the stack.

The collective-permute operand size in the compiled HLO IS the paper's
"transmission resource consumption" — mode m shrinks it by
(d_bneck/d_model) x (int8/bf16), which the roofline harness measures.

Split *learning* across the link uses straight-through-estimator semantics:
the forward wire carries int8 codes; the backward wire carries the gradient
of the boundary activation — float by default (what the paper implies), or
int8 with ``bwd_bits=8`` (beyond paper; ``tests/test_pipeline_pods.py``
pins the compressed-wire collective bytes). Implemented as a
``jax.custom_vjp`` around the
quantize -> ppermute -> dequantize segment.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import bottleneck, quant
from repro.models import sharding
from repro.models.layers import dense_apply, norm_apply
from repro.models import transformer as T


def stack_stages(params, cfg: ModelConfig, n_stages: int = 2):
    """Repack layer params into [n_stages, L/n_stages, ...] for P('pod')
    placement. Requires homogeneous (scan) archs and L % n_stages == 0."""
    if not cfg.homogeneous:
        raise ValueError("pod pipeline requires a homogeneous layer stack; "
                         "hybrid/ssm archs use the tensor-split path instead")
    L = cfg.n_layers
    assert L % n_stages == 0, (L, n_stages)
    per = L // n_stages
    return jax.tree.map(
        lambda a: a.reshape((n_stages, per) + a.shape[1:]), params["layers"])


def _make_wire(bits: int, perm, axis: str = "pod", bwd_bits: int = 0):
    """Forward: quantize -> collective-permute (the uplink) -> dequantize.
    Backward: the boundary gradient rides the reverse link (STE through the
    quantizer, as in QAT split learning).

    ``bwd_bits``: ALSO quantize the backward boundary gradient (beyond
    paper — the f32 gradient dominates the wire once the forward is
    compressed; this closes the gap toward the theoretical 8x).
    Plain rowwise-absmax quantized gradients, no error feedback — the
    residual-error accumulator would live on the UE across steps and is
    noted as an open item in ROADMAP.md."""
    rev = [(d, s) for (s, d) in perm]

    @jax.custom_vjp
    def wire(z):
        if bits == 0:
            return jax.lax.ppermute(z, axis, perm)
        codes, scales = quant.quantize(z, bits)
        codes = jax.lax.ppermute(codes, axis, perm)
        scales = jax.lax.ppermute(scales, axis, perm)
        return quant.dequantize(codes, scales, bits).astype(z.dtype)

    def fwd(z):
        return wire(z), None

    def bwd(_, g):
        if bwd_bits == 0:
            return (jax.lax.ppermute(g, axis, rev),)
        codes, scales = quant.quantize(g, bwd_bits)
        codes = jax.lax.ppermute(codes, axis, rev)
        scales = jax.lax.ppermute(scales, axis, rev)
        return (quant.dequantize(codes, scales, bwd_bits).astype(g.dtype),)

    wire.defvjp(fwd, bwd)
    return wire


def pipeline_apply(stage_layers, bneck_head, x, positions,
                   cfg: ModelConfig, *, mesh, n_micro: int, mode: int,
                   train: bool = False, bwd_bits: int = 0):
    """Run the layer stack as a 2-stage pipeline over the ``pod`` axis.

    stage_layers: [2, L/2, ...] pytree (placed P('pod') by the caller's jit).
    x: embedded inputs [B, S, d]; B % n_micro == 0.
    Returns (hidden [B, S, d], aux).
    """
    B, S, d = x.shape
    n_data = mesh.shape.get("data", 1)
    assert B % (n_micro * n_data) == 0, (B, n_micro, n_data)
    n_stages = mesh.shape["pod"]
    dtype = x.dtype
    bits = 0 if mode == 0 else bottleneck.mode_widths(cfg.split)[mode - 1][1]
    perm = [(i, i + 1) for i in range(n_stages - 1)]
    wire = _make_wire(bits, perm, bwd_bits=bwd_bits)

    def inner(stage_ids, stage_layers, head_f32, x_f32, pos):
        # inside the manual `pod` region the outer mesh's NamedShardings are
        # invalid (pod axis is Manual here) — drop activation constraints for
        # the duration of this trace and let GSPMD keep propagating
        # data/model shardings from the operands
        with sharding.activation_rules(None, {}):
            return _inner_body(stage_ids, stage_layers, head_f32, x_f32, pos)

    def _inner_body(stage_ids, stage_f32, head_f32, x_f32, pos):
        # the stage id rides in as a P('pod')-sharded iota instead of
        # jax.lax.axis_index: under partially-auto shard_map older XLA
        # lowers axis_index on a manual axis to a PartitionId instruction
        # the SPMD partitioner rejects
        stage = stage_ids[0]
        # inputs (incl. the pod-replicated stage weights) enter in fp32 —
        # XLA CPU aborts on the bf16 psum their cotangents need; compute
        # stays in bf16. The batch dim is MANUALLY sharded over `data`
        # (replicating it — the first version — cost 63 GiB/device temp).
        my_layers = jax.tree.map(lambda a: a[0].astype(dtype)
                                 if jnp.issubdtype(a.dtype, jnp.floating)
                                 else a[0], stage_f32)           # [L/2, ...]
        xs = x_f32.astype(dtype)
        head = jax.tree.map(lambda a: a.astype(dtype), head_f32)
        B_loc = xs.shape[0]
        mb_l = B_loc // n_micro
        micro = xs.reshape(n_micro, mb_l, S, d)
        posm = pos[:mb_l]

        def run(h):
            return T.run_layers(my_layers, h, posm, cfg, train=train)

        def boundary_tx(h):
            """Sender-side bottleneck (layer A) + wire."""
            if mode == 0:
                return wire(h)
            z = dense_apply(head["down"],
                            norm_apply(head["norm"], h, "rmsnorm"))
            return wire(z)

        def boundary_rx(zq):
            """Receiver-side adapter (layer B)."""
            if mode == 0:
                return zq
            return dense_apply(head["up"], zq)

        def tick(carry, t):
            recv, out_buf, aux = carry
            inp0 = jnp.where(t < n_micro,
                             micro[jnp.minimum(t, n_micro - 1)], 0.0)
            inp = jnp.where(stage == 0, inp0, recv)
            h, a = run(inp)
            recv = boundary_rx(boundary_tx(h))
            j = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            upd = jax.lax.dynamic_update_slice_in_dim(
                out_buf, h[None], j, axis=0)
            out_buf = jnp.where((stage == n_stages - 1)
                                & (t >= n_stages - 1), upd, out_buf)
            return (recv, out_buf, aux + a), None

        carry0 = (jnp.zeros((mb_l, S, d), dtype),
                  jnp.zeros((n_micro, mb_l, S, d), dtype),
                  jnp.zeros((), jnp.float32))
        (recv, out_buf, aux), _ = jax.lax.scan(
            tick, carry0, jnp.arange(n_micro + n_stages - 1))
        # bring outputs from the last stage to every pod so unembed/loss can
        # run data-parallel (this return hop is the edge->UE feedback path);
        # fp32 reduce for the same XLA CPU reason as above
        out = out_buf.reshape(B_loc, S, d)
        out = jnp.where(stage == n_stages - 1, out, 0.0)
        out = jax.lax.psum(out.astype(jnp.float32), "pod")
        aux = jax.lax.psum(aux, "pod") / n_stages
        aux = jax.lax.pmean(aux, "data")
        return out, aux

    shmap = sharding.shard_map(
        inner, mesh=mesh,
        in_specs=(P("pod"), P("pod"), P(), P("data", None, None),
                  P("data", None)),
        out_specs=(P("data", None, None), P()),
        axis_names={"pod", "data"}, check=False)
    def f32(t):
        return jax.tree.map(lambda a: a.astype(jnp.float32)
                            if jnp.issubdtype(a.dtype, jnp.floating) else a,
                            t)
    head_f32 = f32(bneck_head if bneck_head is not None else {})
    out, aux = shmap(jnp.arange(n_stages, dtype=jnp.int32),
                     f32(stage_layers), head_f32, x.astype(jnp.float32),
                     positions)
    return out.astype(dtype), aux


def pipeline_forward(params, tokens, cfg: ModelConfig, *, mesh,
                     n_micro: int = 4, mode: int = 0, train: bool = False,
                     bwd_bits: int = 0, embeddings=None):
    """Embed -> pod pipeline -> unembed. Returns (logits, aux)."""
    x = T.embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    stages = stack_stages(params, cfg, mesh.shape["pod"])
    modes = params.get("bneck_modes") or ()
    head = modes[mode - 1] if (mode >= 1 and modes) else (
        modes[0] if modes else None)
    h, aux = pipeline_apply(stages, head, x, positions, cfg, mesh=mesh,
                            n_micro=n_micro, mode=mode, train=train,
                            bwd_bits=bwd_bits)
    h = T.norm_apply_final(params, h, cfg)
    return T.lm_logits(params, h, cfg), aux
