"""Algorithm 1 — cascaded training producing multiple complexity-relevance
modes from ONE encoder/decoder pair.

Phase 1 trains the base network (mode 0: raw boundary code z).
Phase m+1 freezes everything trained so far, trains only bottleneck head m
(layer A: down-proj; layer B: up-proj adapter), exactly the paper's lines 2-6.
The "Ensure I(Y; Dec1) <= I(Y; Dec2)" line is checked empirically after each
phase via validation loss ordering (``verify_mode_ordering``).

Works for both the paper's LSTM PoC (``repro.models.lstm``) and any split
transformer (``repro.core.split``) — the trainer only needs a loss function
per mode and a phase mask.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.training import optimizer as opt


# ---------------------------------------------------------------------------
# phase masks
# ---------------------------------------------------------------------------

def transformer_phase_mask(params, phase: int):
    """phase 1: everything except the bottleneck bank; phase m >= 2: only
    head (m-2) of the bank."""
    def mark(key, sub, trainable):
        return jax.tree.map(lambda _: trainable, sub)

    mask = {}
    for k, v in params.items():
        if k == "bneck_modes":
            mask[k] = tuple(
                jax.tree.map(lambda _: (phase - 2) == i, head)
                for i, head in enumerate(v))
        else:
            mask[k] = jax.tree.map(lambda _: phase == 1, v)
    return mask


# ---------------------------------------------------------------------------
# generic cascaded trainer
# ---------------------------------------------------------------------------

def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch, mode) -> (loss, metrics)."""
    @functools.partial(jax.jit, static_argnames=("mode",))
    def step(params, opt_state, batch, mask, *, mode: int):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, mode)
        params, opt_state, info = opt.apply_updates(
            params, grads, opt_state, tcfg, mask)
        metrics = dict(metrics, loss=loss, **info)
        return params, opt_state, metrics
    return step


def train_cascade(params,
                  loss_fn: Callable,
                  data_iter: Callable[[int], Any],
                  tcfg: TrainConfig,
                  *,
                  n_modes: int,
                  steps_per_phase: int,
                  phase_mask_fn: Callable = transformer_phase_mask,
                  eval_fn: Optional[Callable] = None,
                  log_every: int = 50,
                  verbose: bool = True) -> Tuple[Any, Dict]:
    """Run Algorithm 1 over ``n_modes`` modes (phases 1..n_modes).

    ``data_iter(step)`` yields a batch; ``loss_fn(params, batch, mode)``.
    ``eval_fn(params, mode)`` -> dict with 'loss'/'acc' for the Ensure check.
    Returns (params, history).
    """
    step_fn = make_train_step(loss_fn, tcfg)
    opt_state = opt.init(params)
    history: Dict[str, Any] = {"phases": []}
    global_step = 0
    for phase in range(1, n_modes + 1):
        mode = phase - 1
        mask = phase_mask_fn(params, phase)
        phase_log: List[Dict] = []
        for s in range(steps_per_phase):
            batch = data_iter(global_step)
            params, opt_state, m = step_fn(params, opt_state, batch, mask,
                                           mode=mode)
            global_step += 1
            if s % log_every == 0 or s == steps_per_phase - 1:
                rec = {k: float(v) for k, v in m.items()}
                rec["step"] = s
                phase_log.append(rec)
                if verbose:
                    print(f"[cascade] phase {phase} step {s:4d} "
                          f"loss {rec['loss']:.4f} acc {rec.get('acc', 0):.3f}")
        entry = {"phase": phase, "mode": mode, "log": phase_log}
        if eval_fn is not None:
            entry["eval"] = {k: float(v)
                             for k, v in eval_fn(params, mode).items()}
        history["phases"].append(entry)
    if eval_fn is not None:
        history["ensure"] = verify_mode_ordering(params, eval_fn, n_modes)
    return params, history


def verify_mode_ordering(params, eval_fn: Callable, n_modes: int) -> Dict:
    """The paper's Ensure line: each extra bottleneck mode must perform at
    most as well as the previous (relevance ordering by DPI)."""
    evals = [eval_fn(params, m) for m in range(n_modes)]
    losses = [float(e["loss"]) for e in evals]
    ordered = all(losses[i] <= losses[i + 1] + 1e-3
                  for i in range(len(losses) - 1))
    return {"losses": losses,
            "accs": [float(e.get("acc", 0.0)) for e in evals],
            "ordered": ordered}
