"""Bottleneck exit heads — the paper's added "layer A" (encoder side) and
"layer B" (decoder side), generalized to a bank of modes.

Mode 0 is always the phase-1 code z: the raw split-boundary activation
(transmitted in bf16). Mode m >= 1 adds a trained down-projection
(layer A) producing z' of width ``d_bottleneck_m``, quantized for the wire,
and an up-projection adapter (layer B) that maps the received code back into
the frozen decoder's input width — exactly Algorithm 1 lines 3-5.

By the data-processing inequality, each extra mode can only lose information
about X (and hence Y): I(X; z') <= I(X; z). The cascade trainer
(``repro.core.cascade``) enforces the paper's "Ensure" line empirically.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SplitConfig
from repro.core import quant
from repro.models.layers import dense_apply, dense_init, norm_apply, norm_init


def mode_widths(split: SplitConfig) -> List[Tuple[int, int]]:
    """[(width, quant_bits)] for modes 1..M (mode 0 is the raw boundary)."""
    out = []
    if split.d_bottleneck:
        out.append((split.d_bottleneck, split.quant_bits))
    out.extend(split.extra_modes)
    return out


def head_init(key, d_model: int, d_bneck: int, *, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "norm": norm_init(d_model, "rmsnorm", dtype=dtype),
        "down": dense_init(k1, d_model, d_bneck, dtype=dtype),   # layer A
        "up": dense_init(k2, d_bneck, d_model, dtype=dtype),     # layer B
    }


def bank_init(key, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    modes = mode_widths(cfg.split)
    keys = jax.random.split(key, max(len(modes), 1))
    return tuple(head_init(k, cfg.d_model, w, dtype=dtype)
                 for k, (w, _) in zip(keys, modes))


def encode(head, x, bits: int, *, train: bool = False):
    """Encoder-side transmit op (layer A + wire quantization).

    x: [..., d_model] -> (codes, scales) — the payload that crosses the link.
    ``train=True`` uses the straight-through fake-quantizer (float payload,
    identical forward values) so gradients reach layer A during cascade
    phase 2; the wire format for serving/dry-run stays int8.
    """
    z = dense_apply(head["down"], norm_apply(head["norm"], x, "rmsnorm"))
    if train and bits:
        return quant.ste_quantize(z, bits), None
    return quant.quantize(z, bits)


def decode(head, codes, scales, bits: int, dtype=jnp.bfloat16):
    """Decoder-side receive op (dequant + layer B adapter). ``scales`` is
    None on the STE training path (codes already float)."""
    z = codes if scales is None else quant.dequantize(codes, scales, bits)
    return dense_apply(head["up"], z.astype(dtype))


def bank_stack(bank, split: SplitConfig):
    """Pad every head to the widest bottleneck and stack the bank into one
    pytree of [M, ...] arrays so a jitted decode step can *gather* the head
    for each batch slot (mixed-mode continuous batching) instead of
    branching in Python.

    Down-projection columns (and up-projection rows) beyond a head's true
    width are zero, so padded lanes carry exact zeros through quantization
    and contribute nothing to the adapter output — numerically identical to
    running that head unpadded.
    """
    modes = mode_widths(split)
    if not bank:
        raise ValueError("bank_stack needs at least one bottleneck head")
    wmax = max(w for w, _ in modes)
    downs, ups, norms, widths, bits = [], [], [], [], []
    for head, (w, b) in zip(bank, modes):
        dw = head["down"]["w"]                      # [d, w]
        uw = head["up"]["w"]                        # [w, d]
        downs.append(jnp.pad(dw, ((0, 0), (0, wmax - w))))
        ups.append(jnp.pad(uw, ((0, wmax - w), (0, 0))))
        norms.append(head["norm"]["scale"])
        widths.append(w)
        bits.append(b)
    return {
        "down_w": jnp.stack(downs),                 # [M, d, wmax]
        "up_w": jnp.stack(ups),                     # [M, wmax, d]
        "norm_scale": jnp.stack(norms),             # [M, d]
        "width": jnp.asarray(widths, jnp.int32),    # [M]
        "bits": jnp.asarray(bits, jnp.int32),       # [M]
    }


def boundary_mixed(stacked, x, mode_idx, *, dtype=jnp.bfloat16, mesh=None):
    """Per-slot bottleneck at the split boundary inside one jitted step.

    x: [B, S, d] boundary activation ([B, 1, d] at decode); mode_idx: [B]
    int32 in [0, M] where 0 means "transmit the raw code z" and m >= 1
    routes slot b through bottleneck head m-1 (gathered from the stacked
    bank). Simulates the wire round-trip (quantize -> dequantize) with each
    slot's own bit width. Returns the decoder-side activation [B, S, d].

    This is a dispatcher: on TPU (128-aligned model and bank widths) it
    runs the fused mode-grouped Pallas kernel
    (``repro.kernels.boundary_mixed``); everywhere else — CPU serving,
    unaligned widths — it runs the pure-jnp reference
    (``repro.kernels.ref.boundary_mixed_ref``). The two are parity-pinned
    by ``tests/test_kernels.py`` across every calibrated bit width.

    ``mesh``: serving ``('dp','mp')`` mesh — runs the dispatcher per-shard
    inside a replicated ``shard_map`` region (``ops.boundary_mixed_sharded``)
    so dp-sharded engine steps stay bit-identical to unsharded ones.
    """
    from repro.kernels import ops
    if mesh is not None:
        return ops.boundary_mixed_sharded(stacked, x, mode_idx, mesh,
                                          dtype=dtype)
    return ops.boundary_mixed_op(stacked, x, mode_idx, dtype=dtype)


def mode_payload_bytes(cfg: ModelConfig, batch: int, seq: int, mode: int) -> int:
    """Wire bytes for one boundary transfer in the given mode."""
    if mode == 0:
        return quant.payload_bytes((batch, seq, cfg.d_model), 0)
    w, bits = mode_widths(cfg.split)[mode - 1]
    return quant.payload_bytes((batch, seq, w), bits)


def compression_ratio(cfg: ModelConfig, mode: int) -> float:
    full = mode_payload_bytes(cfg, 1, 1, 0)
    return mode_payload_bytes(cfg, 1, 1, mode) / full
