"""Quantized tensor-parallel prefill — the paper's insight applied to the
intra-layer TP boundary (beyond-paper; ``benchmarks/bench_roofline.py``
measures the transfer terms this targets).

The paper compresses the ONE split-learning boundary (bottleneck + int8)
because it crosses the weakest link. Under Megatron-style TP the residual
stream crosses the `model` axis twice per layer (gather before attention /
MLP, reduce-scatter after), and GSPMD's auto placement makes those transfers
the dominant roofline term for small-batch prefill (musicgen-large
prefill_32k: 66.8s collective vs 0.40s compute at baseline).

This module pins the Megatron-SP schedule manually under ``shard_map`` and
quantizes the gathered operand to int8 (the activations entering a matmul —
W8A8 semantics, standard for inference):

    x_loc [B, S/m, d]   (sequence-sharded residual, bf16)
    norm -> quantize int8 -> all_gather('model') -> dequant -> matmul block
    partial sums [B, S, d] -> psum_scatter('model') -> + residual

  per-device collective bytes/layer = 2 * B*S*d * (1 byte) [+ small scales
  and the scattered f32 partials] — 4x less than the bf16 auto placement
  and ~8x less than what the f32-promoted CPU HLO reports.

``bits=0`` keeps the gather in bf16 — the exact-precision manual schedule,
used to isolate "manual SP" gains from quantization gains in §Perf.

Scope guard (``qtp_supported``): homogeneous attention stacks with
n_heads, n_kv_heads, and seq all divisible by the `model` axis; decode and
training use the regular paths.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.models import sharding
from repro.models import transformer as T
from repro.models.attention import (BLOCKED_ATTN_THRESHOLD, _BLOCK_K,
                                    _BLOCK_Q, _blocked_attention,
                                    _dense_attention, apply_rope)
from repro.models.layers import _act, norm_apply


def qtp_supported(cfg: ModelConfig, mesh, seq_len: int) -> bool:
    if mesh is None or "model" not in mesh.shape:
        return False
    m = mesh.shape["model"]
    return (cfg.homogeneous and not cfg.is_moe
            and cfg.n_heads % m == 0 and cfg.n_kv_heads % m == 0
            and seq_len % m == 0 and cfg.d_ff % m == 0)


def _qgather(x, bits: int, axis: str):
    """quantize -> all_gather(seq axis) -> dequantize. x: [B, S_loc, d]."""
    if bits == 0:
        g = jax.lax.all_gather(x, axis, axis=1, tiled=True)
        return g
    codes, scales = quant.quantize(x, bits)        # int8 codes + row scales
    codes = jax.lax.all_gather(codes, axis, axis=1, tiled=True)
    scales = jax.lax.all_gather(scales, axis, axis=1, tiled=True)
    return quant.dequantize(codes, scales, bits).astype(x.dtype)


def qtp_forward(params, tokens, cfg: ModelConfig, *, mesh, bits: int = 8,
                embeddings=None) -> jnp.ndarray:
    """Prefill forward with the manual quantized-SP schedule.

    Returns logits (same contract as ``T.forward`` without aux — dense
    archs only).
    """
    m = mesh.shape["model"]
    x = T.embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    n_q, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ql, kvl = n_q // m, n_kv // m
    dp = sharding.dp_axes(mesh)

    # fully-manual shard_map: batch over the dp axes, seq over `model`;
    # layer weights shard their head/ffn dim over `model` and replicate over
    # dp (jit all-gathers them ONCE outside the scan — ~params/m bytes, tiny
    # next to the per-layer activation traffic this path eliminates).
    wspec = {
        "mix": {"wq": {"w": P(None, None, "model")},
                "wk": {"w": P(None, None, "model")},
                "wv": {"w": P(None, None, "model")},
                "wo": {"w": P(None, "model", None)}},
        "mlp": {"w_gate": {"w": P(None, None, "model")},
                "w_up": {"w": P(None, None, "model")},
                "w_down": {"w": P(None, "model", None)}},
    }
    layers = dict(params["layers"])
    if "mix" in layers and "b" in layers["mix"].get("wq", {}):
        for k in ("wq", "wk", "wv"):
            wspec["mix"][k]["b"] = P(None, "model")

    def inner(layers_l, x_loc, pos):
        # x_loc: [B/dp, S/m, d]; pos: [B/dp, S]; layers_l: stacked [L, ...]
        # with head/ffn dims local to this chip, replicated over dp.
        Bl = x_loc.shape[0]

        def block(x_loc, lp):
            # ---- attention ----
            h = norm_apply(lp["norm1"], x_loc, cfg.norm)
            hg = _qgather(h, bits, "model")                     # [Bl, S, d]
            q = (hg @ lp["mix"]["wq"]["w"]).reshape(Bl, S, ql, hd)
            k = (hg @ lp["mix"]["wk"]["w"]).reshape(Bl, S, kvl, hd)
            v = (hg @ lp["mix"]["wv"]["w"]).reshape(Bl, S, kvl, hd)
            if "b" in lp["mix"].get("wq", {}):
                q = q + lp["mix"]["wq"]["b"].reshape(ql, hd)
                k = k + lp["mix"]["wk"]["b"].reshape(kvl, hd)
                v = v + lp["mix"]["wv"]["b"].reshape(kvl, hd)
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
            w = cfg.sliding_window or cfg.local_window
            if S >= BLOCKED_ATTN_THRESHOLD and S % _BLOCK_Q == 0 \
                    and S % _BLOCK_K == 0:
                attn = _blocked_attention(q, k, v, pos, hd, w)
            else:
                attn = _dense_attention(q, k, v, pos, hd, w)
            attn = attn.astype(x_loc.dtype)          # [Bl, S, ql*hd]
            part = attn @ lp["mix"]["wo"]["w"]                  # partial [B,S,d]
            # f32 around the scatter-reduce: XLA CPU crashes promoting bf16
            # reduces (same workaround as pipeline.py); on TPU this would be
            # a plain bf16 psum_scatter
            mix = jax.lax.psum_scatter(part.astype(jnp.float32), "model",
                                       scatter_dimension=1,
                                       tiled=True)              # [B, S/m, d]
            x_loc = x_loc + mix.astype(x_loc.dtype)
            # ---- mlp ----
            h = norm_apply(lp["norm2"], x_loc, cfg.norm)
            hg = _qgather(h, bits, "model")
            hh = _act(hg @ lp["mlp"]["w_gate"]["w"], cfg.act) * \
                (hg @ lp["mlp"]["w_up"]["w"])
            part = hh @ lp["mlp"]["w_down"]["w"]
            mlp = jax.lax.psum_scatter(part.astype(jnp.float32), "model",
                                       scatter_dimension=1, tiled=True)
            return x_loc + mlp.astype(x_loc.dtype), None

        out, _ = jax.lax.scan(block, x_loc, layers_l)
        return out

    shmap = sharding.shard_map(
        inner, mesh=mesh,
        in_specs=(_specs_for(layers, wspec), P(dp, "model", None),
                  P(dp, None)),
        out_specs=P(dp, "model", None),
        check=False)

    with sharding.activation_rules(None, {}):
        xb = shmap(layers, x, positions)
    x = T.norm_apply_final(params, xb, cfg)
    logits = sharding.constrain(T.lm_logits(params, x, cfg), "logits")
    return logits


def _specs_for(layers, wspec):
    """Match the wspec skeleton to the actual layer pytree (norm params vary
    by norm type; extra keys default to replicated-over-model)."""
    def rule(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        node = wspec
        for k in keys:
            if isinstance(node, dict) and k in node:
                node = node[k]
            else:
                return P(*([None] * leaf.ndim))
        if isinstance(node, P):
            return node
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(rule, layers)
