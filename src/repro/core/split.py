"""Split-model wrapper: cut any assigned architecture at ``cfg.split.split_at``
into a UE-side encoder and an edge-side decoder, with the paper's selectable
bottleneck modes at the boundary.

``split_forward`` is numerically identical to running the full model when
``mode == 0`` (the boundary is transmitted raw); mode m >= 1 routes the
boundary through bottleneck head m (down-proj -> quantize -> wire ->
dequant -> up-proj adapter), which is the phase-2 network of Algorithm 1.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.models import sharding
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# parameter plumbing
# ---------------------------------------------------------------------------

def init_split_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Full model params + the bottleneck mode bank."""
    k1, k2 = jax.random.split(key)
    params = T.init_params(k1, cfg)
    params["bneck_modes"] = bottleneck.bank_init(
        k2, cfg, dtype=T.model_dtype(cfg))
    return params


def slice_layers(layers, cfg: ModelConfig, split_at: Optional[int] = None):
    """(encoder_layers, decoder_layers) views of the layer params."""
    s = split_at if split_at is not None else cfg.split.split_at
    if cfg.homogeneous:
        enc = jax.tree.map(lambda a: a[:s], layers)
        dec = jax.tree.map(lambda a: a[s:], layers)
    else:
        enc, dec = layers[:s], layers[s:]
    return enc, dec


def _kinds(cfg: ModelConfig):
    return tuple(cfg.block_kind(i) for i in range(cfg.n_layers))


def _split_states(states, cfg: ModelConfig, s: int):
    """(encoder_states, decoder_states) views of the per-layer decode state."""
    if cfg.homogeneous:
        return (jax.tree.map(lambda a: a[:s], states),
                jax.tree.map(lambda a: a[s:], states))
    return states[:s], states[s:]


def _merge_states(enc_new, dec_new, cfg: ModelConfig):
    if cfg.homogeneous:
        return jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), enc_new, dec_new)
    return tuple(enc_new) + tuple(dec_new)


# ---------------------------------------------------------------------------
# full-sequence split forward (training / prefill)
# ---------------------------------------------------------------------------

def encoder_apply(params, tokens, cfg: ModelConfig, mode: int, *,
                  train: bool = False, embeddings=None):
    """UE side. Returns (payload, aux, info) where payload crosses the link.

    mode 0 payload: raw boundary activation (bf16).
    mode m payload: (int codes, scales) from bottleneck head m.
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc, _ = slice_layers(params["layers"], cfg, s)
    x, aux = T.run_layers(enc, x, positions, cfg, train=train,
                          kinds=_kinds(cfg)[:s])
    if mode == 0:
        payload = (x, None)
        bits = 0
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        payload = bottleneck.encode(params["bneck_modes"][mode - 1], x, bits,
                                    train=train)
    info = {"positions": positions,
            "payload_bytes": bottleneck.mode_payload_bytes(cfg, B, S, mode)}
    return payload, aux, info


def decoder_apply(params, payload, positions, cfg: ModelConfig, mode: int, *,
                  train: bool = False):
    """Edge side: adapter (mode >= 1) + remaining layers + head."""
    s = cfg.split.split_at
    codes, scales = payload
    if mode == 0:
        x = codes
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        x = bottleneck.decode(params["bneck_modes"][mode - 1], codes, scales,
                              bits, dtype=T.model_dtype(cfg))
    _, dec = slice_layers(params["layers"], cfg, s)
    x, aux = T.run_layers(dec, x, positions, cfg, train=train,
                          kinds=_kinds(cfg)[s:])
    x = T.norm_apply_final(params, x, cfg)
    logits = sharding.constrain(T.lm_logits(params, x, cfg), "logits")
    return logits, aux


def split_forward(params, tokens, cfg: ModelConfig, mode: int = 0, *,
                  train: bool = False, embeddings=None):
    """End-to-end split forward (the wire is simulated as identity on values;
    byte accounting returned in info). Returns (logits, aux, info)."""
    payload, aux1, info = encoder_apply(params, tokens, cfg, mode,
                                        train=train, embeddings=embeddings)
    logits, aux2 = decoder_apply(params, payload, info["positions"], cfg,
                                 mode, train=train)
    return logits, aux1 + aux2, info


# ---------------------------------------------------------------------------
# decode-time split (one token across the link per step)
# ---------------------------------------------------------------------------

def split_decode_step(params, token, states, cur_pos, cfg: ModelConfig,
                      mode: int = 0, return_tokens: bool = False):
    """One-token decode with the boundary activation crossing the link.

    Encoder-side layer states stay on the UE; decoder-side states stay at the
    edge — only the (possibly bottlenecked) activation is transmitted.
    Returns (logits, new_states, payload_bytes); with ``return_tokens`` the
    fused decode tail (``T.decode_tail_tokens``) replaces the logits with
    argmax int32 tokens.
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, token, cfg, None)
    enc_l, dec_l = slice_layers(params["layers"], cfg, s)
    enc_st, dec_st = _split_states(states, cfg, s)
    kinds = _kinds(cfg)
    x, enc_new = T.run_layers_decode(enc_l, x, enc_st, cur_pos, cfg,
                                     kinds=kinds[:s])
    B = x.shape[0]
    if mode == 0:
        payload = (x, None)
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        payload = bottleneck.encode(params["bneck_modes"][mode - 1], x, bits)
        x = bottleneck.decode(params["bneck_modes"][mode - 1], *payload, bits,
                              dtype=T.model_dtype(cfg))
    x, dec_new = T.run_layers_decode(dec_l, x, dec_st, cur_pos, cfg,
                                     kinds=kinds[s:])
    pb = bottleneck.mode_payload_bytes(cfg, B, 1, mode)
    if return_tokens:
        return (T.decode_tail_tokens(params, x, cfg),
                _merge_states(enc_new, dec_new, cfg), pb)
    x = T.norm_apply_final(params, x, cfg)
    logits = T.lm_logits(params, x, cfg)
    return logits, _merge_states(enc_new, dec_new, cfg), pb


def split_decode_step_mixed(params, stacked_bank, token, states, positions,
                            cfg: ModelConfig, mode_idx, block_table=None,
                            mesh=None, return_tokens: bool = False):
    """One decode step for a *mixed-mode* continuous batch.

    Unlike :func:`split_decode_step`, every batch slot decodes at its own
    sequence depth (``positions``: [B] int32 absolute positions) and through
    its own orchestrator-chosen bottleneck (``mode_idx``: [B] int32, 0 = raw
    code z, m >= 1 = head m-1 gathered from ``stacked_bank``; see
    ``bottleneck.bank_stack``). The whole step is one jittable function —
    mode selection is a gather, not a Python branch, so a single compiled
    executable serves any mode mixture.

    Per-slot wire bytes are host-side accounting (they depend only on the
    static mode table, not on traced values) — see
    ``bottleneck.mode_payload_bytes(cfg, 1, 1, mode)`` per slot.
    With ``block_table`` ([B, nb] int32, paged serving) the attention
    leaves of ``states`` are page arenas shared by both halves — the layer
    axis splits exactly like dense stacked leaves.

    ``mesh``: serving ``('dp','mp')`` mesh for the sharded engine — the
    boundary runs in a replicated ``shard_map`` region (bit-identity with
    the unsharded step; see ``ops.boundary_mixed_sharded``) and the
    decoder-side activation is re-constrained batch-over-``dp`` so GSPMD
    keeps the slot sharding through the decoder half. Returns (logits,
    new_states); with ``return_tokens`` the fused decode tail
    (``T.decode_tail_tokens``) replaces the logits with argmax int32 tokens
    and the whole tick is two kernels on TPU — boundary + tail — with the
    f32 logits never touching HBM.
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, token, cfg, None)
    enc_l, dec_l = slice_layers(params["layers"], cfg, s)
    enc_st, dec_st = _split_states(states, cfg, s)
    kinds = _kinds(cfg)
    x, enc_new = T.run_layers_decode(enc_l, x, enc_st, positions, cfg,
                                     kinds=kinds[:s], block_table=block_table)
    x = bottleneck.boundary_mixed(stacked_bank, x, mode_idx,
                                  dtype=T.model_dtype(cfg), mesh=mesh)
    x = sharding.constrain_batch(x, mesh)
    x, dec_new = T.run_layers_decode(dec_l, x, dec_st, positions, cfg,
                                     kinds=kinds[s:], block_table=block_table)
    if return_tokens:
        return T.decode_tail_tokens(params, x, cfg), _merge_states(
            enc_new, dec_new, cfg)
    x = T.norm_apply_final(params, x, cfg)
    logits = T.lm_logits(params, x, cfg)
    return logits, _merge_states(enc_new, dec_new, cfg)


# ---------------------------------------------------------------------------
# batched full-sequence prefill (admission hot path)
# ---------------------------------------------------------------------------

def _prefill_through(params, tokens, cfg: ModelConfig, states, boundary,
                     lengths, block_table=None):
    """Shared whole-prompt prefill skeleton: encoder layers, ``boundary``
    (the wire crossing), decoder layers — populating every layer's decode
    state. Returns (last-real-position logits, new_states)."""
    s = cfg.split.split_at
    x = T.embed_tokens(params, tokens, cfg, None)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    enc_l, dec_l = slice_layers(params["layers"], cfg, s)
    enc_st, dec_st = _split_states(states, cfg, s)
    kinds = _kinds(cfg)
    x, enc_new = T.run_layers_prefill(enc_l, x, positions, enc_st, cfg,
                                      kinds=kinds[:s], lengths=lengths,
                                      block_table=block_table)
    x = boundary(x)
    x, dec_new = T.run_layers_prefill(dec_l, x, positions, dec_st, cfg,
                                      kinds=kinds[s:], lengths=lengths,
                                      block_table=block_table)
    last = (lengths - 1 if lengths is not None
            else jnp.full((B,), S - 1, jnp.int32))
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = T.norm_apply_final(params, x, cfg)
    return T.lm_logits(params, x, cfg), _merge_states(enc_new, dec_new, cfg)


def split_prefill(params, tokens, cfg: ModelConfig, states, mode: int = 0, *,
                  lengths=None):
    """Whole-prompt split prefill in ONE forward pass: encoder layers,
    boundary through bottleneck ``mode`` (the single uplink transfer of the
    prompt's boundary representation), decoder layers — while populating
    every layer's decode state, instead of looping ``split_decode_step``
    per prompt token.

    tokens: [B, S] right-padded to a bucket; ``lengths``: optional [B] true
    prompt lengths. Returns (last-real-position logits, new_states,
    payload_bytes). The byte figure covers the full padded [B, S] bucket
    (it must stay a host-side int under jit); callers admitting ragged
    prompts account per row with ``mode_payload_bytes(cfg, 1, len_b, mode)``
    instead, as the serving engine does.
    """
    def boundary(x):
        if mode == 0:
            return x
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        payload = bottleneck.encode(params["bneck_modes"][mode - 1], x, bits)
        return bottleneck.decode(params["bneck_modes"][mode - 1], *payload,
                                 bits, dtype=T.model_dtype(cfg))

    logits, new_states = _prefill_through(params, tokens, cfg, states,
                                          boundary, lengths)
    B, S = jnp.shape(tokens)[0], jnp.shape(tokens)[-1]
    pb = bottleneck.mode_payload_bytes(cfg, B, S, mode)
    return logits, new_states, pb


def split_prefill_mixed(params, stacked_bank, tokens, states,
                        cfg: ModelConfig, mode_idx, *, lengths=None,
                        block_table=None, mesh=None):
    """Batched multi-request prefill with per-row bottleneck modes: one
    forward over a right-padded prompt batch where row b's boundary
    activations cross the wire through its own admission-chosen mode
    (``mode_idx``: [B] int32, 0 = raw z, m >= 1 = head m-1 gathered from
    ``stacked_bank``). This is the admission analogue of
    :func:`split_decode_step_mixed` — quantization happens per boundary
    position with each row's own bit width, exactly as the per-mode path
    does. Returns (last-real-position logits, new_states).

    ``mesh``: serving mesh — the boundary runs replicated-per-shard like
    the decode step. Prefill inputs arrive replicated (a prompt batch is
    written into dp-sharded pool rows only afterwards), so no batch
    constraint is added here: fully-replicated prefill compute keeps the
    admission path bit-identical to the unsharded engine.
    """
    return _prefill_through(
        params, tokens, cfg, states,
        lambda x: bottleneck.boundary_mixed(stacked_bank, x, mode_idx,
                                            dtype=T.model_dtype(cfg),
                                            mesh=mesh),
        lengths, block_table)
