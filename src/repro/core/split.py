"""Split-model wrapper: cut any assigned architecture at ``cfg.split.split_at``
into a UE-side encoder and an edge-side decoder, with the paper's selectable
bottleneck modes at the boundary.

``split_forward`` is numerically identical to running the full model when
``mode == 0`` (the boundary is transmitted raw); mode m >= 1 routes the
boundary through bottleneck head m (down-proj -> quantize -> wire ->
dequant -> up-proj adapter), which is the phase-2 network of Algorithm 1.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.models import sharding
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# parameter plumbing
# ---------------------------------------------------------------------------

def init_split_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Full model params + the bottleneck mode bank."""
    k1, k2 = jax.random.split(key)
    params = T.init_params(k1, cfg)
    params["bneck_modes"] = bottleneck.bank_init(
        k2, cfg, dtype=T.model_dtype(cfg))
    return params


def slice_layers(layers, cfg: ModelConfig, split_at: Optional[int] = None):
    """(encoder_layers, decoder_layers) views of the layer params."""
    s = split_at if split_at is not None else cfg.split.split_at
    if cfg.homogeneous:
        enc = jax.tree.map(lambda a: a[:s], layers)
        dec = jax.tree.map(lambda a: a[s:], layers)
    else:
        enc, dec = layers[:s], layers[s:]
    return enc, dec


def _kinds(cfg: ModelConfig):
    return tuple(cfg.block_kind(i) for i in range(cfg.n_layers))


# ---------------------------------------------------------------------------
# full-sequence split forward (training / prefill)
# ---------------------------------------------------------------------------

def encoder_apply(params, tokens, cfg: ModelConfig, mode: int, *,
                  train: bool = False, embeddings=None):
    """UE side. Returns (payload, aux, info) where payload crosses the link.

    mode 0 payload: raw boundary activation (bf16).
    mode m payload: (int codes, scales) from bottleneck head m.
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, tokens, cfg, embeddings)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc, _ = slice_layers(params["layers"], cfg, s)
    x, aux = T.run_layers(enc, x, positions, cfg, train=train,
                          kinds=_kinds(cfg)[:s])
    if mode == 0:
        payload = (x, None)
        bits = 0
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        payload = bottleneck.encode(params["bneck_modes"][mode - 1], x, bits,
                                    train=train)
    info = {"positions": positions,
            "payload_bytes": bottleneck.mode_payload_bytes(cfg, B, S, mode)}
    return payload, aux, info


def decoder_apply(params, payload, positions, cfg: ModelConfig, mode: int, *,
                  train: bool = False):
    """Edge side: adapter (mode >= 1) + remaining layers + head."""
    s = cfg.split.split_at
    codes, scales = payload
    if mode == 0:
        x = codes
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        x = bottleneck.decode(params["bneck_modes"][mode - 1], codes, scales,
                              bits, dtype=T.model_dtype(cfg))
    _, dec = slice_layers(params["layers"], cfg, s)
    x, aux = T.run_layers(dec, x, positions, cfg, train=train,
                          kinds=_kinds(cfg)[s:])
    x = T.norm_apply_final(params, x, cfg)
    logits = sharding.constrain(T.lm_logits(params, x, cfg), "logits")
    return logits, aux


def split_forward(params, tokens, cfg: ModelConfig, mode: int = 0, *,
                  train: bool = False, embeddings=None):
    """End-to-end split forward (the wire is simulated as identity on values;
    byte accounting returned in info). Returns (logits, aux, info)."""
    payload, aux1, info = encoder_apply(params, tokens, cfg, mode,
                                        train=train, embeddings=embeddings)
    logits, aux2 = decoder_apply(params, payload, info["positions"], cfg,
                                 mode, train=train)
    return logits, aux1 + aux2, info


# ---------------------------------------------------------------------------
# decode-time split (one token across the link per step)
# ---------------------------------------------------------------------------

def split_decode_step(params, token, states, cur_pos, cfg: ModelConfig,
                      mode: int = 0):
    """One-token decode with the boundary activation crossing the link.

    Encoder-side layer states stay on the UE; decoder-side states stay at the
    edge — only the (possibly bottlenecked) activation is transmitted.
    Returns (logits, new_states, payload_bytes).
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, token, cfg, None)
    enc_l, dec_l = slice_layers(params["layers"], cfg, s)
    if cfg.homogeneous:
        enc_st = jax.tree.map(lambda a: a[:s], states)
        dec_st = jax.tree.map(lambda a: a[s:], states)
    else:
        enc_st, dec_st = states[:s], states[s:]
    kinds = _kinds(cfg)
    x, enc_new = T.run_layers_decode(enc_l, x, enc_st, cur_pos, cfg,
                                     kinds=kinds[:s])
    B = x.shape[0]
    if mode == 0:
        payload = (x, None)
    else:
        _, bits = bottleneck.mode_widths(cfg.split)[mode - 1]
        payload = bottleneck.encode(params["bneck_modes"][mode - 1], x, bits)
        x = bottleneck.decode(params["bneck_modes"][mode - 1], *payload, bits,
                              dtype=T.model_dtype(cfg))
    x, dec_new = T.run_layers_decode(dec_l, x, dec_st, cur_pos, cfg,
                                     kinds=kinds[s:])
    x = T.norm_apply_final(params, x, cfg)
    logits = T.lm_logits(params, x, cfg)
    if cfg.homogeneous:
        new_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), enc_new, dec_new)
    else:
        new_states = tuple(enc_new) + tuple(dec_new)
    pb = bottleneck.mode_payload_bytes(cfg, B, 1, mode)
    return logits, new_states, pb


def split_decode_step_mixed(params, stacked_bank, token, states, positions,
                            cfg: ModelConfig, mode_idx):
    """One decode step for a *mixed-mode* continuous batch.

    Unlike :func:`split_decode_step`, every batch slot decodes at its own
    sequence depth (``positions``: [B] int32 absolute positions) and through
    its own orchestrator-chosen bottleneck (``mode_idx``: [B] int32, 0 = raw
    code z, m >= 1 = head m-1 gathered from ``stacked_bank``; see
    ``bottleneck.bank_stack``). The whole step is one jittable function —
    mode selection is a gather, not a Python branch, so a single compiled
    executable serves any mode mixture.

    Per-slot wire bytes are host-side accounting (they depend only on the
    static mode table, not on traced values) — see
    ``bottleneck.mode_payload_bytes(cfg, 1, 1, mode)`` per slot.
    Returns (logits, new_states).
    """
    s = cfg.split.split_at
    x = T.embed_tokens(params, token, cfg, None)
    enc_l, dec_l = slice_layers(params["layers"], cfg, s)
    if cfg.homogeneous:
        enc_st = jax.tree.map(lambda a: a[:s], states)
        dec_st = jax.tree.map(lambda a: a[s:], states)
    else:
        enc_st, dec_st = states[:s], states[s:]
    kinds = _kinds(cfg)
    x, enc_new = T.run_layers_decode(enc_l, x, enc_st, positions, cfg,
                                     kinds=kinds[:s])
    x = bottleneck.boundary_mixed(stacked_bank, x, mode_idx,
                                  dtype=T.model_dtype(cfg))
    x, dec_new = T.run_layers_decode(dec_l, x, dec_st, positions, cfg,
                                     kinds=kinds[s:])
    x = T.norm_apply_final(params, x, cfg)
    logits = T.lm_logits(params, x, cfg)
    if cfg.homogeneous:
        new_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), enc_new, dec_new)
    else:
        new_states = tuple(enc_new) + tuple(dec_new)
    return logits, new_states
