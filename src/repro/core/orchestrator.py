"""The paper's orchestrator (Fig. 3): monitors network conditions + decoder
performance feedback and instructs the encoder which latent code to transmit.

Policy: among the calibrated modes, pick the most relevant (lowest expected
loss) whose transfer latency fits the application's budget, with hysteresis
to avoid mode flapping. This is the "optimization/search problem" framing the
paper suggests in Sec. VI.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.channel import tx_seconds


@dataclass
class ModeProfile:
    """Calibration entry per mode (from cascade validation)."""
    mode: int
    payload_bytes: int        # per-query boundary payload
    expected_loss: float      # validation loss of this mode
    expected_acc: float = 0.0


@dataclass
class AppRequirement:
    latency_budget_s: float = 0.05   # per-query transfer budget
    min_acc: float = 0.0             # slice-dependent floor (0 = best effort)


@dataclass
class OrchestratorState:
    mode: int = 0
    capacity_ema: float = 0.0
    loss_ema: Dict[int, float] = field(default_factory=dict)
    switches: int = 0
    ticks: int = 0


class Orchestrator:
    def __init__(self, profiles: List[ModeProfile],
                 requirement: AppRequirement = AppRequirement(),
                 *, ema: float = 0.8, hysteresis: float = 0.85):
        if not profiles:
            raise ValueError("need at least one mode profile")
        self.profiles = sorted(profiles, key=lambda p: p.mode)
        self.req = requirement
        self.ema = ema
        self.hysteresis = hysteresis
        self.state = OrchestratorState(
            mode=self.profiles[0].mode,
            loss_ema={p.mode: p.expected_loss for p in self.profiles})

    # -- feedback signals (Fig. 3 arrows) ------------------------------------
    def observe_capacity(self, capacity_bps: float):
        s = self.state
        s.capacity_ema = (self.ema * s.capacity_ema
                          + (1 - self.ema) * capacity_bps
                          if s.ticks else capacity_bps)
        s.ticks += 1

    def observe_decoder_loss(self, mode: int, loss: float):
        prev = self.state.loss_ema.get(mode, loss)
        self.state.loss_ema[mode] = self.ema * prev + (1 - self.ema) * loss

    # -- decision -------------------------------------------------------------
    def feasible(self, p: ModeProfile, capacity_bps: float) -> bool:
        return tx_seconds(p.payload_bytes, capacity_bps) \
            <= self.req.latency_budget_s

    def choose_mode(self) -> int:
        cap = self.state.capacity_ema
        # rank by relevance (EMA loss asc); most informative feasible wins
        ranked = sorted(self.profiles,
                        key=lambda p: self.state.loss_ema[p.mode])
        chosen: Optional[ModeProfile] = None
        for p in ranked:
            if self.req.min_acc and p.expected_acc < self.req.min_acc:
                continue
            if self.feasible(p, cap):
                chosen = p
                break
        if chosen is None:           # nothing fits: smallest payload
            chosen = min(self.profiles, key=lambda p: p.payload_bytes)
        # hysteresis: only leave the current mode if the alternative's
        # required capacity clears by a margin
        cur = next(p for p in self.profiles if p.mode == self.state.mode)
        if chosen.mode != cur.mode and chosen.payload_bytes > cur.payload_bytes:
            if not self.feasible(chosen, cap * self.hysteresis):
                chosen = cur
        if chosen.mode != self.state.mode:
            self.state.switches += 1
            self.state.mode = chosen.mode
        return self.state.mode
