"""The paper's orchestrator (Fig. 3): monitors network conditions + decoder
performance feedback and instructs the encoder which latent code to transmit.

Policy: among the calibrated modes, pick the most relevant (lowest expected
loss) whose transfer latency fits the application's budget, with hysteresis
to avoid mode flapping. This is the "optimization/search problem" framing the
paper suggests in Sec. VI.

Two usage levels:

* **Shared link** (the original API): ``observe_capacity(bps)`` +
  ``choose_mode()`` track one EMA'd capacity for the whole deployment —
  fine when every request rides the same simulated channel.
* **Per-request links** (continuous-batching serving): each in-flight
  request has its *own* mmWave link, so the orchestrator keeps one
  ``LinkState`` per request id — ``register(rid)``, then
  ``observe_capacity(bps, rid=rid)`` / ``choose_mode(rid=rid)`` /
  ``release(rid)``. Mode-relevance feedback (``observe_decoder_loss``)
  stays shared: decoder quality per mode is a property of the calibrated
  cascade, not of any one user's channel.

Cold start: before the first capacity observation the link quality is
*unknown*, not zero — ``choose_mode`` is optimistic and picks the most
relevant mode meeting the accuracy floor instead of silently deeming every
mode infeasible and pinning the smallest payload.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional

from repro.core.channel import tx_seconds


@dataclass
class ModeProfile:
    """Calibration entry per mode (from cascade validation)."""
    mode: int
    payload_bytes: int        # per-query boundary payload
    expected_loss: float      # validation loss of this mode
    expected_acc: float = 0.0


@dataclass
class AppRequirement:
    latency_budget_s: float = 0.05   # per-query transfer budget
    min_acc: float = 0.0             # slice-dependent floor (0 = best effort)


@dataclass
class LinkState:
    """Per-link (per-request, or shared-legacy) orchestration state."""
    mode: int = 0
    capacity_ema: float = 0.0
    switches: int = 0
    ticks: int = 0


@dataclass
class OrchestratorState(LinkState):
    """Legacy shared state; ``loss_ema`` aliases the orchestrator-wide
    relevance feedback so existing callers keep working."""
    loss_ema: Dict[int, float] = field(default_factory=dict)


class Orchestrator:
    def __init__(self, profiles: List[ModeProfile],
                 requirement: Optional[AppRequirement] = None,
                 *, ema: float = 0.8, hysteresis: float = 0.85):
        if not profiles:
            raise ValueError("need at least one mode profile")
        self.profiles = sorted(profiles, key=lambda p: p.mode)
        # a fresh instance per orchestrator: a dataclass default instance
        # would be shared (and mutated) across constructions
        self.req = (dataclasses.replace(requirement) if requirement is not None
                    else AppRequirement())
        self.ema = ema
        self.hysteresis = hysteresis
        self.state = OrchestratorState(
            mode=self.profiles[0].mode,
            loss_ema={p.mode: p.expected_loss for p in self.profiles})
        self.loss_ema = self.state.loss_ema      # shared relevance feedback
        self._links: Dict[Hashable, LinkState] = {}
        self._reqs: Dict[Hashable, AppRequirement] = {}

    # -- per-request lifecycle ------------------------------------------------
    def register(self, rid: Hashable,
                 requirement: Optional[AppRequirement] = None) -> LinkState:
        """Start tracking a request's own link (idempotent)."""
        if rid not in self._links:
            self._links[rid] = LinkState(mode=self.profiles[0].mode)
            if requirement is not None:
                self._reqs[rid] = dataclasses.replace(requirement)
        return self._links[rid]

    def release(self, rid: Hashable) -> None:
        self._links.pop(rid, None)
        self._reqs.pop(rid, None)

    def _link(self, rid: Optional[Hashable]) -> LinkState:
        if rid is None:
            return self.state
        return self.register(rid)

    def _req(self, rid: Optional[Hashable]) -> AppRequirement:
        if rid is None:
            return self.req
        return self._reqs.get(rid, self.req)

    # -- feedback signals (Fig. 3 arrows) ------------------------------------
    def observe_capacity(self, capacity_bps: float,
                         rid: Optional[Hashable] = None):
        s = self._link(rid)
        s.capacity_ema = (self.ema * s.capacity_ema
                          + (1 - self.ema) * capacity_bps
                          if s.ticks else capacity_bps)
        s.ticks += 1

    def observe_decoder_loss(self, mode: int, loss: float):
        prev = self.loss_ema.get(mode, loss)
        self.loss_ema[mode] = self.ema * prev + (1 - self.ema) * loss

    # -- decision -------------------------------------------------------------
    def feasible(self, p: ModeProfile, capacity_bps: float,
                 req: Optional[AppRequirement] = None) -> bool:
        req = req if req is not None else self.req
        return tx_seconds(p.payload_bytes, capacity_bps) \
            <= req.latency_budget_s

    def choose_mode(self, rid: Optional[Hashable] = None) -> int:
        s = self._link(rid)
        req = self._req(rid)
        cap = s.capacity_ema
        # rank by relevance (EMA loss asc); most informative feasible wins
        ranked = sorted(self.profiles, key=lambda p: self.loss_ema[p.mode])
        chosen: Optional[ModeProfile] = None
        for p in ranked:
            if req.min_acc and p.expected_acc < req.min_acc:
                continue
            # cold start: no capacity observed yet -> optimistic (the first
            # observation will correct us next tick); never pin the smallest
            # payload off a phantom zero-capacity reading
            if s.ticks == 0 or self.feasible(p, cap, req):
                chosen = p
                break
        if chosen is None:           # nothing fits: smallest payload
            chosen = min(self.profiles, key=lambda p: p.payload_bytes)
        # hysteresis: only leave the current mode if the alternative's
        # required capacity clears by a margin
        cur = next(p for p in self.profiles if p.mode == s.mode)
        if s.ticks and chosen.mode != cur.mode \
                and chosen.payload_bytes > cur.payload_bytes:
            if not self.feasible(chosen, cap * self.hysteresis, req):
                chosen = cur
        if chosen.mode != s.mode:
            s.switches += 1
            s.mode = chosen.mode
        return s.mode
