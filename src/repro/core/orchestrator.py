"""The paper's orchestrator (Fig. 3): monitors network conditions + decoder
performance feedback and instructs the encoder which latent code to transmit.

Policy: among the calibrated modes, pick the most relevant (lowest expected
loss) whose transfer latency fits the application's budget, with hysteresis
to avoid mode flapping. This is the "optimization/search problem" framing the
paper suggests in Sec. VI.

Two usage levels:

* **Shared link** (the original API): ``observe_capacity(bps)`` +
  ``choose_mode()`` track one EMA'd capacity for the whole deployment —
  fine when every request rides the same simulated channel.
* **Per-request links** (continuous-batching serving): each in-flight
  request has its *own* mmWave link, so the orchestrator keeps one
  ``LinkState`` per request id — ``register(rid)``, then
  ``observe_capacity(bps, rid=rid)`` / ``choose_mode(rid=rid)`` /
  ``release(rid)``. Mode-relevance feedback (``observe_decoder_loss``)
  stays shared: decoder quality per mode is a property of the calibrated
  cascade, not of any one user's channel.

Cold start: before the first capacity observation the link quality is
*unknown*, not zero — ``choose_mode`` is optimistic and picks the most
relevant mode meeting the accuracy floor instead of silently deeming every
mode infeasible and pinning the smallest payload.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.channel import RTT_SECONDS, tx_seconds


@dataclass
class ModeProfile:
    """Calibration entry per mode (from cascade validation)."""
    mode: int
    payload_bytes: int        # per-query boundary payload
    expected_loss: float      # validation loss of this mode
    expected_acc: float = 0.0


@dataclass
class AppRequirement:
    latency_budget_s: float = 0.05   # per-query transfer budget
    min_acc: float = 0.0             # slice-dependent floor (0 = best effort)


@dataclass
class LinkState:
    """Per-link (per-request, or shared-legacy) orchestration state."""
    mode: int = 0
    capacity_ema: float = 0.0
    switches: int = 0
    ticks: int = 0


@dataclass
class OrchestratorState(LinkState):
    """Legacy shared state; ``loss_ema`` aliases the orchestrator-wide
    relevance feedback so existing callers keep working."""
    loss_ema: Dict[int, float] = field(default_factory=dict)


class Orchestrator:
    def __init__(self, profiles: List[ModeProfile],
                 requirement: Optional[AppRequirement] = None,
                 *, ema: float = 0.8, hysteresis: float = 0.85):
        if not profiles:
            raise ValueError("need at least one mode profile")
        self.profiles = sorted(profiles, key=lambda p: p.mode)
        # a fresh instance per orchestrator: a dataclass default instance
        # would be shared (and mutated) across constructions
        self.req = (dataclasses.replace(requirement) if requirement is not None
                    else AppRequirement())
        self.ema = ema
        self.hysteresis = hysteresis
        self.state = OrchestratorState(
            mode=self.profiles[0].mode,
            loss_ema={p.mode: p.expected_loss for p in self.profiles})
        self.loss_ema = self.state.loss_ema      # shared relevance feedback
        self._links: Dict[Hashable, LinkState] = {}
        self._reqs: Dict[Hashable, AppRequirement] = {}

    # -- per-request lifecycle ------------------------------------------------
    def register(self, rid: Hashable,
                 requirement: Optional[AppRequirement] = None) -> LinkState:
        """Start tracking a request's own link (idempotent)."""
        if rid not in self._links:
            self._links[rid] = LinkState(mode=self.profiles[0].mode)
            if requirement is not None:
                self._reqs[rid] = dataclasses.replace(requirement)
        return self._links[rid]

    def release(self, rid: Hashable) -> None:
        self._links.pop(rid, None)
        self._reqs.pop(rid, None)

    def detach(self, rid: Hashable) -> Tuple[Optional[LinkState],
                                             Optional[AppRequirement]]:
        """Remove and RETURN a link's orchestration state instead of
        discarding it — the live-migration export: the capacity EWMA and
        requirement travel with the session to another orchestrator's
        :meth:`attach` so mode selection continues across the handover."""
        return self._links.pop(rid, None), self._reqs.pop(rid, None)

    def attach(self, rid: Hashable, link: Optional[LinkState],
               requirement: Optional[AppRequirement] = None) -> None:
        """Install a link state exported by :meth:`detach` (live-migration
        import). A ``None`` link leaves any existing registration alone."""
        if link is not None:
            self._links[rid] = link
        if requirement is not None:
            self._reqs[rid] = requirement

    def _link(self, rid: Optional[Hashable]) -> LinkState:
        if rid is None:
            return self.state
        return self.register(rid)

    def _req(self, rid: Optional[Hashable]) -> AppRequirement:
        if rid is None:
            return self.req
        return self._reqs.get(rid, self.req)

    # -- feedback signals (Fig. 3 arrows) ------------------------------------
    def observe_capacity(self, capacity_bps: float,
                         rid: Optional[Hashable] = None):
        s = self._link(rid)
        s.capacity_ema = (self.ema * s.capacity_ema
                          + (1 - self.ema) * capacity_bps
                          if s.ticks else capacity_bps)
        s.ticks += 1

    def observe_decoder_loss(self, mode: int, loss: float):
        prev = self.loss_ema.get(mode, loss)
        self.loss_ema[mode] = self.ema * prev + (1 - self.ema) * loss

    # -- decision -------------------------------------------------------------
    def feasible(self, p: ModeProfile, capacity_bps: float,
                 req: Optional[AppRequirement] = None) -> bool:
        req = req if req is not None else self.req
        return tx_seconds(p.payload_bytes, capacity_bps) \
            <= req.latency_budget_s

    def choose_mode(self, rid: Optional[Hashable] = None) -> int:
        s = self._link(rid)
        req = self._req(rid)
        cap = s.capacity_ema
        # rank by relevance (EMA loss asc); most informative feasible wins
        ranked = sorted(self.profiles, key=lambda p: self.loss_ema[p.mode])
        chosen: Optional[ModeProfile] = None
        for p in ranked:
            if req.min_acc and p.expected_acc < req.min_acc:
                continue
            # cold start: no capacity observed yet -> optimistic (the first
            # observation will correct us next tick); never pin the smallest
            # payload off a phantom zero-capacity reading
            if s.ticks == 0 or self.feasible(p, cap, req):
                chosen = p
                break
        if chosen is None:           # nothing fits: smallest payload
            chosen = min(self.profiles, key=lambda p: p.payload_bytes)
        # hysteresis: only leave the current mode if the alternative's
        # required capacity clears by a margin
        cur = next(p for p in self.profiles if p.mode == s.mode)
        if s.ticks and chosen.mode != cur.mode \
                and chosen.payload_bytes > cur.payload_bytes:
            if not self.feasible(chosen, cap * self.hysteresis, req):
                chosen = cur
        if chosen.mode != s.mode:
            s.switches += 1
            s.mode = chosen.mode
        return s.mode

    # -- vectorized per-tick decision (continuous-batching hot path) ----------
    def choose_modes(self, rids: Sequence[Hashable],
                     capacities: Optional[Sequence[Optional[float]]] = None,
                     hold: Optional[Sequence[bool]] = None,
                     commit: bool = True) -> np.ndarray:
        """Per-link mode selection for a whole decode batch in one shot.

        Numerically identical to calling ``observe_capacity(c, rid=r)`` +
        ``choose_mode(rid=r)`` per link, but the O(N x M) feasibility scan
        (every link against every mode profile) is one numpy broadcast
        instead of N Python loops — this is what the serving-side
        ``ModeController`` calls every engine tick.

        ``capacities``: optional per-link observation (``None`` entries skip
        the EMA update for that link). ``hold``: optional boolean mask —
        links with ``hold[i]`` keep their current mode this tick (their EMA
        still updates); the controller uses it for dwell-time suppression.
        Returns the chosen mode per link as ``int32 [N]``; with ``commit``
        (the default) each link's ``LinkState`` (mode, switch count) updates
        exactly as the scalar path does. ``commit=False`` leaves the link
        states untouched so a caller that may still override the choice
        (the controller's deadline escalation) can commit the FINAL mode
        once via :meth:`force_mode` — one counted switch per observable
        transition.
        """
        links = [self._link(r) for r in rids]
        if capacities is not None:
            for r, c in zip(rids, capacities):
                if c is not None:
                    self.observe_capacity(c, rid=r)
        caps = np.array([link.capacity_ema for link in links], np.float64)
        ticks = np.array([link.ticks for link in links], np.int64)
        cur = np.array([link.mode for link in links], np.int64)
        budgets = np.array([self._req(r).latency_budget_s for r in rids])
        min_accs = np.array([self._req(r).min_acc for r in rids])

        # rank modes by relevance (shared EMA loss, ascending) once per tick
        ranked = sorted(self.profiles, key=lambda p: self.loss_ema[p.mode])
        pay_r = np.array([p.payload_bytes for p in ranked], np.float64)
        acc_r = np.array([p.expected_acc for p in ranked])
        mode_r = np.array([p.mode for p in ranked], np.int64)

        # feasibility: [N, M] transfer latencies against per-link budgets
        tx = pay_r[None, :] / np.maximum(caps[:, None], 1.0) + RTT_SECONDS
        feasible = tx <= budgets[:, None]
        feasible[ticks == 0, :] = True          # cold start: optimistic
        ok = feasible & ((min_accs[:, None] <= 0.0)
                         | (acc_r[None, :] >= min_accs[:, None]))
        any_ok = ok.any(axis=1)
        chosen = mode_r[np.argmax(ok, axis=1)]  # most relevant feasible
        fallback = min(self.profiles, key=lambda p: p.payload_bytes).mode
        chosen = np.where(any_ok, chosen, fallback)

        # hysteresis: an upgrade (larger payload than current) must stay
        # feasible at capacity * hysteresis, else keep the current mode
        pos = {p.mode: i for i, p in enumerate(self.profiles)}
        pay_m = np.array([p.payload_bytes for p in self.profiles], np.float64)
        pay_cho = pay_m[[pos[int(m)] for m in chosen]]
        pay_cur = pay_m[[pos[int(m)] for m in cur]]
        upgrade = (ticks > 0) & (chosen != cur) & (pay_cho > pay_cur)
        tx_h = pay_cho / np.maximum(caps * self.hysteresis, 1.0) + RTT_SECONDS
        chosen = np.where(upgrade & (tx_h > budgets), cur, chosen)

        if hold is not None:
            chosen = np.where(np.asarray(hold, bool), cur, chosen)
        if commit:
            for link, m in zip(links, chosen):
                if int(m) != link.mode:
                    link.switches += 1
                    link.mode = int(m)
        return chosen.astype(np.int32)

    def force_mode(self, rid: Optional[Hashable], mode: int) -> int:
        """Set a link's mode directly (the controller's commit point after
        an uncommitted ``choose_modes`` pass, including deadline
        escalations). Counts a switch when it changes."""
        s = self._link(rid)
        if mode != s.mode:
            s.switches += 1
            s.mode = mode
        return s.mode

    def requirement_for(self, rid: Optional[Hashable] = None) -> AppRequirement:
        """The effective ``AppRequirement`` for a link: the one registered
        for ``rid``, else the orchestrator-wide default."""
        return self._req(rid)
