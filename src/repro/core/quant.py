"""Symmetric row-wise latent-code quantization for the transmitted
bottleneck payload (pure-jnp reference; the fused Pallas kernel lives in
``repro.kernels``).

int4 values are stored one-per-int8 here (the Pallas kernel packs two per
byte on TPU); ``payload_bytes`` accounts for the packed wire format either
way, since byte accounting is what the orchestrator and the roofline consume.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def qmax(bits: int) -> int:
    """127 for int8, 7 for int4 — floored at 1 so ``bits=1`` maps to the
    ternary {-1, 0, 1} code instead of a zero qmax (which made the scale
    infinite and the dequant NaN). ``bottleneck.boundary_mixed`` applies the
    same floor; the two wire paths must agree."""
    return max((1 << (bits - 1)) - 1, 1)


def quantize(x, bits: int = 8):
    """Row-wise symmetric quantization over the last dim.

    x: [..., d] float -> (codes int8 [..., d], scales fp32 [..., 1]).
    """
    if bits == 0:
        return x, None
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax(bits)
    q = jnp.clip(jnp.round(xf / scale), -qmax(bits), qmax(bits))
    return q.astype(jnp.int8), scale


def dequantize(q, scale, bits: int = 8):
    if bits == 0:
        return q
    return q.astype(jnp.float32) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quantize(x, bits: int):
    """Fake-quantize with a straight-through estimator: forward sees the
    int8-roundtripped values, backward passes gradients through unchanged.
    Used on the training path so Algorithm 1's phase-2 bottleneck (which
    sits BEFORE the wire quantizer) still receives gradients."""
    q, s = quantize(x, bits)
    return dequantize(q, s, bits).astype(x.dtype)


def _ste_fwd(x, bits):
    return ste_quantize(x, bits), None


def _ste_bwd(bits, _, g):
    return (g,)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def payload_bytes(shape, bits: int, dtype_bytes: int = 2) -> int:
    """Wire bytes for a latent of ``shape`` ([..., d]): packed codes +
    one fp16 scale per row (bits==0 -> raw bf16 payload).

    Codes pack per *row*, not per tensor: each row of ``d`` sub-byte codes
    is padded up to a whole byte (an int4 row with odd ``d`` carries a
    trailing nibble on the wire), so the orchestrator's feasibility math
    matches the real packed format.
    """
    import math
    n = math.prod(shape)
    if bits == 0:
        return n * dtype_bytes
    # bits=1 is the ternary {-1, 0, 1} code (see qmax's floor) — three
    # states cannot pack at 1 bit/value, so charge the 2-bit packing
    eff_bits = max(bits, 2)
    rows = n // shape[-1]
    return rows * math.ceil(shape[-1] * eff_bits / 8) + rows * 2


def quant_error(x, bits: int = 8) -> jnp.ndarray:
    """Mean |x - dequant(quant(x))| — used by tests and the orchestrator's
    relevance calibration."""
    q, s = quantize(x, bits)
    return jnp.mean(jnp.abs(x.astype(jnp.float32) - dequantize(q, s, bits)))
