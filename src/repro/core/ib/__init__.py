from repro.core.ib import binning, gcmi, info_plane, kde

__all__ = ["binning", "gcmi", "info_plane", "kde"]
