"""Gaussian-copula mutual information (GCMI) estimator [Ince et al. 2017],
the paper's choice for I(X;H) in sequential models (Sec. VI): robust to
multidimensional variables and marginal distributions, and extends to
conditional MI — which the paper uses to quantify temporal-state redundancy,
e.g. I(x_1..x_T ; H_T | H_{T-1}, H_{T-2}).

All quantities are returned in BITS.
"""
from __future__ import annotations

import numpy as np
from scipy.special import ndtri, psi

_LN2 = np.log(2.0)


def copula_normalize(x: np.ndarray) -> np.ndarray:
    """Rank -> standard-normal transform per column. x: [N, d]."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    ranks = np.argsort(np.argsort(x, axis=0), axis=0).astype(np.float64)
    return ndtri((ranks + 1.0) / (n + 1.0))


def _ent_g(x: np.ndarray, *, bias_correct: bool = True) -> float:
    """Differential entropy (bits) of multivariate Gaussian fit to x [N,d]."""
    n, d = x.shape
    c = np.cov(x, rowvar=False).reshape(d, d)
    # regularize for near-singular covariances
    c = c + 1e-10 * np.eye(d)
    sign, logdet = np.linalg.slogdet(c)
    h = 0.5 * (d * np.log(2 * np.pi * np.e) + logdet)
    if bias_correct and n > d + 1:
        # Ince et al. 2017: E[log det(sample cov)] differs from
        # log det(true cov) by sum_i psi((n-i)/2) - d*log((n-1)/2).
        h += 0.5 * (sum(psi((n - i) / 2.0) for i in range(1, d + 1))
                    - d * np.log((n - 1) / 2.0))
    return h / _LN2


def mi_gg(x: np.ndarray, y: np.ndarray, *, bias_correct: bool = True) -> float:
    """Gaussian MI I(X;Y) in bits. x: [N,dx], y: [N,dy] (already Gaussian)."""
    x = np.atleast_2d(x.T).T
    y = np.atleast_2d(y.T).T
    xy = np.concatenate([x, y], axis=1)
    return max(_ent_g(x, bias_correct=bias_correct)
               + _ent_g(y, bias_correct=bias_correct)
               - _ent_g(xy, bias_correct=bias_correct), 0.0)


def gcmi_cc(x: np.ndarray, y: np.ndarray) -> float:
    """Copula MI between continuous multivariates (lower bound on true MI)."""
    return mi_gg(copula_normalize(x), copula_normalize(y))


def cmi_ggg(x: np.ndarray, y: np.ndarray, z: np.ndarray) -> float:
    """Gaussian conditional MI I(X;Y|Z) in bits."""
    x, y, z = (np.atleast_2d(a.T).T for a in (x, y, z))
    xz = np.concatenate([x, z], axis=1)
    yz = np.concatenate([y, z], axis=1)
    xyz = np.concatenate([x, y, z], axis=1)
    v = (_ent_g(xz) + _ent_g(yz) - _ent_g(z) - _ent_g(xyz))
    return max(v, 0.0)


def gccmi_ccc(x: np.ndarray, y: np.ndarray, z: np.ndarray) -> float:
    """Copula conditional MI (continuous x, y, z)."""
    return cmi_ggg(copula_normalize(x), copula_normalize(y),
                   copula_normalize(z))


def gcmi_model_cd(x: np.ndarray, y: np.ndarray, n_classes: int) -> float:
    """I(X;Y) for continuous X, discrete Y: copula-normalize X then
    class-conditional Gaussian mixture formula. y: [N] ints."""
    cx = copula_normalize(x)
    n, d = cx.shape
    h_x = _ent_g(cx)
    h_cond = 0.0
    for c in range(n_classes):
        idx = y == c
        k = int(idx.sum())
        if k < d + 2:     # not enough samples to fit a class covariance
            continue
        h_cond += (k / n) * _ent_g(cx[idx])
    return max(h_x - h_cond, 0.0)
