"""Binning MI estimator — the original IB-papers baseline [Tishby/Shwartz-Ziv].

The paper notes binning is sensitive to bin size (Sec. VI); it is kept here
as the reference estimator the robust ones (KDE/GCMI) are compared against,
exactly mirroring the literature's methodology.
"""
from __future__ import annotations

from collections import Counter
from typing import Tuple

import numpy as np

_LN2 = np.log(2.0)


def _digitize(t: np.ndarray, n_bins: int) -> np.ndarray:
    lo, hi = t.min(), t.max()
    if hi - lo < 1e-12:
        return np.zeros_like(t, dtype=np.int32)
    edges = np.linspace(lo, hi, n_bins + 1)[1:-1]
    return np.digitize(t, edges).astype(np.int32)


def _discrete_entropy(rows: np.ndarray) -> float:
    """Entropy (bits) of the empirical distribution over row patterns."""
    counts = Counter(map(bytes, np.ascontiguousarray(rows)))
    n = rows.shape[0]
    p = np.array(list(counts.values()), dtype=np.float64) / n
    return float(-np.sum(p * np.log(p)) / _LN2)


def bin_mi_tx(t: np.ndarray, n_bins: int = 30) -> float:
    """I(T;X) = H(T_binned) for deterministic T=f(X)."""
    return _discrete_entropy(_digitize(np.asarray(t), n_bins))


def bin_mi_ty(t: np.ndarray, y: np.ndarray, n_classes: int,
              n_bins: int = 30) -> float:
    t = _digitize(np.asarray(t), n_bins)
    h_t = _discrete_entropy(t)
    n = t.shape[0]
    h_cond = 0.0
    for c in range(n_classes):
        idx = y == c
        if idx.sum() < 1:
            continue
        h_cond += (idx.sum() / n) * _discrete_entropy(t[idx])
    return max(h_t - h_cond, 0.0)
