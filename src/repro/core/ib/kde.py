"""Kolchinsky–Tracey pairwise-distance KDE bound on mixture entropy
[Entropy 2017; used by Saxe et al. 2019 and by the paper for I(H;Y)].

Model: the layer activation T is taken as T + N(0, noise_var I) (the standard
trick that makes MI finite for deterministic networks). The entropy of the
resulting Gaussian mixture is bounded with the pairwise KL (upper) /
Bhattacharyya (lower) distance bounds; MI follows as

  I(T;X) = H(T) - H(T|X) = H_mix(T) - d/2 log(2 pi e sigma^2)
  I(T;Y) = H_mix(T) - sum_y p(y) H_mix(T | Y=y)

Returned in BITS.
"""
from __future__ import annotations

import numpy as np

_LN2 = np.log(2.0)


def _pairwise_sq_dists(t: np.ndarray, max_n: int = 2048,
                       rng: np.random.Generator | None = None) -> np.ndarray:
    if t.shape[0] > max_n:
        rng = rng or np.random.default_rng(0)
        t = t[rng.choice(t.shape[0], max_n, replace=False)]
    sq = np.sum(t * t, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (t @ t.T)
    return np.maximum(d2, 0.0)


def mixture_entropy_upper(t: np.ndarray, noise_var: float,
                          max_n: int = 2048) -> float:
    """KL-distance upper bound on H(T + noise), bits. t: [N, d]."""
    t = np.asarray(t, dtype=np.float64)
    n, d = t.shape
    d2 = _pairwise_sq_dists(t, max_n)
    n_eff = d2.shape[0]
    # -mean_i log mean_j exp(-KL_ij), KL_ij = ||ti-tj||^2 / (2 sigma^2)
    logits = -d2 / (2.0 * noise_var)
    lse = np.logaddexp.reduce(logits, axis=1) - np.log(n_eff)
    h_pairwise = -np.mean(lse)
    h_component = 0.5 * d * np.log(2 * np.pi * np.e * noise_var)
    return (h_pairwise + h_component) / _LN2


def mi_tx(t: np.ndarray, noise_var: float = 0.1, max_n: int = 2048) -> float:
    """I(T; X) for deterministic T = f(X) under additive Gaussian noise."""
    t = np.asarray(t, dtype=np.float64)
    d = t.shape[1]
    h_t = mixture_entropy_upper(t, noise_var, max_n)
    h_t_given_x = 0.5 * d * np.log(2 * np.pi * np.e * noise_var) / _LN2
    return max(h_t - h_t_given_x, 0.0)


def mi_ty(t: np.ndarray, y: np.ndarray, n_classes: int,
          noise_var: float = 0.1, max_n: int = 2048) -> float:
    """I(T; Y) with discrete labels y [N]."""
    t = np.asarray(t, dtype=np.float64)
    n = t.shape[0]
    h_t = mixture_entropy_upper(t, noise_var, max_n)
    h_cond = 0.0
    for c in range(n_classes):
        idx = y == c
        k = int(idx.sum())
        if k < 2:
            continue
        h_cond += (k / n) * mixture_entropy_upper(t[idx], noise_var, max_n)
    return max(h_t - h_cond, 0.0)
