"""Information-plane and temporal-information analysis (paper Figs. 1, 7-9).

Estimator assignment follows Sec. VI: GCMI for I(X;H) (robust to
multidimensional variables), Kolchinsky KDE for I(H;Y), and the GCMI
conditional-MI extension for the temporal-redundancy analysis that justifies
truncating H^(1) to its last few temporal states (paper Eq. 3).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.ib import gcmi, kde

# PCA cap applied before covariance-based estimation; the paper's point that
# "estimating the MI can be challenging due to the large hidden temporal
# states" is exactly this — we reduce dimensions the same way it reduces
# temporal states (Eq. 3).
_MAX_DIM = 32


def _reduce(x: np.ndarray, max_dim: int = _MAX_DIM) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    if x.shape[1] <= max_dim:
        return x
    xc = x - x.mean(0)
    # SVD-based PCA (deterministic)
    _, _, vt = np.linalg.svd(xc, full_matrices=False)
    return xc @ vt[:max_dim].T


def layer_point(h: np.ndarray, x: np.ndarray, y: np.ndarray,
                n_classes: int, noise_var: float = 0.1) -> Dict[str, float]:
    """One information-plane point for a layer activation h.

    h: [N, ...] flattened per sample; x: [N, ...]; y: [N] ints.
    """
    hr, xr = _reduce(h), _reduce(x)
    return {
        "I_XH": gcmi.gcmi_cc(xr, hr),
        "I_HY": kde.mi_ty(hr, y, n_classes, noise_var),
    }


def information_plane(acts_by_epoch: Sequence[Dict[str, np.ndarray]],
                      x: np.ndarray, y: np.ndarray, layer_names: List[str],
                      n_classes: int) -> Dict[str, List[Dict[str, float]]]:
    """Per-epoch, per-layer (I(X;H), I(H;Y)) trajectories (Figs. 1/9)."""
    out: Dict[str, List[Dict[str, float]]] = {name: [] for name in layer_names}
    for acts in acts_by_epoch:
        for name in layer_names:
            out[name].append(layer_point(acts[name], x, y, n_classes))
    return out


def temporal_curves(acts_by_epoch: Sequence[np.ndarray], x: np.ndarray,
                    y_tau: np.ndarray, n_classes: int) -> Dict[str, np.ndarray]:
    """The 3-D information curves (Figs. 7-8).

    acts_by_epoch: sequence over epochs of H^{(1)} activations [N, T, cells].
    x: [N, T, D] inputs; y_tau: [N] the label at the probe timestep tau.
    Returns I_HtY [epochs, T] = I(H_t; y_tau) and
            I_XH  [epochs, T] = I(x_1..x_t ; H_1..H_t).
    """
    E = len(acts_by_epoch)
    T = acts_by_epoch[0].shape[1]
    i_hty = np.zeros((E, T))
    i_xh = np.zeros((E, T))
    for e, h in enumerate(acts_by_epoch):
        for t in range(T):
            i_hty[e, t] = kde.mi_ty(_reduce(h[:, t]), y_tau, n_classes)
            i_xh[e, t] = gcmi.gcmi_cc(_reduce(x[:, :t + 1]),
                                      _reduce(h[:, :t + 1]))
    return {"I_HtY": i_hty, "I_XH": i_xh}


def temporal_redundancy(h1: np.ndarray, x: np.ndarray,
                        max_condition: int = 3) -> List[float]:
    """Conditional-MI redundancy ladder (paper Sec. VI):
    [ I(X; H_T | H_{T-1}), I(X; H_T | H_{T-1}, H_{T-2}), ... ].

    h1: [N, T, cells]; x: [N, T, D].
    """
    T = h1.shape[1]
    xf = _reduce(x)
    hT = _reduce(h1[:, T - 1])
    out = []
    for k in range(1, max_condition + 1):
        cond = _reduce(h1[:, T - 1 - k:T - 1])
        out.append(gcmi.gccmi_ccc(xf, hT, cond))
    return out


def compression_onset(i_xh_by_epoch: np.ndarray) -> int:
    """Epoch index where I(X;H) peaks (fitting->compression transition)."""
    return int(np.argmax(np.asarray(i_xh_by_epoch)))
