import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without real
hardware.

For every (architecture x input shape x mesh) combination this lowers and
compiles the appropriate step program against ShapeDtypeStruct stand-ins
(no allocation), then records memory_analysis / cost_analysis / the
collective schedule parsed from the post-SPMD HLO into
``results/dryrun/<arch>__<shape>__<mesh>[__<variant>].json``.

Variants:
  baseline   - standard pjit step (TP over 'model', DP/FSDP over 'data'(+pod))
  pipeline0  - 2-stage pod pipeline, raw bf16 boundary (paper mode z)
  pipeline1  - 2-stage pod pipeline, bottleneck+int8 boundary (paper mode z')
  pipeline2  - pipeline1 + int8 BACKWARD wire (beyond paper, §Perf pair C)
  qtp0/qtp8  - manual Megatron-SP prefill, bf16 / int8-quantized gathers
               (beyond paper, §Perf pair A)
The pipeline variants exist only for multi-pod train/prefill of homogeneous
archs — they are the paper's technique at pod scale. Placement knobs:
--act-policy seq|batch|batch2d, --tp-scope all|ffn, --moe-ep.

NOTE: the XLA_FLAGS line above must run before ANY other import (jax locks
the device count on first init). Do not set this flag globally.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import split as SP
from repro.data.tokens import token_batch_shapes
from repro.launch import analytic, roofline
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import sharding
from repro.models import transformer as T
from repro.training import loop as train_loop
from repro.training import optimizer as opt

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# full-attention archs skip long_500k (sub-quadratic required); see DESIGN.md
LONG_CTX_ARCHS = ("mixtral-8x7b", "recurrentgemma-2b", "xlstm-125m")


def pair_supported(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CTX_ARCHS
    return True


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, sc: ShapeConfig, mesh,
                act_policy: str = "seq") -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one batch (weak-type-correct,
    shardable, no device allocation)."""
    out = {}
    for name, shape in token_batch_shapes(cfg, sc.global_batch, sc.seq_len,
                                          sc.kind).items():
        dtype = jnp.float32 if name == "embeddings" else jnp.int32
        spec = sharding.batch_pspec(mesh, len(shape), sc.global_batch,
                                    act_policy)
        out[name] = _sds(shape, dtype, mesh, spec)
    return out


def abstract_params(cfg: ModelConfig, mesh, tp_scope: str = "all"):
    shapes = jax.eval_shape(
        lambda k: SP.init_split_params(k, cfg), jax.random.PRNGKey(0))
    specs = sharding.param_pspecs(shapes, mesh,
                                  stacked_layers=cfg.homogeneous,
                                  tp_scope=tp_scope)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs), specs


def abstract_opt_state(params_abs, mesh):
    def f32_like(s):
        return _sds(s.shape, jnp.float32, mesh, s.sharding.spec)
    m = jax.tree.map(f32_like, params_abs)
    v = jax.tree.map(f32_like, params_abs)
    step = _sds((), jnp.int32, mesh, P())
    return opt.AdamState(step=step, m=m, v=v)


def abstract_decode_state(cfg: ModelConfig, sc: ShapeConfig, mesh,
                          kv_bits: int = 0):
    shapes = jax.eval_shape(
        lambda: T.init_decode_state(cfg, sc.global_batch, sc.seq_len,
                                    kv_bits))
    specs = sharding.state_pspecs(shapes, mesh, sc.global_batch,
                                  stacked=cfg.homogeneous)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


# ---------------------------------------------------------------------------
# step builders per shape kind
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, sc: ShapeConfig, mesh, variant: str,
               seq_shard: bool = True, act_policy: Optional[str] = None,
               tp_scope: str = "all", moe_ep: bool = False,
               kv_bits: int = 0):
    tcfg = TrainConfig()
    policy = act_policy or ("seq" if seq_shard else "batch")
    if sc.kind == "train":
        use_pipe = variant.startswith("pipeline")
        mode = int(variant[-1]) if use_pipe else None
        bwd_bits = 0
        if use_pipe and mode == 2:        # pipeline2 = mode-1 + int8 bwd wire
            mode, bwd_bits = 1, 8
        step = train_loop.make_train_step(
            cfg, tcfg, mode=mode, mesh=mesh, use_pipeline=use_pipe,
            n_micro=4, act_policy=policy, moe_ep=moe_ep, bwd_bits=bwd_bits)
        params_abs, _ = abstract_params(cfg, mesh, tp_scope)
        opt_abs = abstract_opt_state(params_abs, mesh)
        batch_abs = input_specs(cfg, sc, mesh, policy)
        return jax.jit(step), (params_abs, opt_abs, batch_abs)

    if sc.kind == "prefill":
        use_pipe = variant.startswith("pipeline")
        use_qtp = variant.startswith("qtp")
        mode = int(variant[-1]) if (use_pipe or use_qtp) else None
        rules = sharding.default_activation_rules(mesh, act_policy=policy,
                                                   moe_ep=moe_ep)

        def prefill(params, batch):
            with sharding.activation_rules(mesh, rules):
                if use_pipe:
                    from repro.core import pipeline as PL
                    logits, _ = PL.pipeline_forward(
                        params, batch["tokens"], cfg, mesh=mesh, n_micro=4,
                        mode=mode, embeddings=batch.get("embeddings"))
                elif use_qtp:
                    from repro.core import qtp as QTP
                    logits = QTP.qtp_forward(
                        params, batch["tokens"], cfg, mesh=mesh, bits=mode,
                        embeddings=batch.get("embeddings"))
                else:
                    logits, _ = T.forward(
                        params, batch["tokens"], cfg,
                        embeddings=batch.get("embeddings"))
            return logits

        params_abs, _ = abstract_params(cfg, mesh, tp_scope)
        batch_abs = input_specs(cfg, sc, mesh, policy)
        return jax.jit(prefill), (params_abs, batch_abs)

    # decode: ONE new token against a seq_len-deep state
    def serve_step(params, token, states, cur_pos):
        logits, new_states = T.decode_step(params, token, states, cur_pos,
                                           cfg)
        return logits, new_states

    params_abs, _ = abstract_params(cfg, mesh, tp_scope)
    tok_shapes = token_batch_shapes(cfg, sc.global_batch, sc.seq_len, "decode")
    tok_abs = _sds(tok_shapes["tokens"], jnp.int32, mesh,
                   sharding.batch_pspec(mesh, len(tok_shapes["tokens"]),
                                        sc.global_batch))
    states_abs = abstract_decode_state(cfg, sc, mesh, kv_bits)
    pos_abs = _sds((), jnp.int32, mesh, P())
    return jax.jit(serve_step), (params_abs, tok_abs, states_abs, pos_abs)


# ---------------------------------------------------------------------------
# run one combination
# ---------------------------------------------------------------------------

def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            variant: str = "baseline", seq_shard: bool = True,
            act_policy: Optional[str] = None, tp_scope: str = "all",
            moe_ep: bool = False, kv_bits: int = 0,
            save: bool = True, verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    sc = get_shape(shape)
    if not pair_supported(arch, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic decode (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    step, args = build_step(cfg, sc, mesh, variant, seq_shard, act_policy,
                            tp_scope, moe_ep, kv_bits)
    with mesh_context(mesh):
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo)
    coll_bytes = int(sum(v["bytes"] for v in coll.values()))

    # analytic FLOPs/bytes (XLA's cost_analysis counts while-loop bodies
    # once, undercounting everything under lax.scan — see launch/analytic.py)
    flops_dev = analytic.step_flops(cfg, sc) / chips
    bytes_model = analytic.step_hbm_bytes(cfg, sc, chips,
                                          kv_bits=kv_bits)
    hbm_bytes = bytes_model.total
    terms = roofline.roofline_terms(flops_dev, hbm_bytes, coll_bytes, chips)

    toks = sc.global_batch * (1 if sc.kind == "decode" else sc.seq_len)
    n_active = cfg.active_param_count()
    mf = roofline.model_flops_per_step(
        n_active, toks, "train" if sc.kind == "train" else "inference")
    policy = act_policy or ("seq" if seq_shard else "batch")
    result = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "seq_shard": policy == "seq", "act_policy": policy,
        "tp_scope": tp_scope, "moe_ep": moe_ep, "kv_bits": kv_bits,
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": hbm_bytes,
        "hbm_bytes_breakdown": dataclasses.asdict(bytes_model),
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "roofline": terms,
        "model_flops": mf,
        "useful_ratio": roofline.useful_ratio(mf, flops_dev, chips),
        "raw_cost_analysis": {k: float(v) for k, v in cost.items()
                              if k in ("flops", "bytes accessed")},
        "memory_analysis": _mem_dict(mem),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if verbose:
        ma = result["memory_analysis"]
        print(f"[dryrun] {arch} x {shape} x {result['mesh']} ({variant}): "
              f"compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"-> {terms['dominant']}  "
              f"useful {result['useful_ratio']:.2f}  "
              f"argbytes/dev {ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp {ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        tag = f"{arch}__{shape}__{result['mesh'].replace('x','_')}"
        if variant != "baseline":
            tag += f"__{variant}"
        if policy == "batch":
            tag += "__noseqshard"
        elif policy != "seq":
            tag += f"__{policy}"
        if tp_scope != "all":
            tag += f"__tp{tp_scope}"
        if moe_ep:
            tag += "__ep"
        if kv_bits:
            tag += f"__kv{kv_bits}"
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def _mem_dict(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + ["all"])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + ["all"])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "pipeline0", "pipeline1",
                             "pipeline2", "qtp0", "qtp8"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--act-policy", default=None,
                    choices=["seq", "batch", "batch2d"])
    ap.add_argument("--tp-scope", default="all", choices=["all", "ffn"])
    ap.add_argument("--kv-bits", type=int, default=0, choices=[0, 8],
                    help="int8 KV cache for decode shapes")
    ap.add_argument("--moe-ep", action="store_true",
                    help="shard_map expert-parallel MoE (requires "
                         "E %% model == 0 and batch %% chips == 0)")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, multi_pod=mp, variant=args.variant,
                            seq_shard=not args.no_seq_shard,
                            act_policy=args.act_policy,
                            tp_scope=args.tp_scope, moe_ep=args.moe_ep,
                            kv_bits=args.kv_bits,
                            save=not args.no_save)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"[dryrun] FAIL {arch} x {shape} "
                          f"multipod={mp}: {e!r}")
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
