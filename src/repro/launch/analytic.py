"""Analytic FLOP / HBM-byte models per (arch x shape), used as the corrected
roofline numerator.

WHY NOT cost_analysis alone: XLA's HloCostAnalysis counts a while-loop body
ONCE, so anything under ``lax.scan`` (our layer stacks, time scans, blocked
attention) is undercounted by the trip count — stablelm's reported FLOPs came
out 12x below 6ND, which is physically impossible. The dry-run JSON keeps the
raw cost_analysis numbers for transparency; the roofline table uses these
first-principles formulas (documented below, validated against cost_analysis
on unrolled reduced configs in tests/test_analytic.py).

All formulas are FORWARD per-token per-layer; the step-level functions apply
the standard multipliers (train = fwd + 2x bwd + ~1x remat recompute = 4x
layers, 3x head; prefill = 1x; decode = 1x with T_eff = cache length).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

CAPACITY_FACTOR = 1.25     # must match models.moe default
_MLSTM_PF = 2.0
_SLSTM_PF = 4.0 / 3.0


# ---------------------------------------------------------------------------
# per-layer forward FLOPs per token
# ---------------------------------------------------------------------------

def _attn_layer_flops(cfg: ModelConfig, t_eff: int, group_n: int) -> float:
    d, hd, nq, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * hd * (nq + 2 * nkv) + 2 * nq * hd * d
    attn = 4 * t_eff * nq * hd          # qk^T + pv (full blocks, see DESIGN)
    return proj + attn + _mlp_flops(cfg, group_n)


def _mlp_flops(cfg: ModelConfig, group_n: int) -> float:
    if cfg.d_ff == 0:
        return 0.0
    if cfg.is_moe:
        k = cfg.experts_per_tok
        expert = 6 * cfg.d_model * cfg.d_ff * k
        # dispatch + combine einsums: 2 x (2 * E*C * d) per token,
        # E*C = k * cf * group_n
        dispatch = 4 * (k * CAPACITY_FACTOR * group_n) * cfg.d_model
        router = 2 * cfg.d_model * cfg.n_experts
        return expert + dispatch + router
    return 6 * cfg.d_model * cfg.d_ff


def _rglru_layer_flops(cfg: ModelConfig, group_n: int) -> float:
    d = cfg.d_model
    dr = cfg.d_rnn or d
    branches = 2 * 2 * d * dr            # in_gate + in_rec
    gates = 2 * 2 * dr * dr              # w_a + w_x
    conv_scan = 10 * dr                  # conv(4 taps) + recurrence update
    out = 2 * dr * d
    return branches + gates + conv_scan + out + _mlp_flops(cfg, group_n)


def _mlstm_layer_flops(cfg: ModelConfig) -> float:
    d = cfg.d_model
    di = int(_MLSTM_PF * d)
    hd = di // cfg.n_heads
    up = 2 * 2 * d * di
    qkv = 3 * 2 * di * di
    rec = 5 * di * hd                    # C update + Cq readout per head
    down = 2 * di * d
    return up + qkv + rec + down


def _slstm_layer_flops(cfg: ModelConfig) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    di = int(_SLSTM_PF * d)
    gates = 4 * 2 * d * d
    rec = 4 * 2 * d * hd                 # block-diagonal R per gate
    mlp = 2 * 2 * d * di + 2 * di * d
    return gates + rec + mlp


def fwd_flops_per_token(cfg: ModelConfig, t_eff: int, group_n: int) -> float:
    total = 0.0
    for layer in range(cfg.n_layers):
        kind = cfg.block_kind(layer)
        if kind == "attn":
            w = cfg.sliding_window or cfg.local_window
            total += _attn_layer_flops(cfg, min(t_eff, w) if w else t_eff,
                                       group_n)
        elif kind == "rglru":
            total += _rglru_layer_flops(cfg, group_n)
        elif kind == "mlstm":
            total += _mlstm_layer_flops(cfg)
        elif kind == "slstm":
            total += _slstm_layer_flops(cfg)
    return total


def head_flops_per_token(cfg: ModelConfig) -> float:
    k = cfg.n_codebooks if cfg.frontend == "audio" else 1
    return 2 * cfg.d_model * cfg.vocab_size * k


# ---------------------------------------------------------------------------
# step-level totals
# ---------------------------------------------------------------------------

def step_flops(cfg: ModelConfig, sc: ShapeConfig) -> float:
    """Total (all-chip) FLOPs for one step of this shape."""
    if sc.kind == "decode":
        toks = sc.global_batch
        body = fwd_flops_per_token(cfg, sc.seq_len, group_n=1)
        return toks * (body + head_flops_per_token(cfg))
    toks = sc.global_batch * sc.seq_len
    body = fwd_flops_per_token(cfg, sc.seq_len, group_n=sc.seq_len)
    head = head_flops_per_token(cfg)
    if sc.kind == "train":
        return toks * (4.0 * body + 3.0 * head)
    return toks * (body + head)


@dataclass
class BytesModel:
    params: float
    activations: float
    kv_cache: float
    optimizer: float

    @property
    def total(self) -> float:
        return self.params + self.activations + self.kv_cache + self.optimizer


def step_hbm_bytes(cfg: ModelConfig, sc: ShapeConfig, chips: int,
                   model_shard: int = 16, kv_bits: int = 0) -> BytesModel:
    """Per-DEVICE HBM traffic for one step (coarse, documented model):

    - params: each device reads its TP shard of every weight once per pass
      (train: fwd + remat-fwd + bwd = 3 passes, bf16), MoE scaled to active
      experts' share of traffic (all experts touched across the batch).
    - optimizer: adam m/v read+write fp32 + param shard read+write (train).
    - activations: ~12 resident tensor passes of [tokens_dev, d] per layer
      (norms, projections in/out, residual adds) + blocked-attention KV
      re-reads (S / block_q passes over K,V per batch row).
    - kv_cache (decode): read full cache shard + write one slot per layer.
    """
    P = cfg.param_count()
    dev_tokens = (sc.global_batch * (1 if sc.kind == "decode" else sc.seq_len)
                  ) / max(chips // model_shard, 1)
    p_shard = 2.0 * P / model_shard            # bf16 bytes per full TP pass
    d = cfg.d_model

    if sc.kind == "decode":
        params = p_shard                        # one forward pass
        act = 12 * dev_tokens * d * 2 * cfg.n_layers
        kv = 0.0
        for layer in range(cfg.n_layers):
            kind = cfg.block_kind(layer)
            if kind == "attn":
                w = cfg.sliding_window or cfg.local_window
                t = min(sc.seq_len, w) if w else sc.seq_len
                # bytes/elt: bf16 = 2; int8 cache = 1 + scales (4/hd per elt)
                bpe = 2.0 if kv_bits == 0 else \
                    kv_bits / 8.0 + 4.0 / cfg.head_dim
                kv += (sc.global_batch / max(chips // model_shard, 1)) * \
                    t * cfg.n_kv_heads * cfg.head_dim * bpe * 2 / \
                    (model_shard if cfg.n_kv_heads % model_shard == 0 else 1)
            elif kind == "mlstm":
                di = int(_MLSTM_PF * d)
                hd = di // cfg.n_heads
                kv += sc.global_batch / max(chips // model_shard, 1) * \
                    cfg.n_heads * hd * hd * 4 * 2
        return BytesModel(params, act, kv, 0.0)

    passes = 3.0 if sc.kind == "train" else 1.0
    params = passes * p_shard
    opt = (20.0 * P / chips) if sc.kind == "train" else 0.0   # m,v rw + p rw
    act_passes = 12 * (4 if sc.kind == "train" else 1)
    act = act_passes * dev_tokens * d * 2 * cfg.n_layers
    # blocked attention K/V re-reads
    attn_layers = sum(1 for i in range(cfg.n_layers)
                      if cfg.block_kind(i) == "attn")
    if attn_layers and sc.seq_len >= 2048:
        n_qblocks = sc.seq_len / 512
        rows_dev = sc.global_batch / max(chips // model_shard, 1)
        kv_bytes = (sc.seq_len * cfg.n_kv_heads * cfg.head_dim * 2 * 2
                    / model_shard)
        act += attn_layers * rows_dev * n_qblocks * kv_bytes * \
            (4 if sc.kind == "train" else 1)
    return BytesModel(params, act, 0.0, opt)
