"""Production serving launcher: requests through the split engine with the
orchestrator picking the transmit mode from simulated mmWave channels (the
paper's Fig. 3/5 loop, runnable end to end).

    # synchronous static batch (legacy engine)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 4 --prompt-len 16 --gen 32
    # continuous batching: per-request channels, per-slot bottleneck modes
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --engine continuous --requests 16 --n-slots 4 --arrival-every 2

Policies (sync engine):
  orchestrator  paper's dynamic policy (channel + loss feedback, hysteresis)
  static0       always mode 0 (raw boundary, most informative)
  static1       always mode 1 (bottleneck z', cheapest)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core import bottleneck
from repro.core import split as SP
from repro.core.channel import Channel, ChannelConfig, channel_fleet
from repro.core.orchestrator import AppRequirement, ModeProfile, Orchestrator
from repro.data import tokens
from repro.models import transformer as T
from repro.serving import (ContinuousBatchingEngine, ControllerConfig,
                           ModeController, Request, ServingEngine)
from repro.training import checkpoint


def build_orchestrator(cfg, batch: int, latency_budget_s: float,
                       *, hysteresis: float = 0.85):
    """Mode profiles from the analytic payload model (calibration stands in
    for the cascade validation losses on untrained smoke weights)."""
    profiles = []
    for m in range(cfg.split.n_modes):
        pb = bottleneck.mode_payload_bytes(cfg, batch, 1, m)
        profiles.append(ModeProfile(mode=m, payload_bytes=pb,
                                    expected_loss=float(m)))  # DPI ordering
    return Orchestrator(profiles,
                        AppRequirement(latency_budget_s=latency_budget_s),
                        hysteresis=hysteresis)


def run_continuous(args, cfg, params):
    orch = build_orchestrator(cfg, 1, args.latency_budget_ms / 1e3,
                              hysteresis=1.0)
    chans = channel_fleet(
        args.requests,
        ChannelConfig(mean_mbps=args.mean_mbps, std_mbps=args.mean_mbps / 2,
                      blockage_prob=0.06, recovery_prob=0.2,
                      seed=args.channel_seed),
        seed=args.channel_seed, mean_spread=0.9)
    src = tokens.MarkovTokenSource(cfg, seed=7)
    batch = src.batch(args.requests, args.prompt_len)["tokens"]
    reqs = [Request(rid=i, prompt=np.asarray(batch[i]),
                    max_new_tokens=args.gen, channel=chans[i],
                    arrival_tick=i * args.arrival_every)
            for i in range(args.requests)]
    kw = {}
    if args.mode_policy == "adaptive":
        kw["controller"] = ModeController(
            orch, ControllerConfig(dwell_ticks=args.dwell_ticks))
    else:
        kw["orchestrator"] = orch
        kw["freeze_modes"] = args.mode_policy == "frozen"
    eng = ContinuousBatchingEngine(params, cfg, n_slots=args.n_slots,
                                   cache_len=args.cache_len, **kw)
    # warm the compiled prefill/decode paths (every prefill batch bucket)
    # so decode_tok_per_s measures steady-state serving — the sync engine
    # likewise excludes its one-time prefill/trace cost from the decode rate
    eng.warm(np.asarray(batch[0]))

    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0
    st = eng.stats()
    return {
        "engine": "continuous",
        "n_slots": args.n_slots,
        "decode_tok_per_s": round(st["decode_tokens"] / max(wall, 1e-9), 1),
        "per_request": [s.result() for s in done[:4]],
        **st,
    }


def run_sync(args, cfg, params):
    orch = None
    if args.policy == "orchestrator":
        orch = build_orchestrator(cfg, args.requests,
                                  args.latency_budget_ms / 1e3)
    eng = ServingEngine(params, cfg, cache_len=args.cache_len,
                        batch=args.requests, orchestrator=orch)

    # batched request prompts
    src = tokens.MarkovTokenSource(cfg, seed=7)
    prompt = jnp.asarray(
        src.batch(args.requests, args.prompt_len)["tokens"])
    chan = Channel(ChannelConfig(seed=args.channel_seed))

    t0 = time.time()
    logits = eng.prefill(prompt)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    if args.policy.startswith("static"):
        if T.full_attention_arch(cfg) and \
                eng.pos + args.gen > args.cache_len:
            # same cache-wraparound guard ServingEngine.decode_tokens
            # applies on the orchestrator path
            raise ValueError(
                f"--gen {args.gen} from pos {eng.pos} exceeds --cache-len "
                f"{args.cache_len} on a full-attention arch")
        mode = int(args.policy[-1])
        out, wire = [], 0
        tok = first
        for _ in range(args.gen):
            logits, eng.states, pb = SP.split_decode_step(
                params, tok, eng.states, jnp.int32(eng.pos), cfg, mode=mode)
            eng.pos += 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
            wire += int(pb)
        gen = np.concatenate(out, axis=-1)
        stats = {"tokens": int(gen.size), "wire_bytes": wire,
                 "mode_counts": {mode: args.gen}}
    else:
        gen = eng.decode_tokens(first, args.gen, capacity_bps_fn=chan.step)
        stats = {"tokens": eng.stats.tokens,
                 "wire_bytes": eng.stats.wire_bytes,
                 "mode_counts": eng.stats.mode_counts,
                 "mode_switches": orch.state.switches}
    t_total = time.time() - t0

    toks = args.requests * args.gen
    return {
        "engine": "sync", "policy": args.policy,
        "prefill_s": round(t_prefill, 2),
        "decode_tok_per_s": round(toks / max(t_total - t_prefill, 1e-9), 1),
        "wire_bytes_per_token": stats["wire_bytes"] / max(toks, 1),
        **stats,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "continuous"])
    ap.add_argument("--requests", type=int, default=4,
                    help="number of requests (sync: the batch size)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--policy", default="orchestrator",
                    choices=["orchestrator", "static0", "static1"])
    ap.add_argument("--latency-budget-ms", type=float, default=5.0)
    ap.add_argument("--channel-seed", type=int, default=0)
    ap.add_argument("--n-slots", type=int, default=4,
                    help="continuous engine: decode slot pool size")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="continuous engine: ticks between request arrivals")
    ap.add_argument("--mode-policy", default="pertick",
                    choices=["pertick", "adaptive", "frozen"],
                    help="continuous engine: per-tick orchestrator loop "
                         "(legacy), adaptive ModeController (dwell + "
                         "deadline escalation), or admission-frozen modes")
    ap.add_argument("--dwell-ticks", type=int, default=2,
                    help="adaptive policy: min ticks between mode switches")
    ap.add_argument("--mean-mbps", type=float, default=40.0,
                    help="continuous engine: fleet mean uplink")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"== launch.serve {args.arch} "
          f"({'reduced' if args.reduced else 'FULL'}) "
          f"engine={args.engine} requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen} ==")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = checkpoint.restore(args.ckpt, params)
        print(f"loaded weights from {args.ckpt}")

    summary = (run_continuous if args.engine == "continuous"
               else run_sync)(args, cfg, params)
    summary = {"arch": args.arch, **summary}
    print(json.dumps(summary, indent=1, default=str))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summary, f, indent=1, default=str)
    return summary


if __name__ == "__main__":
    main()
