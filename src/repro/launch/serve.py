"""Production serving launcher: requests through the split engine with the
orchestrator picking the transmit mode from simulated mmWave channels (the
paper's Fig. 3/5 loop, runnable end to end).

    # synchronous static batch (legacy engine)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 4 --prompt-len 16 --gen 32
    # continuous batching: per-request channels, per-slot bottleneck modes
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --engine continuous --requests 16 --n-slots 4 --arrival-every 2
    # edge cluster: N replicas, mobility traces, live migration on handover
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --engine cluster --replicas 2 --placement best-channel \
        --handover migrate --requests 8 --n-slots 2
    # mesh-sharded serving: slot pools over dp, decoder heads over mp
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --engine continuous --requests 16 --n-slots 8 --dp 4 --mp 2

Policies (sync engine):
  orchestrator  paper's dynamic policy (channel + loss feedback, hysteresis)
  static0       always mode 0 (raw boundary, most informative)
  static1       always mode 1 (bottleneck z', cheapest)
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core import bottleneck
from repro.core import split as SP
from repro.core.channel import (Channel, ChannelConfig, FleetChannel,
                                MobilityChannel, channel_fleet)
from repro.data.lumos5g import capacity_traces_bps
from repro.core.orchestrator import AppRequirement, ModeProfile, Orchestrator
from repro.data import tokens
from repro.models import transformer as T
from repro.models.sharding import serving_mesh
from repro.serving import (HANDOVER_POLICIES, PLACEMENTS,
                           Autoscaler, AutoscalerConfig,
                           ContinuousBatchingEngine, ControllerConfig,
                           EdgeCluster, FleetLoadConfig, ModeController,
                           Request, SLOAdmission, SLOAdmissionConfig,
                           ServingEngine, Telemetry, fleet_requests,
                           profile_capture)
from repro.serving.telemetry import Stopwatch
from repro.training import checkpoint


def build_orchestrator(cfg, batch: int, latency_budget_s: float,
                       *, hysteresis: float = 0.85):
    """Mode profiles from the analytic payload model (calibration stands in
    for the cascade validation losses on untrained smoke weights)."""
    profiles = []
    for m in range(cfg.split.n_modes):
        pb = bottleneck.mode_payload_bytes(cfg, batch, 1, m)
        profiles.append(ModeProfile(mode=m, payload_bytes=pb,
                                    expected_loss=float(m)))  # DPI ordering
    return Orchestrator(profiles,
                        AppRequirement(latency_budget_s=latency_budget_s),
                        hysteresis=hysteresis)


def _build_mesh(args):
    """``('dp','mp')`` serving mesh from --dp/--mp, or None (single-device
    semantics, bit-identical to builds without the flags)."""
    if not (args.dp or args.mp):
        return None
    dp, mp = args.dp or 1, args.mp or 1
    n_dev = len(jax.devices())
    if dp * mp > n_dev:
        raise SystemExit(
            f"--dp {dp} x --mp {mp} needs {dp * mp} devices but only "
            f"{n_dev} visible (on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return serving_mesh(dp, mp)


def _latency_section(tel) -> dict:
    """Millisecond percentile summary of the run's latency histograms
    (empty without --telemetry)."""
    if tel is None:
        return {}
    return {"latency": tel.registry.latency_summary(
        "engine.ttft_s", "engine.intertoken_s",
        "engine.admit_to_first_token_s", "cluster.migration_backhaul_s")}


def run_continuous(args, cfg, params, tel=None):
    orch = build_orchestrator(cfg, 1, args.latency_budget_ms / 1e3,
                              hysteresis=1.0)
    chans = channel_fleet(
        args.requests,
        ChannelConfig(mean_mbps=args.mean_mbps, std_mbps=args.mean_mbps / 2,
                      blockage_prob=0.06, recovery_prob=0.2,
                      seed=args.channel_seed),
        seed=args.channel_seed, mean_spread=0.9)
    src = tokens.MarkovTokenSource(cfg, seed=7)
    batch = src.batch(args.requests, args.prompt_len)["tokens"]
    reqs = [Request(rid=i, prompt=np.asarray(batch[i]),
                    max_new_tokens=args.gen, channel=chans[i],
                    arrival_tick=i * args.arrival_every)
            for i in range(args.requests)]
    kw = {}
    if args.mode_policy == "adaptive":
        kw["controller"] = ModeController(
            orch, ControllerConfig(dwell_ticks=args.dwell_ticks))
    else:
        kw["orchestrator"] = orch
        kw["freeze_modes"] = args.mode_policy == "frozen"
    eng = ContinuousBatchingEngine(params, cfg, n_slots=args.n_slots,
                                   cache_len=args.cache_len,
                                   mesh=_build_mesh(args), telemetry=tel,
                                   **kw)
    # warm the compiled prefill/decode paths (every prefill batch bucket)
    # so decode_tok_per_s measures steady-state serving — the sync engine
    # likewise excludes its one-time prefill/trace cost from the decode rate
    eng.warm(np.asarray(batch[0]))

    with Stopwatch() as sw:
        done = eng.run(reqs)
    st = eng.stats()
    return {
        "engine": "continuous",
        "n_slots": args.n_slots,
        "decode_tok_per_s": round(
            st["decode_tokens"] / max(sw.seconds, 1e-9), 1),
        "per_request": [s.result() for s in done[:4]],
        **_latency_section(tel),
        **st,
    }


def run_cluster(args, cfg, params, tel=None):
    """Multi-replica edge cluster on scripted mobility: each UE starts in
    its home cell and crosses into the next cell partway through its
    generation, so every session exercises the configured handover policy
    (migrate / stay / drop) under the chosen placement."""
    n_rep = args.replicas
    cap_bps = args.mean_mbps * 1e6 / 8.0
    rng = np.random.default_rng(args.channel_seed)
    src = tokens.MarkovTokenSource(cfg, seed=7)
    batch = src.batch(args.requests, args.prompt_len)["tokens"]
    reqs = []
    for i in range(args.requests):
        home = i % n_rep
        cross = int(rng.integers(2, max(args.gen - 2, 3)))
        cells = [home] * cross + [(home + 1) % n_rep] * (args.gen + 8)
        ch = MobilityChannel(cells, [cap_bps] * n_rep,
                             detach_factor=args.detach_factor)
        reqs.append(Request(rid=i, prompt=np.asarray(batch[i]),
                            max_new_tokens=args.gen, channel=ch,
                            arrival_tick=i * args.arrival_every))
    cluster = EdgeCluster(
        params, cfg, n_replicas=n_rep, n_slots=args.n_slots,
        cache_len=args.cache_len, placement=args.placement,
        handover=args.handover, snapshot_bits=args.snapshot_bits,
        backhaul_bps=args.backhaul_mbps * 1e6 / 8.0,
        latency_budget_s=args.latency_budget_ms / 1e3,
        telemetry=tel, dp=args.dp, mp=args.mp)
    # warm every replica's compiled paths so decode_tok_per_s measures
    # steady-state serving, same as the continuous-engine path
    cluster.warm(np.asarray(batch[0]))
    with Stopwatch() as sw:
        done = cluster.run(reqs)
    st = cluster.stats()
    cluster.close()
    return {
        "engine": "cluster",
        "decode_tok_per_s": round(
            st["decode_tokens"] / max(sw.seconds, 1e-9), 1),
        "per_request": [s.result() for s in done[:4]],
        **_latency_section(tel),
        **st,
    }


def run_fleet(args, cfg, params, tel=None):
    """City-fleet serving: every UE rides one lane of a single vectorized
    ``FleetChannel`` replaying Lumos5G-resampled capacity traces (no
    per-UE Python channel objects), arrivals come from a Poisson or
    heavy-tail renewal process, and the elastic ``EdgeCluster`` applies
    SLO-driven admission plus replica autoscaling."""
    n = args.requests
    traces = capacity_traces_bps(n, 512, seed=args.channel_seed)
    fleet = FleetChannel(n, traces_bps=traces, cycle=True)
    load = FleetLoadConfig(arrival=args.arrival,
                           mean_interarrival_ticks=args.arrival_every,
                           prompt_len=args.prompt_len,
                           max_new_tokens=args.gen,
                           vocab=cfg.vocab_size,
                           slo_ticks=args.slo_ticks,
                           seed=args.channel_seed)
    reqs = fleet_requests(fleet, load)
    min_payload = min(bottleneck.mode_payload_bytes(cfg, 1, 1, m)
                      for m in range(cfg.split.n_modes))
    autoscaler = (Autoscaler(AutoscalerConfig(
        max_replicas=args.max_replicas)) if args.autoscale else None)
    cluster = EdgeCluster(
        params, cfg, n_replicas=args.replicas, n_slots=args.n_slots,
        cache_len=args.cache_len, placement="least-loaded",
        latency_budget_s=args.latency_budget_ms / 1e3,
        admission=SLOAdmission(min_payload, SLOAdmissionConfig(
            latency_budget_s=args.latency_budget_ms / 1e3)),
        autoscaler=autoscaler,
        telemetry=tel,
        max_pending=max(n, 64))
    cluster.warm(reqs[0].prompt)
    with Stopwatch() as sw:
        done = cluster.run_paced(reqs)
    st = cluster.stats()
    cluster.close()
    return {
        "engine": "fleet",
        "n_ues": n,
        "arrival": args.arrival,
        "autoscale": bool(args.autoscale),
        "decode_tok_per_s": round(
            st["decode_tokens"] / max(sw.seconds, 1e-9), 1),
        "admission": cluster.admission.stats(),
        "per_request": [s.result() for s in done[:2]],
        **_latency_section(tel),
        **st,
    }


def run_sync(args, cfg, params, tel=None):
    orch = None
    if args.policy == "orchestrator":
        orch = build_orchestrator(cfg, args.requests,
                                  args.latency_budget_ms / 1e3)
    eng = ServingEngine(params, cfg, cache_len=args.cache_len,
                        batch=args.requests, orchestrator=orch,
                        mesh=_build_mesh(args), telemetry=tel)

    # batched request prompts
    src = tokens.MarkovTokenSource(cfg, seed=7)
    prompt = jnp.asarray(
        src.batch(args.requests, args.prompt_len)["tokens"])
    chan = Channel(ChannelConfig(seed=args.channel_seed))

    with Stopwatch() as sw:
        logits = eng.prefill(prompt)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = sw.lap()

        if args.policy.startswith("static"):
            # same cache-wraparound guard ServingEngine.decode_tokens
            # applies on the orchestrator path
            T.check_cache_capacity(cfg, eng.pos, args.gen, args.cache_len,
                                   what="--gen")
            mode = int(args.policy[-1])
            out, wire = [], 0
            tok = first
            for _ in range(args.gen):
                logits, eng.states, pb = SP.split_decode_step(
                    params, tok, eng.states, jnp.int32(eng.pos), cfg,
                    mode=mode)
                eng.pos += 1
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(np.asarray(tok))
                wire += int(pb)
            gen = np.concatenate(out, axis=-1)
            stats = {"tokens": int(gen.size), "wire_bytes": wire,
                     "mode_counts": {mode: args.gen}}
        else:
            gen = eng.decode_tokens(first, args.gen,
                                    capacity_bps_fn=chan.step)
            stats = {"tokens": eng.stats.tokens,
                     "wire_bytes": eng.stats.wire_bytes,
                     "mode_counts": eng.stats.mode_counts,
                     "mode_switches": orch.state.switches}
    t_total = sw.seconds

    toks = args.requests * args.gen
    return {
        "engine": "sync", "policy": args.policy,
        "prefill_s": round(t_prefill, 2),
        "decode_tok_per_s": round(toks / max(t_total - t_prefill, 1e-9), 1),
        "wire_bytes_per_token": stats["wire_bytes"] / max(toks, 1),
        **stats,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "continuous", "cluster", "fleet"])
    ap.add_argument("--requests", type=int, default=4,
                    help="number of requests (sync: the batch size)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--policy", default="orchestrator",
                    choices=["orchestrator", "static0", "static1"])
    ap.add_argument("--latency-budget-ms", type=float, default=5.0)
    ap.add_argument("--channel-seed", type=int, default=0)
    ap.add_argument("--n-slots", type=int, default=4,
                    help="continuous engine: decode slot pool size")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="continuous engine: ticks between request arrivals")
    ap.add_argument("--mode-policy", default="pertick",
                    choices=["pertick", "adaptive", "frozen"],
                    help="continuous engine: per-tick orchestrator loop "
                         "(legacy), adaptive ModeController (dwell + "
                         "deadline escalation), or admission-frozen modes")
    ap.add_argument("--dwell-ticks", type=int, default=2,
                    help="adaptive policy: min ticks between mode switches")
    ap.add_argument("--mean-mbps", type=float, default=40.0,
                    help="continuous engine: fleet mean uplink")
    ap.add_argument("--replicas", type=int, default=2,
                    help="cluster engine: decoder replicas (one per cell)")
    ap.add_argument("--placement", default="least-loaded",
                    choices=list(PLACEMENTS),
                    help="cluster engine: new-request routing policy")
    ap.add_argument("--handover", default="migrate",
                    choices=list(HANDOVER_POLICIES),
                    help="cluster engine: what to do when a UE crosses "
                         "cells mid-generation")
    ap.add_argument("--snapshot-bits", type=int, default=0,
                    help="cluster engine: quantize migration snapshots at "
                         "this bit width (0 = raw, bit-exact)")
    ap.add_argument("--backhaul-mbps", type=float, default=10000.0,
                    help="cluster engine: inter-replica backhaul for "
                         "migration snapshots")
    ap.add_argument("--detach-factor", type=float, default=0.05,
                    help="cluster engine: capacity multiplier while a UE "
                         "is served from the wrong cell")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "heavy-tail", "burst"],
                    help="fleet engine: arrival process for the load "
                         "generator")
    ap.add_argument("--slo-ticks", type=int, default=96,
                    help="fleet engine: session SLO in engine ticks "
                         "(arrival -> finish, queue wait included)")
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet engine: attach the replica autoscaler")
    ap.add_argument("--max-replicas", type=int, default=8,
                    help="fleet engine: autoscaler ceiling")
    ap.add_argument("--dp", type=int, default=None,
                    help="serving mesh: data-parallel axis — slot/page "
                         "pools shard over dp (must divide n_slots; "
                         "cluster engine: per-replica, replicas get "
                         "disjoint device subsets)")
    ap.add_argument("--mp", type=int, default=None,
                    help="serving mesh: tensor-parallel axis — decoder "
                         "heads/FFN shard over mp (reassociates "
                         "reductions; dp alone stays bit-identical)")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the metrics registry + trace recorder "
                         "(latency percentiles land in the summary)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace JSON "
                         "here (implies --telemetry)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the run into "
                         "this directory")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"== launch.serve {args.arch} "
          f"({'reduced' if args.reduced else 'FULL'}) "
          f"engine={args.engine} requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen} ==")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = checkpoint.restore(args.ckpt, params)
        print(f"loaded weights from {args.ckpt}")

    tel = (Telemetry() if (args.telemetry or args.trace_out) else None)
    runner = {"sync": run_sync, "continuous": run_continuous,
              "cluster": run_cluster, "fleet": run_fleet}[args.engine]
    with profile_capture(args.profile_dir):
        summary = runner(args, cfg, params, tel)
    summary = {"arch": args.arch, **summary}
    if args.trace_out and tel is not None:
        tel.trace.export(args.trace_out)
        summary["trace_out"] = args.trace_out
        summary["trace_events"] = len(tel.trace.events())
    print(json.dumps(summary, indent=1, default=str))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summary, f, indent=1, default=str)
    return summary


if __name__ == "__main__":
    main()
