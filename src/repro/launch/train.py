"""Production training launcher.

Builds a mesh over the *actual* devices of the host (degrading gracefully to
1 CPU device), shards params/optimizer with the same rules the multi-pod
dry-run proves out, and runs the (optionally split-cascade) training loop
with checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 50 --batch 4 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --cascade --steps 40            # Algorithm 1: phase-1 then phase-2

On a real TPU slice the same entry point runs the full configs: the mesh is
shaped from ``jax.device_count()`` (data x model), params are initialized
directly into their shards via ``jax.jit`` out_shardings, and the step is
donated to keep HBM flat.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import cascade as CC
from repro.core import split as SP
from repro.data import tokens
from repro.launch.mesh import mesh_context
from repro.models import sharding
from repro.training import checkpoint
from repro.training import loop as L
from repro.training import optimizer as opt


def make_host_mesh(model_parallel: int = 1):
    """Mesh over the real devices: (data, model)."""
    n = jax.device_count()
    if n % model_parallel != 0:
        raise ValueError(f"{n} devices not divisible by mp={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def sharded_init(cfg: ModelConfig, mesh, seed: int = 0):
    """Initialize params directly into their shards (no host round-trip)."""
    abstract = jax.eval_shape(
        lambda k: SP.init_split_params(k, cfg), jax.random.PRNGKey(seed))
    specs = sharding.param_pspecs(abstract, mesh,
                                  stacked_layers=cfg.homogeneous)
    out_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)
    init = jax.jit(lambda k: SP.init_split_params(k, cfg),
                   out_shardings=out_sh)
    with mesh_context(mesh):
        return init(jax.random.PRNGKey(seed)), specs


def run_phase(params, cfg, tcfg, mesh, specs, data_fn, *, steps, mode,
              log_every=10, donate=True):
    """One monolithic/split training phase on a mesh."""
    step_fn = L.make_train_step(cfg, tcfg, mode=mode, mesh=mesh)
    opt_state = opt.init(params)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    hist = []
    t0 = time.time()
    with mesh_context(mesh):
        for s in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data_fn(s).items()}
            params, opt_state, m = jitted(params, opt_state, batch)
            if s % log_every == 0 or s == steps - 1:
                rec = {k: float(v) for k, v in m.items()}
                rec.update(step=s, wall=round(time.time() - t0, 1))
                hist.append(rec)
                print(f"[launch.train] step {s:4d} loss {rec['loss']:.4f} "
                      f"({rec['wall']}s)")
    return params, hist


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", type=int, default=None,
                    help="split bottleneck mode (None = monolithic)")
    ap.add_argument("--cascade", action="store_true",
                    help="run Algorithm 1: phase-1 (mode 0) then phase-2 "
                         "(frozen backbone, train bottleneck head)")
    ap.add_argument("--mp", type=int, default=1, help="model-parallel size")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--resume", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(args.mp)
    print(f"== launch.train {args.arch} ({'reduced' if args.reduced else 'FULL'}) "
          f"on mesh {dict(mesh.shape)} — {cfg.param_count()/1e6:.1f}M params ==")

    params, specs = sharded_init(cfg, mesh, args.seed)
    if args.resume:
        params = checkpoint.restore(args.resume, params)
        print(f"resumed from {args.resume}")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=max(args.steps, 100), seed=args.seed)
    src = tokens.MarkovTokenSource(cfg, seed=args.seed)
    data_fn = lambda s: src.batch(args.batch, args.seq, s)  # noqa: E731

    os.makedirs(args.ckpt_dir, exist_ok=True)
    history = {}
    if args.cascade:
        # Algorithm 1 over all configured modes, sharded on the host mesh.
        def loss_fn(p, batch, mode):
            return L.make_loss_fn(cfg, mode=mode)(p, batch)

        def eval_fn(p, mode):
            b = {k: jnp.asarray(v) for k, v in data_fn(10_001).items()}
            return L.make_eval_step(cfg, mode=mode)(p, b)

        n_modes = cfg.split.n_modes
        with mesh_context(mesh):
            params, hist = CC.train_cascade(
                params, loss_fn,
                lambda s: {k: jnp.asarray(v) for k, v in data_fn(s).items()},
                tcfg, n_modes=n_modes, steps_per_phase=args.steps,
                eval_fn=eval_fn, log_every=max(args.steps // 4, 1))
        history["cascade"] = hist["ensure"]
        print(f"[cascade] mode losses {hist['ensure']['losses']} "
              f"ordered={hist['ensure']['ordered']}")
    else:
        params, h = run_phase(params, cfg, tcfg, mesh, specs, data_fn,
                              steps=args.steps, mode=args.mode)
        history["phase1"] = h

    ck = os.path.join(args.ckpt_dir, f"{args.arch.replace('.', '_')}.npz")
    checkpoint.save(ck, params, {"arch": args.arch, "steps": args.steps,
                                 "reduced": args.reduced})
    with open(ck.replace(".npz", "_history.json"), "w") as f:
        json.dump(history, f, indent=1)
    print(f"checkpoint -> {ck}")
    return history


if __name__ == "__main__":
    main()
