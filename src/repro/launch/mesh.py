"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def mesh_context(mesh):
    """Enter ``mesh`` as the ambient mesh, across JAX versions.

    Newer JAX exposes ``jax.set_mesh``; on older releases
    ``jax.sharding.Mesh`` is itself the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(pod: int = 1, data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires forced host devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
