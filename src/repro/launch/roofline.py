"""Roofline-term derivation from compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory     = HLO_bytes / (chips x 819 GB/s)
  collective = collective_bytes / (chips x 50 GB/s)

``cost_analysis`` provides FLOPs/bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "  %foo = f32[128,4096]{1,0} all-reduce(...)", possibly tuple-typed:
# "(bf16[8,16]{...}, f32[8]{...}) all-reduce(..."
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[-a-z]*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        s = line.strip()
        if name is None and s.endswith("{") and "->" in s:
            head = s[len("ENTRY "):] if s.startswith("ENTRY ") else s
            name = head.split()[0].lstrip("%")
            buf = []
        elif s == "}" and name is not None:
            comps[name] = "\n".join(buf)
            name = None
        elif name is not None:
            buf.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    """lax.scan lowers to a while whose condition compares a counter to a
    constant — take the max int constant in the condition as the trip count
    (fallback 1 for dynamic loops)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)
              if int(c) < 10_000_000]
    return max(consts) if consts else 1


def _comp_multipliers(comps: Dict[str, str], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation, following nested while
    loops from the entry computation."""
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for wm in _WHILE_RE.finditer(comps[name]):
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            visit(body, m * trips)

    visit(entry, 1.0)
    return mult


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes per collective kind (per-device program),
    multiplying ops inside while-loop bodies (lax.scan) by the loop trip
    count — XLA lists a loop body once but it executes trip-count times."""
    comps = _split_computations(hlo_text)
    entry = None
    m_entry = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m_entry:
        entry = m_entry.group(1)
    mults = (_comp_multipliers(comps, entry)
             if entry and entry in comps else {})

    out: Dict[str, Dict[str, float]] = {
        k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    if comps:
        for cname, body in comps.items():
            mult = mults.get(cname, 1.0 if not mults else 0.0)
            if mult == 0.0:
                # unreached computations (e.g. fusions) hold no collectives,
                # but keep them counted once if they somehow do
                mult = 1.0 if _OP_RE.search(body) and cname not in mults \
                    else mult
            if mult == 0.0:
                continue
            for m in _OP_RE.finditer(body):
                shape_str, kind = m.group(1), m.group(2)
                out[kind]["bytes"] += _shape_bytes(shape_str) * mult
                out[kind]["count"] += mult
    else:
        for m in _OP_RE.finditer(hlo_text):
            shape_str, kind = m.group(1), m.group(2)
            out[kind]["bytes"] += _shape_bytes(shape_str)
            out[kind]["count"] += 1
    return out


def collective_bytes_total(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in parse_collectives(hlo_text).values()))


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int, *, per_device: bool = True) -> Dict[str, float]:
    """All inputs are per-device program quantities when per_device=True
    (XLA cost_analysis and the SPMD HLO are per-device); chips scales the
    aggregate hardware. Returns seconds per term + dominant."""
    if per_device:
        # per-device work over per-chip peak == aggregate over aggregate
        compute = flops / PEAK_FLOPS_BF16
        memory = hbm_bytes / HBM_BW
        collective = coll_bytes / ICI_BW
    else:
        compute = flops / (chips * PEAK_FLOPS_BF16)
        memory = hbm_bytes / (chips * HBM_BW)
        collective = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bound_s"] = max(compute, memory, collective)
    return terms


def model_flops_per_step(n_active_params: int, tokens_per_step: int,
                         kind: str = "train") -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference forward)."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_active_params * tokens_per_step


def useful_ratio(model_flops: float, hlo_flops_per_device: float,
                 chips: int) -> float:
    """MODEL_FLOPS / total HLO FLOPs — how much compiled compute is useful
    (catches remat/redundancy/dispatch waste)."""
    total = hlo_flops_per_device * chips
    return model_flops / total if total else 0.0
