"""Transformer training loop: loss builders, (optionally sharded) train
steps, and the driver used by examples and the multi-pod launcher.

The same ``make_train_step`` serves three callers:
  - CPU smoke tests / examples (mesh=None),
  - the multi-pod dry-run (mesh + ShapeDtypeStruct lowering),
  - real training (mesh + device arrays).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pipeline as PL
from repro.core import split as SP
from repro.models import sharding
from repro.models import transformer as T
from repro.serving import telemetry
from repro.training import optimizer as opt

AUX_WEIGHT = 0.01     # MoE load-balance loss weight


def make_loss_fn(cfg: ModelConfig, *, mode: Optional[int] = None,
                 use_pipeline: bool = False, mesh=None,
                 n_micro: int = 4, bwd_bits: int = 0) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics).

    mode None: plain full-model forward (paper-agnostic baseline).
    mode int: split forward through bottleneck mode m (0 = raw boundary).
    use_pipeline: route through the 2-stage pod pipeline (requires mesh).
    """
    def loss_fn(params, batch):
        emb = batch.get("embeddings")
        if use_pipeline:
            logits, aux = PL.pipeline_forward(
                params, batch["tokens"], cfg, mesh=mesh, n_micro=n_micro,
                mode=mode or 0, train=True, bwd_bits=bwd_bits,
                embeddings=emb)
        elif mode is None:
            logits, aux = T.forward(params, batch["tokens"], cfg, train=True,
                                    embeddings=emb)
        else:
            logits, aux, _ = SP.split_forward(params, batch["tokens"], cfg,
                                              mode, train=True,
                                              embeddings=emb)
        labels = batch["labels"]
        if cfg.frontend == "vision" and emb is not None:
            logits = logits[:, -labels.shape[-1]:]     # text positions only
        loss = T.lm_loss(logits, labels)
        total = loss + AUX_WEIGHT * aux
        return total, {"lm_loss": loss, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, *,
                    mode: Optional[int] = None, mesh=None,
                    use_pipeline: bool = False, n_micro: int = 4,
                    seq_shard: bool = True, act_policy: Optional[str] = None,
                    moe_ep: bool = False, bwd_bits: int = 0,
                    donate: bool = True) -> Callable:
    """Returns jitted step(params, opt_state, batch) -> (params, opt_state,
    metrics). When ``mesh`` is given, activation constraints are installed
    and callers pass shardings via in_shardings at lower time."""
    loss_fn = make_loss_fn(cfg, mode=mode, use_pipeline=use_pipeline,
                           mesh=mesh, n_micro=n_micro, bwd_bits=bwd_bits)
    rules = (sharding.default_activation_rules(mesh, seq_shard=seq_shard,
                                               act_policy=act_policy,
                                               moe_ep=moe_ep)
             if mesh is not None else {})

    def step(params, opt_state, batch):
        with sharding.activation_rules(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state, info = opt.apply_updates(
                params, grads, opt_state, tcfg)
        return params, opt_state, dict(metrics, loss=loss, **info)

    return step


def make_eval_step(cfg: ModelConfig, *, mode: Optional[int] = None):
    loss_fn = make_loss_fn(cfg, mode=mode)

    @jax.jit
    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)
    return step


def train_loop(params, cfg: ModelConfig, tcfg: TrainConfig,
               data_fn: Callable[[int], Dict], *, steps: int,
               mode: Optional[int] = None, log_every: int = 20,
               callback: Optional[Callable] = None) -> Tuple[Any, list]:
    """Simple single-host driver used by the examples."""
    step_fn = jax.jit(make_train_step(cfg, tcfg, mode=mode))
    opt_state = opt.init(params)
    history = []
    t0 = telemetry.now()
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data_fn(s).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if s % log_every == 0 or s == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec.update(step=s, wall=telemetry.now() - t0)
            history.append(rec)
            print(f"[train] step {s:5d} loss {rec['loss']:.4f} "
                  f"lm {rec['lm_loss']:.4f} lr {rec['lr']:.2e} "
                  f"({rec['wall']:.1f}s)")
            if callback:
                callback(params, rec)
    return params, history
