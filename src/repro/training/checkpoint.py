"""Dependency-free pytree checkpointing (npz + path-keyed flattening).

Handles the mixed dict/tuple pytrees our params use; dtypes (incl. bf16 via
a uint16 view) round-trip exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        flat[key] = arr
    return flat


def save(path: str, tree, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    store = {}
    dtypes = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            store[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            store[k] = v
            dtypes[k] = str(v.dtype)
    store["__meta__"] = np.frombuffer(
        json.dumps({"dtypes": dtypes, "meta": metadata or {}}).encode(),
        dtype=np.uint8)
    np.savez(path, **store)


def restore(path: str, like) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat = {}
        for k in z.files:
            if k == "__meta__":
                continue
            arr = z[k]
            if meta["dtypes"].get(k) == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[k] = arr

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_k, leaf in leaves_with_path:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key!r}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_metadata(path: str) -> Dict[str, Any]:
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        return json.loads(bytes(z["__meta__"]).decode())["meta"]
