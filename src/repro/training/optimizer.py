"""AdamW with freeze-mask support (pure JAX, optax-free).

The freeze mask is how Algorithm 1's "Freeze(Encoder1, Decoder1)" is
implemented: masked leaves keep their value and their optimizer state is
never touched, so cascade phases can share one optimizer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def apply_updates(params, grads, state: AdamState, cfg: TrainConfig,
                  mask=None):
    """One AdamW step. ``mask``: pytree of bools, True = trainable."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, trainable=True):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        delta = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - delta).astype(p.dtype)
        if trainable is True:
            return p_new, m_new, v_new
        t = jnp.asarray(trainable)
        return (jnp.where(t, p_new, p), jnp.where(t, m_new, m),
                jnp.where(t, v_new, v))

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    t_leaves = (treedef.flatten_up_to(mask) if mask is not None
                else [True] * len(p_leaves))
    triples = [upd(p, g, m, v, t) for p, g, m, v, t in
               zip(p_leaves, g_leaves, m_leaves, v_leaves, t_leaves)]
    p_new = jax.tree.unflatten(treedef, [t[0] for t in triples])
    m_new = jax.tree.unflatten(treedef, [t[1] for t in triples])
    v_new = jax.tree.unflatten(treedef, [t[2] for t in triples])
    return p_new, AdamState(step, m_new, v_new), {"lr": lr, "grad_norm": gnorm}
