"""Fleet-scale load generation and SLO-driven admission control.

Two pieces the city-scale bench and the elastic ``EdgeCluster`` share:

* :func:`fleet_requests` — a deterministic load generator: Poisson or
  heavy-tail (Pareto) arrival processes over thousands of UEs, each UE
  riding its own lane of one vectorized
  :class:`~repro.core.channel.FleetChannel` (no per-UE Python channel
  objects anywhere), each request carrying a session-level
  ``slo_ticks`` deadline.
* :class:`SLOAdmission` — the admission gate: decisions come from
  *predicted deadline-miss*, not just slot pressure. A request is
  rejected outright when its link is hopeless (even the cheapest
  calibrated payload cannot meet the per-token budget at the UE's
  observed capacity) or when the predicted queue wait plus service time
  already exceeds its session SLO; it is *parked* (deferred, retried
  each cluster step, aged out to a rejection) under transient backlog
  pressure the autoscaler may yet relieve.

The gate is a pure decision function of scalars — no cluster reference —
so it unit-tests without any engine and the cluster stays the single
place that derives the signals.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional

import numpy as np

from repro.core.channel import FleetChannel, tx_seconds
from repro.serving.session import Request

ARRIVALS = ("poisson", "heavy-tail", "burst")


# ---------------------------------------------------------------------------
# SLO-driven admission
# ---------------------------------------------------------------------------

@dataclass
class SLOAdmissionConfig:
    #: per-token transfer budget the link-hopeless test measures against
    latency_budget_s: float = 0.006
    #: reject when the cheapest payload's transfer time exceeds
    #: ``hopeless_factor * latency_budget_s`` at the observed capacity
    hopeless_factor: float = 2.0
    #: park (defer) when cluster backlog exceeds this many waiting
    #: requests per aggregate live slot
    park_queue_per_slot: float = 1.0
    #: parked longer than this many cluster steps -> terminal rejection
    park_max_ticks: int = 64


class SLOAdmission:
    """Predictive admission gate. ``decide`` returns ``"admit"``,
    ``"park"``, or ``"reject"``, tallies per-reason counters, and appends
    one structured record per decision to the bounded ``events`` deque
    (verdict, reason, predicted deadline margin, queue-per-slot at
    decision time) — fleet-bench rejections stay auditable post-hoc.
    Attaching a :class:`~repro.serving.telemetry.Telemetry` (the cluster
    does this on its control-plane lane) additionally stamps every
    decision onto the trace timeline."""

    def __init__(self, min_payload_bytes: Optional[int] = None,
                 cfg: Optional[SLOAdmissionConfig] = None, *,
                 events_capacity: int = 4096):
        self.min_payload_bytes = min_payload_bytes
        self.cfg = cfg if cfg is not None else SLOAdmissionConfig()
        self.admitted = 0
        self.rejected_link = 0       # link-hopeless rejections
        self.rejected_deadline = 0   # predicted session-SLO miss
        self.parked = 0
        #: last ``events_capacity`` decision records (oldest dropped)
        self.events: Deque[dict] = deque(maxlen=int(events_capacity))
        #: optional :class:`~repro.serving.telemetry.Telemetry`; when set,
        #: every decision also lands on the trace timeline as an instant
        self.telemetry = None

    def decide(self, *, slo_ticks: Optional[int],
               predicted_wait_ticks: int, service_ticks: int,
               capacity_bps: Optional[float] = None,
               queue_per_slot: float = 0.0, rid=None) -> str:
        verdict, reason = "admit", "ok"
        if capacity_bps is not None and self.min_payload_bytes:
            tx = tx_seconds(self.min_payload_bytes,
                            max(float(capacity_bps), 1.0))
            if tx > self.cfg.hopeless_factor * self.cfg.latency_budget_s:
                verdict, reason = "reject", "link_hopeless"
        # predicted margin: SLO ticks left after queue wait + service time
        # (negative = predicted miss); None when the request carries no SLO
        margin = (slo_ticks - (predicted_wait_ticks + service_ticks)
                  if slo_ticks is not None else None)
        if verdict == "admit":
            if margin is not None and margin < 0:
                verdict, reason = "reject", "deadline"
            elif queue_per_slot > self.cfg.park_queue_per_slot:
                verdict, reason = "park", "backlog"
        if reason == "link_hopeless":
            self.rejected_link += 1
        elif reason == "deadline":
            self.rejected_deadline += 1
        elif verdict == "park":
            self.parked += 1
        else:
            self.admitted += 1
        record = {"rid": rid, "verdict": verdict, "reason": reason,
                  "margin_ticks": margin,
                  "predicted_wait_ticks": int(predicted_wait_ticks),
                  "service_ticks": int(service_ticks),
                  "queue_per_slot": round(float(queue_per_slot), 4)}
        self.events.append(record)
        if self.telemetry is not None:
            self.telemetry.instant("slo_admission", cat="admission",
                                   **record)
        return verdict

    def stats(self) -> dict:
        return {
            "admitted": self.admitted,
            "rejected_link": self.rejected_link,
            "rejected_deadline": self.rejected_deadline,
            "parked": self.parked,
        }


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

@dataclass
class FleetLoadConfig:
    """One request per UE, arrival times drawn from a renewal process.

    ``poisson`` draws exponential interarrivals (memoryless, smooth
    offered load); ``heavy-tail`` draws mean-matched Pareto interarrivals
    (``pareto_alpha``), giving the bursty flash-crowd arrivals real
    mobile traffic shows; ``burst`` packs all arrivals into the first
    ``burst_ticks`` ticks uniformly (worst-case stampede).
    """
    arrival: str = "poisson"
    mean_interarrival_ticks: float = 2.0
    pareto_alpha: float = 1.5           # heavy-tail shape (alpha > 1)
    burst_ticks: int = 8
    prompt_len: int = 8
    prompt_len_jitter: int = 0          # +/- uniform jitter on prompt_len
    max_new_tokens: int = 8
    vocab: int = 256
    slo_ticks: Optional[int] = 96       # session deadline; None: no SLO
    seed: int = 0


def arrival_ticks(n: int, cfg: FleetLoadConfig) -> np.ndarray:
    """Deterministic arrival tick per request ``[n] int64`` (sorted)."""
    if cfg.arrival not in ARRIVALS:
        raise ValueError(f"arrival must be one of {ARRIVALS}")
    if n < 1:
        raise ValueError("need at least one request")
    rng = np.random.default_rng(cfg.seed)
    mean = float(cfg.mean_interarrival_ticks)
    if cfg.arrival == "poisson":
        gaps = rng.exponential(mean, size=n)
    elif cfg.arrival == "heavy-tail":
        a = float(cfg.pareto_alpha)
        if a <= 1.0:
            raise ValueError("pareto_alpha must be > 1 (finite mean)")
        # standard Pareto (x_m = 1) has mean a/(a-1); rescale to `mean`
        gaps = (rng.pareto(a, size=n) + 1.0) * mean * (a - 1.0) / a
    else:                               # burst
        return np.sort(rng.integers(0, max(cfg.burst_ticks, 1),
                                    size=n)).astype(np.int64)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def fleet_requests(fleet: FleetChannel,
                   cfg: Optional[FleetLoadConfig] = None, *,
                   requirement=None) -> List[Request]:
    """One :class:`Request` per fleet lane, arrival-ordered.

    Request ``i`` rides ``fleet.lane(i)`` — a stateless view into the
    vectorized fleet, so the serving hot path never touches a per-UE
    Python channel object. Prompts are seeded token arrays; every
    request carries ``cfg.slo_ticks`` for the admission gate and the
    cluster's session-SLO accounting.
    """
    cfg = cfg if cfg is not None else FleetLoadConfig()
    n = fleet.n
    ticks = arrival_ticks(n, cfg)
    rng = np.random.default_rng(cfg.seed + 1)
    jit = int(cfg.prompt_len_jitter)
    lens = (rng.integers(-jit, jit + 1, size=n) + cfg.prompt_len
            if jit else np.full(n, cfg.prompt_len))
    lens = np.maximum(lens, 1)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, size=int(lens[i]),
                                        dtype=np.int32),
                    max_new_tokens=cfg.max_new_tokens,
                    channel=fleet.lane(i),
                    requirement=requirement,
                    arrival_tick=int(ticks[i]),
                    slo_ticks=cfg.slo_ticks)
            for i in range(n)]
