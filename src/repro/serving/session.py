"""Request lifecycle for the continuous-batching split-serving engine.

A ``Request`` is what the UE submits: a prompt, a generation budget, and —
because this is *split* serving — the user's own simulated mmWave link and
(optionally) their application's latency/accuracy requirement. The engine
admits requests from a bounded ``RequestQueue`` into decode slots; each
admitted request becomes a ``Session`` that records, per generated token,
which bottleneck mode the orchestrator chose for *this* user's channel and
what it cost on the wire.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.channel import Channel
from repro.core.orchestrator import AppRequirement


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] tokens (or [K, S] for audio)
    max_new_tokens: int = 32
    channel: Optional[Channel] = None  # this user's uplink (None: engine default)
    requirement: Optional[AppRequirement] = None
    arrival_tick: int = 0              # engine tick at which the UE submits
    #: wall-clock stamps on the shared telemetry clock
    #: (``serving.telemetry.now``), set by the engine: queue entry and
    #: admission pop — TTFT measures from t_submit, the
    #: admission-to-first-token histogram from t_admit
    t_submit: float = 0.0
    t_admit: float = 0.0
    #: session-level SLO in engine ticks: the request should FINISH within
    #: this many ticks of its arrival (queue wait included). ``None`` means
    #: no session SLO — only the per-token latency budget applies. The
    #: fleet admission gate predicts against it and the cluster counts a
    #: session-SLO miss when finished_tick - arrival_tick exceeds it.
    slo_ticks: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])


@dataclass
class Session:
    """One admitted request bound to a decode slot."""
    request: Request
    slot: int
    admitted_tick: int = 0
    gen_budget: int = 0                # effective max_new_tokens (0: the
                                       # request's own; engines may clip it
                                       # to cache capacity at admission)
    pos: int = 0                       # absolute position of the next token
    tokens: List[int] = field(default_factory=list)
    wire_bytes: int = 0                # uplink boundary bytes, this request
    prefill_wire_bytes: int = 0
    transfer_s: float = 0.0            # accumulated simulated link latency
    ttft_s: float = 0.0                # wall clock submit -> first token
    mode_counts: Dict[int, int] = field(default_factory=dict)
    admission_mode: int = 0            # mode chosen when the prompt crossed
    #: (engine_tick, mode) whenever this session's transmit mode changed;
    #: the admission entry is always present, so a session that never
    #: switched has exactly one entry
    mode_trace: List[Tuple[int, int]] = field(default_factory=list)
    deadline_misses: int = 0           # decode tokens whose simulated
    #                                    transfer blew the latency budget
    escalations: int = 0               # controller deadline escalations
    #: one record per live migration this session survived:
    #: {tick, from_replica, to_replica, snapshot_bytes, bits, transfer_s}
    #: (empty for single-engine serving — see serving/migration.py)
    migrations: List[dict] = field(default_factory=list)
    #: channel ticks at which this session's UE crossed a cell boundary
    #: (empty when the request's channel has no mobility)
    handover_ticks: List[int] = field(default_factory=list)
    finished_tick: int = -1

    @property
    def done(self) -> bool:
        budget = self.gen_budget or self.request.max_new_tokens
        return len(self.tokens) >= budget

    def account(self, mode: int, payload_bytes: int, tx_s: float):
        self.wire_bytes += payload_bytes
        self.transfer_s += tx_s
        self.mode_counts[mode] = self.mode_counts.get(mode, 0) + 1

    def result(self) -> dict:
        return {
            "rid": self.request.rid,
            "tokens": list(self.tokens),
            "n_tokens": len(self.tokens),
            "wire_bytes": self.wire_bytes,
            "prefill_wire_bytes": self.prefill_wire_bytes,
            "transfer_s": round(self.transfer_s, 6),
            "ttft_s": round(self.ttft_s, 6),
            "mode_counts": dict(self.mode_counts),
            "admission_mode": self.admission_mode,
            "mode_trace": list(self.mode_trace),
            "mode_switches": max(len(self.mode_trace) - 1, 0),
            "deadline_misses": self.deadline_misses,
            "escalations": self.escalations,
            "migrations": list(self.migrations),
            "handover_ticks": list(self.handover_ticks),
            "admitted_tick": self.admitted_tick,
            "finished_tick": self.finished_tick,
        }


class RequestQueue:
    """Bounded FIFO admission queue. ``submit`` rejects (returns False) when
    the queue is full — back-pressure instead of unbounded memory growth
    under heavy offered load. Backed by a ``deque`` so admission pops are
    O(1) (a list's ``pop(0)`` shifts every queued request per admission —
    O(n) per pop, quadratic over a busy tick's drain)."""

    def __init__(self, max_pending: int = 64):
        self.max_pending = max_pending
        self._q: Deque[Request] = deque()
        self.submitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> bool:
        if len(self._q) >= self.max_pending:
            self.rejected += 1
            return False
        self._q.append(req)
        self.submitted += 1
        return True

    def pop(self) -> Optional[Request]:
        return self._q.popleft() if self._q else None

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None
