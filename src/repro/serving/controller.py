"""Per-slot, per-tick bottleneck mode controller for the continuous engine.

The paper's central claim is *dynamic* encoding/decoding: the encoder's
transmit mode must track the channel as it changes, not just at admission.
The continuous engine already decodes any per-slot mode mixture in one
jitted step (``split_decode_step_mixed`` gathers each slot's head from the
stacked bank), so re-selecting a live session's mode costs **no retrace** —
what was missing is the control loop. ``ModeController`` closes it:

* every decode tick it feeds each live session's own ``Channel`` observation
  into the shared :class:`~repro.core.orchestrator.Orchestrator` (per-link
  EWMA capacity tracking) and re-selects that session's bottleneck mode via
  the vectorized ``Orchestrator.choose_modes`` — one numpy broadcast over
  the whole pool, not N Python feasibility scans;
* **dwell time**: after a switch, a session's mode is held for
  ``dwell_ticks`` engine ticks, on top of the orchestrator's capacity
  hysteresis, so a link oscillating around a feasibility boundary cannot
  flap between modes every tick;
* **deadline-aware escalation**: the controller tracks an EWMA of each
  session's per-token transfer-time utilization (predicted transfer latency
  of the chosen mode / the session's ``AppRequirement.latency_budget_s``).
  When utilization crosses ``escalate_util`` the session is dropped to the
  cheapest calibrated mode *immediately*, bypassing dwell and hysteresis —
  a degrading mmWave link must never ride an 8-bit payload through its
  latency budget just because the dwell timer says wait.

The engine (``repro.serving.batcher``) records the resulting per-session
mode-switch traces and deadline misses in ``Session``/``stats()``;
``benchmarks/bench_serving.py --channel-trace`` compares this adaptive
policy against admission-frozen modes on identical scripted channels.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.channel import tx_seconds
from repro.core.orchestrator import AppRequirement, Orchestrator


@dataclass
class ControllerConfig:
    """Knobs for the per-tick mode control loop (the orchestrator's EWMA
    weight and capacity hysteresis are configured on the orchestrator)."""
    dwell_ticks: int = 2        # min ticks between voluntary mode switches
    escalate_util: float = 1.0  # transfer/budget EWMA ratio that triggers
    #                             escalation to the cheapest mode
    util_ema: float = 0.5       # EWMA weight for the utilization tracker


@dataclass
class SlotControl:
    """Per-session controller state (lives from admission to retirement)."""
    mode: int = 0
    last_switch_tick: int = -(1 << 30)
    util_ema: float = 0.0
    ticks: int = 0              # decode ticks this session has been steered
    switches: int = 0
    escalations: int = 0
    #: (engine_tick, from_mode, to_mode) per switch, admission entry included
    trace: List[Tuple[int, int, int]] = field(default_factory=list)


class ModeController:
    """Drives per-slot, per-tick mode re-selection for live sessions.

    Wraps a shared :class:`Orchestrator` (mode calibration and per-link
    EWMAs live there) and adds the serving-side control policy: dwell-time
    suppression and deadline-aware escalation. One controller serves one
    engine; sessions attach at admission and detach at retirement.
    """

    def __init__(self, orchestrator: Orchestrator,
                 cfg: Optional[ControllerConfig] = None):
        self.orch = orchestrator
        self.cfg = cfg if cfg is not None else ControllerConfig()
        self._ctl: Dict[Hashable, SlotControl] = {}
        self._cheapest = min(orchestrator.profiles,
                             key=lambda p: p.payload_bytes).mode
        self._payload = {p.mode: p.payload_bytes
                         for p in orchestrator.profiles}
        #: optional observer ``(rid, tick, from_mode, to_mode) -> None``
        #: fired on every deadline escalation (telemetry engines attach a
        #: trace-event emitter here; None costs nothing)
        self.on_escalate = None

    # -- session lifecycle ----------------------------------------------------
    def admit(self, rid: Hashable, requirement: Optional[AppRequirement],
              capacity_bps: Optional[float], tick: int) -> int:
        """Admission-time selection: register the link, feed the first
        capacity observation, choose the initial mode. Returns the mode."""
        self.orch.register(rid, requirement)
        if capacity_bps is not None:
            self.orch.observe_capacity(capacity_bps, rid=rid)
        mode = self.orch.choose_mode(rid=rid)
        self._ctl[rid] = SlotControl(mode=mode, last_switch_tick=tick,
                                     trace=[(tick, mode, mode)])
        return mode

    def finish(self, rid: Hashable) -> Optional[SlotControl]:
        """Release the session's link state; returns its control record so
        the engine can fold the switch trace into the ``Session``."""
        self.orch.release(rid)
        return self._ctl.pop(rid, None)

    def detach(self, rid: Hashable) -> Optional[SlotControl]:
        """Remove and return the session's control record WITHOUT touching
        the orchestrator (the caller detaches that separately) — the
        live-migration export: dwell timer, utilization EWMA, and switch
        trace travel with the session to the target controller."""
        return self._ctl.pop(rid, None)

    def attach(self, rid: Hashable, ctl: Optional[SlotControl]) -> None:
        """Install a control record exported by :meth:`detach`."""
        if ctl is not None:
            self._ctl[rid] = ctl

    # -- the per-tick control loop --------------------------------------------
    def step_modes(self, rids: Sequence[Hashable],
                   capacities: Sequence[Optional[float]],
                   tick: int) -> np.ndarray:
        """Re-select every live session's mode for this engine tick.

        ``rids``/``capacities`` are aligned (capacity ``None`` = no fresh
        observation for that link this tick). Returns ``int32 [N]`` modes.
        """
        if not len(rids):
            return np.zeros(0, np.int32)
        ctls = [self._ctl.setdefault(r, SlotControl()) for r in rids]
        hold = np.array([tick - c.last_switch_tick < self.cfg.dwell_ticks
                         for c in ctls])
        # uncommitted pass: the policy's pick, which escalation may still
        # override — each link's FINAL mode commits exactly once below
        chosen = self.orch.choose_modes(rids, capacities, hold=hold,
                                        commit=False)

        for i, (rid, ctl) in enumerate(zip(rids, ctls)):
            link = self.orch.register(rid)
            req = self.orch.requirement_for(rid)
            mode = int(chosen[i])
            if link.ticks > 0:
                # deadline tracker: predicted transfer time of the mode we
                # are about to use, as a fraction of this session's latency
                # budget (the same tx_seconds the engine's accounting uses).
                # Cold links (no capacity observed yet) are skipped entirely
                # — the EMA is a phantom 0.0 there and utilization would
                # explode; choose_modes is documented to stay optimistic on
                # cold start, so the escalation tracker stays out of it too.
                tx = tx_seconds(self._payload[mode], link.capacity_ema)
                util = tx / max(req.latency_budget_s, 1e-9)
                w = self.cfg.util_ema
                ctl.util_ema = (util if ctl.ticks == 0
                                else w * ctl.util_ema + (1 - w) * util)
                ctl.ticks += 1
            if (ctl.ticks > 0 and ctl.util_ema > self.cfg.escalate_util
                    and mode != self._cheapest):
                # budget at risk: drop to the cheapest calibrated mode NOW,
                # overriding dwell/hysteresis (they exist to damp flapping,
                # not to ride a collapsing link into a deadline miss)
                if self.on_escalate is not None:
                    self.on_escalate(rid, tick, int(chosen[i]), self._cheapest)
                mode = self._cheapest
                ctl.escalations += 1
            self.orch.force_mode(rid, mode)   # single commit point: one
            #                                   counted switch per transition
            if mode != ctl.mode:
                ctl.trace.append((tick, ctl.mode, mode))
                ctl.mode = mode
                ctl.switches += 1
                ctl.last_switch_tick = tick
            chosen[i] = mode
        return chosen

    # -- introspection --------------------------------------------------------
    def control(self, rid: Hashable) -> Optional[SlotControl]:
        return self._ctl.get(rid)

    @property
    def n_attached(self) -> int:
        return len(self._ctl)


# ---------------------------------------------------------------------------
# replica autoscaling (fleet-scale elasticity)
# ---------------------------------------------------------------------------

@dataclass
class AutoscalerConfig:
    """Thresholds for SLO-driven replica elasticity.

    Pressure (any of): smoothed slot occupancy above ``high_occupancy``,
    queue backlog above ``queue_per_slot_high`` waiting requests per
    aggregate slot, or the recent session-SLO miss rate above
    ``miss_rate_high``. Relaxation (all of): occupancy below
    ``low_occupancy`` with an empty backlog and no recent misses. Either
    condition must hold for ``sustain_ticks`` consecutive observations to
    fire, and after any decision the scaler sleeps ``cooldown_ticks`` so
    capacity changes settle before the signals are trusted again.
    """
    min_replicas: int = 1
    max_replicas: int = 8
    high_occupancy: float = 0.85
    low_occupancy: float = 0.30
    queue_per_slot_high: float = 1.0
    miss_rate_high: float = 0.05
    sustain_ticks: int = 3
    cooldown_ticks: int = 8
    ema: float = 0.5                 # occupancy smoothing weight (on history)


class Autoscaler:
    """Pure-signal replica-count controller.

    ``observe`` consumes one cluster-step observation and returns the
    decision for this tick: ``+1`` (add a replica), ``-1`` (retire one),
    or ``0``. It never touches the cluster itself — ``EdgeCluster.step``
    applies the decision — so decisions are a deterministic function of
    the observation sequence and unit-testable without any engine.
    """

    def __init__(self, cfg: Optional[AutoscalerConfig] = None):
        self.cfg = cfg if cfg is not None else AutoscalerConfig()
        if self.cfg.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.cfg.max_replicas < self.cfg.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        self.occ_ema = 0.0
        self.ticks = 0
        self._hi = 0                 # consecutive pressure observations
        self._lo = 0                 # consecutive relaxation observations
        self._cooldown = 0
        #: (tick_index, decision, reason) per nonzero decision
        self.events: List[Tuple[int, int, str]] = []

    def observe(self, *, n_replicas: int, occupancy: float,
                queue_per_slot: float = 0.0,
                miss_rate: float = 0.0) -> int:
        """One observation -> -1/0/+1. ``occupancy`` is the live-replica
        mean busy-slot fraction for the step, ``queue_per_slot`` the
        waiting requests per aggregate slot, ``miss_rate`` the recent
        session-SLO miss fraction."""
        w = self.cfg.ema
        self.occ_ema = (occupancy if self.ticks == 0
                        else w * self.occ_ema + (1 - w) * occupancy)
        self.ticks += 1
        pressure = (self.occ_ema > self.cfg.high_occupancy
                    or queue_per_slot > self.cfg.queue_per_slot_high
                    or miss_rate > self.cfg.miss_rate_high)
        relaxed = (self.occ_ema < self.cfg.low_occupancy
                   and queue_per_slot <= 0.0
                   and miss_rate <= 0.0)
        self._hi = self._hi + 1 if pressure else 0
        self._lo = self._lo + 1 if relaxed else 0
        if self._cooldown > 0:
            self._cooldown -= 1
            return 0
        if self._hi >= self.cfg.sustain_ticks \
                and n_replicas < self.cfg.max_replicas:
            self._hi = self._lo = 0
            self._cooldown = self.cfg.cooldown_ticks
            reason = ("occupancy" if self.occ_ema > self.cfg.high_occupancy
                      else "queue" if queue_per_slot
                      > self.cfg.queue_per_slot_high else "miss_rate")
            self.events.append((self.ticks - 1, +1, reason))
            return +1
        if self._lo >= self.cfg.sustain_ticks \
                and n_replicas > self.cfg.min_replicas:
            self._hi = self._lo = 0
            self._cooldown = self.cfg.cooldown_ticks
            self.events.append((self.ticks - 1, -1, "idle"))
            return -1
        return 0
