"""Continuous-batching split-serving: slot pool + mixed-mode decode loop.

The engine keeps a fixed pool of ``n_slots`` decode slots (KV caches /
recurrent states allocated once, recycled as sequences finish). Every engine
tick it:

1. admits pending requests from the bounded queue into free slots — all
   newly admitted prompts prefill in one batched full-sequence forward per
   prompt-length bucket (pad to power-of-two buckets to bound recompiles),
   routed through each request's admission-chosen bottleneck mode, and the
   resulting per-layer states scatter into the slots. Requests whose
   ``prompt_len + max_new_tokens`` cannot fit a full-attention cache are
   truncated or rejected (counted) instead of silently wrapping the rolling
   cache over the prompt;
2. steps each active request's *own* simulated mmWave channel and picks
   that request's bottleneck mode under the configured mode policy —
   ``adaptive`` (a ``ModeController``: vectorized re-selection from the
   link EWMA with dwell-time damping and deadline-aware escalation),
   ``per-tick`` (the orchestrator's scalar loop, the legacy default), or
   ``frozen`` (the admission-chosen mode for the session's whole life, the
   baseline the paper's dynamic claim is measured against) — for every
   tick of the next *decode window* (mode choice depends only on channel
   observations and token counts, never on decoded token values, so whole
   windows are decidable up front); and
3. dispatches the window as ONE jitted ``lax.scan`` of the mixed-mode
   decode step for the whole pool — per-slot positions (sequences are at
   different depths), per-slot mode indices (the bottleneck head is a
   gather over the stacked mode bank, not a Python branch), argmax + token
   feedback + position increments fused on device against donated pool
   buffers — and reads the window's int32 token block back one window
   late, overlapping the host sync and all host bookkeeping with the next
   window's device compute (see ``_step_device``);
4. accounts uplink bytes and simulated transfer latency per request at
   window-decision time and retires finished sessions at dispatch time,
   freeing their slots (token values land at materialization).

Free slots still ride through the decode step (the batch shape is static for
jit); their outputs are ignored and their state is fully overwritten at the
next admission. ``host_loop=True`` preserves the legacy synchronous
per-tick loop (one blocking argmax round-trip per tick) as the measured
baseline and equivalence oracle — ``tests/test_device_loop.py`` pins the
two loops token-identical.

For homogeneous full-attention archs the pool is *paged* by default
(``PagedPool``): KV rows live in fixed ``page_len``-row pages of ONE global
arena per leaf, each slot maps logical row ``t`` to arena page
``block_table[slot, t // page_len]``, and admission is page-budget-based —
a request is admitted when its worst-case page count fits the arena's
uncommitted pages (long prompts are admissible up to the whole arena, far
past the dense per-slot ``cache_len``), pages are allocated on demand tick
by tick, and requests PARK at the queue head under arena pressure instead
of being rejected. ``paged=False`` forces the dense pool (the legacy
capacity semantics); on every shape the dense pool can fit, the decoded
streams are pinned bit-identical between the two (``tests/test_paged.py``).

``mesh`` (a ``models.sharding.serving_mesh`` ``('dp','mp')`` mesh) shards
the whole data plane: pool state rides slot-over-``dp`` / KV-heads-over-
``mp`` (``pool_pspecs``), params ride TP-over-``mp`` (replicated over
``dp``), and the compiled steps — including the donated ``lax.scan``
device window — run under GSPMD with the bottleneck boundary pinned in a
replicated ``shard_map`` region. ``mesh=None`` (the default) is the
single-device engine, byte-for-byte unchanged; a dp-only mesh is pinned
token-bit-identical to it (``tests/test_sharded_serving.py``); ``mp > 1``
reassociates head reductions (numerically equivalent, not bit-exact) —
see ``docs/sharding.md``.
"""
from __future__ import annotations

import concurrent.futures as _cf
import functools
import heapq
import time
import weakref
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.core import split as SP
from repro.core.channel import Channel, tx_seconds
from repro.core.orchestrator import Orchestrator
from repro.models import sharding
from repro.models import transformer as T
from repro.serving.controller import ModeController
from repro.serving.session import Request, RequestQueue, Session
from repro.serving.telemetry import Telemetry, now as _now


def _slot_axis(cfg: ModelConfig) -> int:
    # homogeneous archs stack per-layer states into [L, B, ...] leaves;
    # heterogeneous archs keep a tuple of per-layer [B, ...] pytrees
    return 1 if cfg.homogeneous else 0


def _put_rows(pool_states, batch_states, idx, axis: int):
    """Scatter rows 0..len(idx)-1 of a batched state pytree into the pool
    rows ``idx`` (distinct by construction) — the one shared scatter every
    admission/inject path builds on."""
    n = idx.shape[0]

    def put(p, b):
        rows = jnp.moveaxis(b, axis, 0)[:n]
        pb = jnp.moveaxis(p, axis, 0).at[idx].set(rows)
        return jnp.moveaxis(pb, 0, axis)

    return jax.tree.map(put, pool_states, batch_states)


@functools.partial(jax.jit, static_argnums=(3,))
def scatter_rows(pool_states, batch_states, idx, axis: int):
    """THE pool row scatter, shared by both pools: dense slots
    (``SlotPool.write_rows``, ``axis = _slot_axis(cfg)``) and arena pages
    (``PagedPool.write_pages``, ``axis = 1`` — a page is just a row of the
    page axis). One jitted dispatch; sharding-aware by construction: on a
    serving mesh the donated/updated pool operand carries its
    ``pool_pspecs`` sharding and GSPMD keeps ``.at[].set`` output sharding
    equal to the operand's, so scatters never unshard the pool."""
    return _put_rows(pool_states, batch_states, idx, axis)


@functools.partial(jax.jit, static_argnums=(2,))
def gather_rows(pool_states, idx, axis: int):
    """The gather inverse of :func:`scatter_rows`, shared the same way
    (``SlotPool.read_rows`` on the slot axis, ``PagedPool.read_pages`` on
    the page axis): pull rows ``idx`` out of the pool as a batched state
    pytree with batch = ``len(idx)`` on ``axis``. Sharded pools gather
    into fully host-addressable outputs — the migration snapshot path
    reads them with plain ``np.asarray`` regardless of mesh."""
    def take(p):
        return jnp.moveaxis(jnp.moveaxis(p, axis, 0)[idx], 0, axis)

    return jax.tree.map(take, pool_states)


@functools.partial(jax.jit, static_argnums=(6,), donate_argnums=(0, 1))
def _admit_scatter(pool_states, positions, cur_tokens, batch_states, slots,
                   pos_vals, axis: int, first_tokens):
    """Device-resident admission: install a prefilled batch's states,
    positions, and first generated tokens into their pool slots in one
    dispatch. The pool state and positions are donated — admission updates
    the resident pool in place instead of copying it. ``cur_tokens`` is
    deliberately NOT donated: the engine's one-tick-lagged sync may still
    hold that buffer for a pending host read (and it is tiny)."""
    n = slots.shape[0]
    new_states = _put_rows(pool_states, batch_states, slots, axis)
    positions = positions.at[slots].set(pos_vals)
    cur_tokens = cur_tokens.at[slots].set(
        first_tokens[:n].reshape((n,) + cur_tokens.shape[1:]))
    return new_states, positions, cur_tokens


@functools.partial(jax.jit, donate_argnums=(0,))
def _admit_meta(positions, cur_tokens, slots, pos_vals, first_tokens):
    """Paged device-resident admission: the prefill already wrote the arena
    through the group's block tables, so only positions and first tokens
    scatter (``cur_tokens`` not donated — same pending-read caveat as
    :func:`_admit_scatter`)."""
    n = slots.shape[0]
    positions = positions.at[slots].set(pos_vals)
    cur_tokens = cur_tokens.at[slots].set(
        first_tokens[:n].reshape((n,) + cur_tokens.shape[1:]))
    return positions, cur_tokens


def _bucket_len(n: int, lo: int = 8) -> int:
    """Pad ``n`` up to the next power-of-two bucket (>= ``lo``) so the
    jitted prefill sees O(log max_prompt) distinct shapes, not one per
    prompt length."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _group_by_bucket(admits):
    """Group (req, slot, mode) admissions by prompt-length bucket."""
    groups: Dict[int, list] = {}
    for a in admits:
        groups.setdefault(_bucket_len(a[0].prompt_len), []).append(a)
    return groups


class _EngineSteps:
    """The jitted step/prefill callables one engine configuration needs."""

    def __init__(self, mono_step, mono_step_dev, mono_prefill,
                 mixed_step=None, mixed_step_dev=None, mixed_prefill=None):
        self.mono_step = mono_step
        self.mono_step_dev = mono_step_dev
        self.mono_prefill = mono_prefill
        self.mixed_step = mixed_step
        self.mixed_step_dev = mixed_step_dev
        self.mixed_prefill = mixed_prefill


def _window_scan_body(cfg: ModelConfig, mesh, *, mixed: bool,
                      fused_tail: bool, telemetry: bool = False):
    """The ONE place the device-resident decode window's scan body is
    defined — shared by the dense and paged step builders (``bt=None``
    selects dense) and by the plain and mixed variants.

    A [K, B] mode matrix drives K whole ticks in one ``lax.scan``: token
    feedback, position increments and per-tick mode gathers all stay on
    device. With ``fused_tail`` (the default) each tick asks the model step
    for tokens directly (``return_tokens=True`` ->
    ``ops.decode_tail_op``), so a tick lowers to the boundary kernel plus
    ONE fused norm/head/argmax tail kernel with the token fed straight back
    into the next tick's embed — no separate head/argmax/feedback HLOs and
    no [B, V] f32 logits in HBM. ``fused_tail=False`` keeps the legacy
    logits+argmax body: the equivalence oracle ``tests/test_device_loop.py``
    pins token streams against.

    ``telemetry``: the body additionally emits a per-tick int32 telemetry
    row ``[wire_bytes, live_slots, mode_hist[0..M-1]]`` computed from the
    window's frozen live mask (``active``) and the per-mode payload table
    (``pb_table``) — stacked to a ``[K, 2 + M]`` block that rides the scan
    OUTPUT (result index 4) and is folded into the metrics registry one
    window late, exactly like token values. Pure integer arithmetic on
    inputs the untraced body already has: token bits are untouched."""
    def run(params, stacked, tok, states, positions, modes_k, bt,
            pb_table=None, active=None):
        def body(carry, modes):
            tok, states, positions = carry
            if mixed:
                out, new_states = SP.split_decode_step_mixed(
                    params, stacked, tok, states, positions, cfg, modes,
                    block_table=bt, mesh=mesh, return_tokens=fused_tail)
            else:
                out, new_states = T.decode_step(
                    params, tok, states, positions, cfg, block_table=bt,
                    return_tokens=fused_tail)
            nxt = out if fused_tail else jnp.argmax(out, axis=-1)
            nxt = nxt.astype(jnp.int32).reshape(tok.shape)
            if telemetry:
                row = jnp.concatenate([
                    jnp.sum(active * pb_table[modes])[None],
                    jnp.sum(active)[None],
                    jnp.zeros(pb_table.shape[0], jnp.int32)
                       .at[modes].add(active),
                ]).astype(jnp.int32)
                return (nxt, new_states, positions + 1), (nxt, row)
            return (nxt, new_states, positions + 1), nxt

        carry, out = jax.lax.scan(body, (tok, states, positions), modes_k)
        if telemetry:
            toks, tel = out
            return (*carry, toks, tel)
        return (*carry, out)

    return run


def _paged_steps(cfg: ModelConfig, mixed: bool, mesh=None,
                 fused_tail: bool = True,
                 telemetry: bool = False) -> _EngineSteps:
    """Paged variants of the engine closures: every decode step threads the
    ``[B, nb]`` block table through to the paged attention path, and
    prefill writes straight into the (donated) page arena through the
    group's block tables instead of materializing dense per-row caches.
    The closures are shape-polymorphic in the table width (pow2-bucketed by
    the pool), so one set serves every arena size. ``mesh`` builds the
    sharded variants (see :func:`_compiled_steps`); ``telemetry`` the
    instrumented window bodies (two trailing ``pb_table``/``active``
    args ahead of ``bt``)."""
    run_mono = _window_scan_body(cfg, mesh, mixed=False,
                                 fused_tail=fused_tail, telemetry=telemetry)

    @jax.jit
    def mono_step(params, tok, states, pos, bt):
        return T.decode_step(params, tok, states, pos, cfg, block_table=bt)

    if telemetry:
        @functools.partial(jax.jit, donate_argnums=(2, 3))
        def mono_step_dev(params, tok, states, positions, modes_k,
                          pb_table, active, bt):
            return run_mono(params, None, tok, states, positions, modes_k,
                            bt, pb_table, active)
    else:
        @functools.partial(jax.jit, donate_argnums=(2, 3))
        def mono_step_dev(params, tok, states, positions, modes_k, bt):
            return run_mono(params, None, tok, states, positions, modes_k,
                            bt)

    @functools.partial(jax.jit, donate_argnums=(3,))
    def mono_prefill(params, toks, lengths, arena, bt):
        logits, new_arena = T.prefill(params, toks, cfg, arena,
                                      lengths=lengths, block_table=bt)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_arena

    if not mixed:
        return _EngineSteps(mono_step, mono_step_dev, mono_prefill)

    run_mixed = _window_scan_body(cfg, mesh, mixed=True,
                                  fused_tail=fused_tail, telemetry=telemetry)

    @jax.jit
    def mixed_step(params, stacked, tok, states, positions, modes, bt):
        return SP.split_decode_step_mixed(params, stacked, tok, states,
                                          positions, cfg, modes,
                                          block_table=bt, mesh=mesh)

    if telemetry:
        @functools.partial(jax.jit, donate_argnums=(3, 4))
        def mixed_step_dev(params, stacked, tok, states, positions,
                           modes_k, pb_table, active, bt):
            return run_mixed(params, stacked, tok, states, positions,
                             modes_k, bt, pb_table, active)
    else:
        @functools.partial(jax.jit, donate_argnums=(3, 4))
        def mixed_step_dev(params, stacked, tok, states, positions,
                           modes_k, bt):
            return run_mixed(params, stacked, tok, states, positions,
                             modes_k, bt)

    @functools.partial(jax.jit, donate_argnums=(4,))
    def mixed_prefill(params, stacked, toks, lengths, arena, modes, bt):
        logits, new_arena = SP.split_prefill_mixed(
            params, stacked, toks, arena, cfg, modes, lengths=lengths,
            block_table=bt, mesh=mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_arena

    return _EngineSteps(mono_step, mono_step_dev, mono_prefill,
                        mixed_step, mixed_step_dev, mixed_prefill)


@functools.lru_cache(maxsize=None)
def _compiled_steps(cfg: ModelConfig, cache_len: int, mixed: bool,
                    paged: bool = False, mesh=None,
                    fused_tail: bool = True,
                    telemetry: bool = False) -> _EngineSteps:
    """Build (once per ``(cfg, cache_len)``) the jitted decode/prefill
    closures every ``ContinuousBatchingEngine`` runs on. Cached at module
    level so N engines of the same configuration — a cluster's replicas,
    an A/B benchmark's paired engines — share ONE set of function objects
    and therefore ONE XLA compile cache, instead of re-tracing per engine.
    The closures are pure functions of their arguments (params ride in as
    an argument), so sharing them across engines is sound; donation is a
    per-call property and composes with sharing.

    ``mesh`` (hashable, part of the cache key: mesh shape AND device
    assignment, since the ``shard_map`` boundary region binds concrete
    devices) builds the mesh-aware variants: the mixed steps thread the
    mesh into ``split_decode_step_mixed`` / ``split_prefill_mixed``, and
    sharding of the donated scan carries follows the ``NamedSharding``-
    annotated inputs the engine places (GSPMD propagates input shardings
    through the whole step, donation included). Engines on the SAME mesh —
    e.g. benchmark A/B pairs — still share one compile cache; cluster
    replicas on disjoint device subsets get one entry each.

    ``fused_tail`` (part of the cache key) selects the fused decode-tail
    window body — see :func:`_window_scan_body`; ``False`` builds the
    legacy logits+argmax loop the device-loop equivalence tests run.

    ``telemetry`` (part of the cache key — instrumented and plain engines
    must not share traced functions) builds the window bodies that emit
    the per-tick int32 telemetry block; the dev steps then take two extra
    args (``pb_table [M]``, ``active [B]``) after the mode matrix."""
    if paged:
        return _paged_steps(cfg, mixed, mesh, fused_tail, telemetry)

    run_mono = _window_scan_body(cfg, mesh, mixed=False,
                                 fused_tail=fused_tail, telemetry=telemetry)

    @jax.jit
    def mono_step(params, tok, states, pos):
        return T.decode_step(params, tok, states, pos, cfg)

    # device-resident decode window: a [K, B] mode matrix drives K
    # whole ticks in ONE jitted lax.scan — argmax + token feedback +
    # position increments all on device, slot-pool state and positions
    # donated so XLA updates the resident pool in place instead of
    # copying the whole KV/recurrent pool every tick. Mode choice and
    # budget-based retirement depend only on channels and counts (never
    # on token values), so the host precomputes the window and reads
    # the [K, B] token block back one window late. Free slots ride
    # along (their positions drift, but admission rewrites them).
    if telemetry:
        @functools.partial(jax.jit, donate_argnums=(2, 3))
        def mono_step_dev(params, tok, states, positions, modes_k,
                          pb_table, active):
            return run_mono(params, None, tok, states, positions, modes_k,
                            None, pb_table, active)
    else:
        @functools.partial(jax.jit, donate_argnums=(2, 3))
        def mono_step_dev(params, tok, states, positions, modes_k):
            return run_mono(params, None, tok, states, positions, modes_k,
                            None)

    @jax.jit
    def mono_prefill(params, toks, lengths):
        # fresh zero states materialize inside the jit (shapes are
        # static per bucket) — no per-admission host allocation; the
        # argmax rides inside the jit so only int32 tokens cross the
        # host boundary
        states = T.init_decode_state(cfg, toks.shape[0], cache_len)
        logits, new_states = T.prefill(params, toks, cfg, states,
                                       lengths=lengths)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_states

    if not mixed:
        return _EngineSteps(mono_step, mono_step_dev, mono_prefill)

    @jax.jit
    def mixed_step(params, stacked, tok, states, positions, modes):
        return SP.split_decode_step_mixed(params, stacked, tok,
                                          states, positions, cfg, modes,
                                          mesh=mesh)

    run_mixed = _window_scan_body(cfg, mesh, mixed=True,
                                  fused_tail=fused_tail, telemetry=telemetry)

    if telemetry:
        @functools.partial(jax.jit, donate_argnums=(3, 4))
        def mixed_step_dev(params, stacked, tok, states, positions,
                           modes_k, pb_table, active):
            return run_mixed(params, stacked, tok, states, positions,
                             modes_k, None, pb_table, active)
    else:
        @functools.partial(jax.jit, donate_argnums=(3, 4))
        def mixed_step_dev(params, stacked, tok, states, positions,
                           modes_k):
            return run_mixed(params, stacked, tok, states, positions,
                             modes_k, None)

    @jax.jit
    def mixed_prefill(params, stacked, toks, lengths, modes):
        states = T.init_decode_state(cfg, toks.shape[0], cache_len)
        logits, new_states = SP.split_prefill_mixed(
            params, stacked, toks, states, cfg, modes,
            lengths=lengths, mesh=mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_states

    return _EngineSteps(mono_step, mono_step_dev, mono_prefill,
                        mixed_step, mixed_step_dev, mixed_prefill)


class SlotPool:
    """Fixed pool of decode slots with recycled cache/recurrent state.

    ``mesh``: serving ``('dp','mp')`` mesh — the state tree is placed with
    ``sharding.pool_pspecs`` (slot axis over ``dp``, KV head groups over
    ``mp``, non-dividing dims replicated) and every ``read_rows``/
    ``write_rows`` keeps that placement (the shared jitted gather/scatter
    preserves operand sharding)."""

    paged = False

    def __init__(self, cfg: ModelConfig, n_slots: int, cache_len: int, *,
                 mesh=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.mesh = mesh
        self.states = T.init_decode_state(cfg, n_slots, cache_len)
        if mesh is not None:
            self.states = sharding.shard_pool(self.states, mesh,
                                              slot_axis=_slot_axis(cfg))
        self.positions = np.zeros(n_slots, np.int32)
        self._free = list(range(n_slots - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, slot: int):
        if not 0 <= slot < self.n_slots:
            raise ValueError(
                f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double release of slot {slot}")
        self.positions[slot] = 0
        self._free.append(slot)

    def write_rows(self, batch_states, slots, positions):
        """Install rows 0..len(slots)-1 of a freshly prefilled batched state
        into the given slots in one scatter (full overwrite — whatever a
        previous occupant left behind is gone)."""
        self.states = scatter_rows(self.states, batch_states,
                                   jnp.asarray(slots, jnp.int32),
                                   _slot_axis(self.cfg))
        for s, p in zip(slots, positions):
            self.positions[s] = p

    def read_rows(self, slots):
        """The gather inverse of :meth:`write_rows`: extract the given
        slots' decode state (KV cache rows / recurrent carries, attention
        cache contents included) as a batched state pytree with batch =
        ``len(slots)`` on the slot axis — the exact shape ``write_rows``
        accepts, so ``write_rows(read_rows(s), s, pos)`` is an identity and
        a row read here injects bit-exactly into any same-config pool (the
        live-migration snapshot path)."""
        return gather_rows(self.states, jnp.asarray(slots, jnp.int32),
                           _slot_axis(self.cfg))


@functools.partial(jax.jit, static_argnums=(3,))
def _gather_pages(arena, bt, used, plen: int):
    """Gather block-table pages into logical row order: arena leaves
    ``[L, n_pages + 1, plen, ...]`` + table ``[n, nb]`` -> dense
    ``[L, n, nb * plen, ...]`` blocks. Chunks at or past each row's
    allocation (``used``) are zeroed — they point at the scratch page,
    whose contents are drifting-write junk."""
    nb = bt.shape[1]
    keep = jnp.arange(nb)[None, :] < used[:, None]        # [n, nb]

    def take(a):
        g = a[:, bt]                                      # [L, n, nb, plen, *]
        m = keep.reshape((1,) + keep.shape + (1,) * (g.ndim - 3))
        g = jnp.where(m, g, 0)
        return g.reshape(g.shape[:2] + (nb * g.shape[3],) + g.shape[4:])

    return jax.tree.map(take, arena)


@functools.partial(jax.jit, static_argnums=(4,))
def _scatter_pages(arena, rows, bt, used, plen: int):
    """The inverse of :func:`_gather_pages`: scatter dense logical-row
    blocks ``[L, n, nb * plen, ...]`` back through the block table; chunks
    past a row's allocation get an out-of-bounds page index and drop."""
    nb = bt.shape[1]
    keep = jnp.arange(nb)[None, :] < used[:, None]        # [n, nb]

    def put(a, r):
        rc = r.reshape(r.shape[:2] + (nb, plen) + r.shape[3:])
        pg = jnp.where(keep, bt, a.shape[1])
        return a.at[:, pg].set(rc, mode="drop")

    return jax.tree.map(put, arena, rows)


class PagedPool:
    """Paged decode-state pool: one global page arena per KV leaf, per-slot
    block tables, and a page free list.

    The arena holds ``n_pages + 1`` pages of ``page_len`` rows per leaf
    (``[L, n_pages + 1, page_len, n_kv, hd]``); page 0 is the reserved
    scratch page — free slots carry all-zero block-table rows, so their
    drifting decode writes land there and are never read unmasked. Real
    pages are 1..n_pages. A slot's logical row ``t`` (== absolute position
    ``t``; full attention never wraps) lives at
    ``arena[block_np[slot, t // page_len], t % page_len]``.

    Admission-side accounting: ``commit_pages`` reserves a session's
    worst-case page count up front and ``pages_available`` subtracts every
    resident session's still-undrawn reservation from the free list, so the
    engine only admits what on-demand ``alloc_pages`` growth can always
    satisfy — backpressure parks requests in the queue instead of
    deadlocking mid-decode.

    ``mesh``: serving mesh — the arena shards its PAGE axis over ``dp``
    (pages are this pool's slot axis) and KV head groups over ``mp``. The
    arena allocation is padded up to a ``dp``-divisible page count (extra
    pages never enter the free list, so capacity semantics are unchanged)
    because the natural ``n_pages + 1`` (scratch page 0 included) is
    usually odd and would silently fall back to a replicated arena.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, n_slots: int, cache_len: int, *,
                 page_len: int = 8, n_pages: Optional[int] = None,
                 mesh=None):
        if not (T.full_attention_arch(cfg) and cfg.homogeneous):
            raise ValueError(
                "paged pools need a homogeneous full-attention arch — "
                "windowed/recurrent decode state is bounded by construction "
                "and keeps the dense SlotPool")
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len           # dense-equivalent per-slot rows
        self.page_len = page_len
        self.mesh = mesh
        per_slot = -(-cache_len // page_len)
        self.n_pages = n_pages if n_pages is not None else n_slots * per_slot
        #: arena rows — ONE session's max context (it may claim every page)
        self.capacity = self.n_pages * page_len
        n_arena = self.n_pages + 1
        if mesh is not None:
            dp = mesh.shape["dp"]
            n_arena = -(-n_arena // dp) * dp
        self.states = T.init_decode_state(cfg, n_arena, page_len)
        if mesh is not None:
            self.states = sharding.shard_pool(self.states, mesh, slot_axis=1)
        self.positions = np.zeros(n_slots, np.int32)
        self._free = list(range(n_slots - 1, -1, -1))
        self.block_np = np.zeros((n_slots, self.n_pages), np.int32)
        self.pages_used = np.zeros(n_slots, np.int32)
        self._committed = np.zeros(n_slots, np.int32)
        self._free_pages = list(range(self.n_pages, 0, -1))  # pop -> 1, 2, ..
        self._free_page_set = set(self._free_pages)
        self.peak_pages_in_use = 0

    # -- slot lifecycle (the SlotPool contract) -------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, slot: int):
        if not 0 <= slot < self.n_slots:
            raise ValueError(
                f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double release of slot {slot}")
        for i in range(int(self.pages_used[slot])):
            self._push_free_page(int(self.block_np[slot, i]))
        self.block_np[slot, :] = 0
        self.pages_used[slot] = 0
        self._committed[slot] = 0
        self.positions[slot] = 0
        self._free.append(slot)

    # -- page accounting ------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free_pages)

    @property
    def pages_available(self) -> int:
        """Pages a NEW admission may claim: the free list minus pages
        already promised (committed) to resident sessions but not drawn."""
        reserved = int(self._committed.sum()) - int(self.pages_used.sum())
        return len(self._free_pages) - reserved

    def _push_free_page(self, page: int):
        if not 1 <= page <= self.n_pages:
            raise ValueError(
                f"page {page} out of range [1, {self.n_pages}]")
        if page in self._free_page_set:
            raise ValueError(f"double free of page {page}")
        self._free_pages.append(page)
        self._free_page_set.add(page)

    def commit_pages(self, slot: int, n_total: int):
        """Reserve a session's worst-case page count (the engine admits only
        when :attr:`pages_available` covers it), so later on-demand
        :meth:`alloc_pages` growth can never exhaust the arena mid-decode."""
        self._committed[slot] = max(int(n_total), int(self.pages_used[slot]))

    def alloc_pages(self, slot: int, n_rows: int):
        """Ensure pages covering logical rows ``0..n_rows-1`` are allocated
        to the slot (idempotent; growth draws from the free list)."""
        need = -(-max(int(n_rows), 1) // self.page_len)
        have = int(self.pages_used[slot])
        if need <= have:
            return
        if need - have > len(self._free_pages):
            raise RuntimeError(
                f"page arena exhausted: slot {slot} needs {need - have} more "
                f"pages, {len(self._free_pages)} free (admission commitment "
                f"accounting should have prevented this)")
        for i in range(have, need):
            page = self._free_pages.pop()
            self._free_page_set.discard(page)
            self.block_np[slot, i] = page
        self.pages_used[slot] = need
        self._committed[slot] = max(int(self._committed[slot]), need)
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)

    # -- block tables ---------------------------------------------------------
    def table_width(self) -> int:
        """Pow2 bucket (>= 1, <= n_pages) covering every slot's allocated
        pages — the block-table width the compiled steps see, so the decode
        gather cost tracks the longest LIVE sequence, not the whole arena,
        and the jit sees O(log n_pages) distinct widths."""
        hi = max(int(self.pages_used.max()), 1)
        b = 1
        while b < hi:
            b <<= 1
        return min(b, self.n_pages)

    def block_table(self):
        """Device copy of the pool block table at the current bucketed width
        (a fresh buffer per call — never donated; the host-side ``block_np``
        stays authoritative). On a mesh the slot axis rides ``dp`` like
        every other per-slot decode input."""
        return sharding.shard_batch(
            jnp.asarray(self.block_np[:, :self.table_width()]), self.mesh)

    # -- row/page I/O ---------------------------------------------------------
    def write_rows(self, batch_states, slots, positions):
        """Block-table-aware scatter: install dense logical-row blocks
        ``[L, n, R, ...]`` into each slot's pages, allocating on demand for
        the given positions — ``write_rows(read_rows(s), s, pos)`` is
        bit-exact over every allocated page."""
        R = jax.tree.leaves(batch_states)[0].shape[2]
        nb = R // self.page_len
        for s, p in zip(slots, positions):
            if -(-max(int(p), 1) // self.page_len) > nb:
                raise ValueError(
                    f"{R} rows cannot cover position {p} at page_len "
                    f"{self.page_len}")
            self.alloc_pages(s, max(int(p), 1))
            self.positions[s] = int(p)
        sl = np.asarray(slots, np.int64)
        self.states = _scatter_pages(
            self.states, batch_states,
            jnp.asarray(self.block_np[sl][:, :nb], jnp.int32),
            jnp.asarray(np.minimum(self.pages_used[sl], nb), jnp.int32),
            self.page_len)

    def read_rows(self, slots):
        """The gather inverse of :meth:`write_rows`: each slot's logical
        rows in order, ``[L, n, table_width() * page_len, ...]`` per leaf,
        with unallocated chunks zeroed."""
        sl = np.asarray(slots, np.int64)
        nb = self.table_width()
        return _gather_pages(
            self.states, jnp.asarray(self.block_np[sl][:, :nb], jnp.int32),
            jnp.asarray(self.pages_used[sl], jnp.int32), self.page_len)

    def read_pages(self, slot: int):
        """A slot's ALLOCATED pages in block-table order — ``[L, nbu, plen,
        ...]`` per leaf, the migration payload (pages only, no dense
        expansion, no scratch junk)."""
        nbu = max(int(self.pages_used[slot]), 1)
        bt = jnp.asarray(self.block_np[slot, :nbu], jnp.int32)
        return gather_rows(self.states, bt, 1)

    def write_pages(self, slot: int, blocks, position: int):
        """Install a migrated-in session's page block (the exact
        :meth:`read_pages` layout) into freshly allocated local pages."""
        nbu = jax.tree.leaves(blocks)[0].shape[1]
        self.alloc_pages(slot, nbu * self.page_len)
        bt = jnp.asarray(self.block_np[slot, :nbu], jnp.int32)
        self.states = scatter_rows(self.states, blocks, bt, 1)
        self.positions[slot] = int(position)


class ContinuousBatchingEngine:
    """Split-inference engine with per-request dynamic bottleneck modes.

    ``orchestrator`` is shared (mode calibration is global) but tracks one
    link state per request id; ``default_channel`` serves requests that
    arrive without their own ``Channel``.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 cache_len: int = 128,
                 orchestrator: Optional[Orchestrator] = None,
                 controller: Optional[ModeController] = None,
                 freeze_modes: bool = False,
                 default_channel: Optional[Channel] = None,
                 max_pending: int = 64,
                 host_loop: bool = False,
                 max_window: int = 16,
                 paged: Optional[bool] = None,
                 page_len: int = 8,
                 n_pages: Optional[int] = None,
                 mesh=None,
                 fused_tail: bool = True,
                 telemetry: Optional[Telemetry] = None):
        if controller is not None:
            if freeze_modes:
                raise ValueError("controller and freeze_modes are mutually "
                                 "exclusive mode policies")
            if orchestrator is not None and orchestrator is not controller.orch:
                raise ValueError("pass either the controller (which owns its "
                                 "orchestrator) or an orchestrator, not both")
            orchestrator = controller.orch
        # mesh placement first: params ride TP-over-mp (replicated over
        # dp), so every jitted step below sees committed inputs
        self.mesh = mesh
        self.params = sharding.shard_params(params, mesh)
        self.cfg = cfg
        self.orch = orchestrator
        self.controller = controller
        self.freeze_modes = freeze_modes
        self.default_channel = default_channel
        # homogeneous full-attention archs page their KV by default (paged
        # admission lifts the per-slot cache_len cap to the whole arena);
        # windowed / recurrent archs keep the dense pool — their decode
        # state is bounded by construction and has nothing to page
        paged_ok = T.full_attention_arch(cfg) and cfg.homogeneous
        self.paged = paged_ok if paged is None else bool(paged)
        if self.paged and not paged_ok:
            raise ValueError(
                "paged=True needs a homogeneous full-attention arch; "
                "windowed/recurrent decode state is bounded by construction")
        self.pool = (PagedPool(cfg, n_slots, cache_len, page_len=page_len,
                               n_pages=n_pages, mesh=mesh)
                     if self.paged
                     else SlotPool(cfg, n_slots, cache_len, mesh=mesh))
        self.queue = RequestQueue(max_pending)
        self.active: Dict[int, Session] = {}          # slot -> session
        self.finished: List[Session] = []
        self.tick = 0
        self.mode_mix_ticks = 0       # decode ticks with >= 2 distinct modes
        self.decode_ticks = 0
        self.decoded_slot_ticks = 0   # sum over decode ticks of live slots:
        #                               tokens decoded ON this engine (a
        #                               migrated-in session's earlier tokens
        #                               were decoded elsewhere)
        self.prefill_calls = 0        # jitted batched-prefill dispatches
        self.prefill_tokens = 0       # true prompt tokens prefilled
        self.prefill_padded_tokens = 0  # incl. bucket/batch padding
        self.requests_over_capacity = 0  # rejected: prompt can't fit cache
        self.requests_truncated = 0   # max_new_tokens clipped to cache
        self.requests_parked = 0      # deferred at least once: arena pressure
        self._parked_rids: set = set()
        # full-attention archs must fit prompt + generation in the cache —
        # the whole page arena when paged (one session may claim every
        # page), the per-slot cache_len when dense; windowed/recurrent
        # archs are bounded-state by construction
        self.max_context: Optional[int] = (
            self.pool.capacity if self.paged
            else cache_len if T.full_attention_arch(cfg) else None)
        bank = params.get("bneck_modes") or ()
        self.stacked_bank = (bottleneck.bank_stack(bank, cfg.split)
                             if len(bank) else None)
        if self.stacked_bank is not None:
            # the boundary's shard_map region consumes the bank fully
            # replicated (every shard runs the full-batch boundary)
            self.stacked_bank = sharding.replicate(self.stacked_bank, mesh)
        if controller is not None and self.stacked_bank is None:
            raise ValueError("adaptive mode control needs a bottleneck mode "
                             "bank in params (init_split_params)")
        self._tok_shape = ((n_slots, cfg.n_codebooks, 1)
                           if cfg.frontend == "audio" and cfg.n_codebooks > 1
                           else (n_slots, 1))
        # fused_tail: window ticks end in the fused norm/head/argmax tail
        # kernel (see _window_scan_body); False keeps the legacy
        # logits+argmax window — the token-identity oracle in tests
        self.fused_tail = bool(fused_tail)
        # telemetry is OPTIONAL and additive: None (the default) compiles
        # and runs the exact pre-telemetry engine; a Telemetry object
        # selects the instrumented window bodies (a separate compile-cache
        # entry) and turns on the guarded host-side observations below
        self._tel = telemetry
        steps = _compiled_steps(cfg, cache_len,
                                self.stacked_bank is not None, self.paged,
                                mesh, self.fused_tail, self._tel is not None)
        self.host_loop = host_loop
        self.max_window = max(int(max_window), 1)
        if not host_loop:
            # the device loop donates the pool state pytree; freshly
            # initialized states may alias one zeros buffer across several
            # leaves (XLA rejects donating the same buffer twice), so force
            # each leaf onto its own buffer once, up front
            self.pool.states = jax.tree.map(lambda a: a.copy(),
                                            self.pool.states)
        # device loop: tokens and positions are device-resident; the host
        # only ever receives small int32 token arrays, one tick late
        self.cur_tokens = (np.zeros(self._tok_shape, np.int32) if host_loop
                           else sharding.shard_batch(
                               jnp.zeros(self._tok_shape, jnp.int32), mesh))
        self._positions = sharding.shard_batch(
            jnp.zeros(n_slots, jnp.int32), mesh)
        #: (snapshot of (slot, session) pairs, step future) for the most
        #: recently dispatched tick — materialized one tick later so the
        #: host<->device sync overlaps the NEXT tick's device compute
        self._inflight: Optional[tuple] = None
        #: future of the last dispatched device step; while it is pending,
        #: ``pool.states`` / ``cur_tokens`` / ``_positions`` are stale (and
        #: possibly donated) — ``_sync_device_state`` re-homes them
        self._future: Optional[_cf.Future] = None
        #: per-ENGINE pipeline worker (lazily created): jitted decode steps
        #: execute here so the XLA call (which releases the GIL) overlaps
        #: the main thread's per-tick orchestrator / controller / channel
        #: bookkeeping. A single worker keeps execution strictly FIFO —
        #: step t+1's closure reads step t's future, so device-side
        #: ordering (and therefore every decoded token) is deterministic.
        #: Per-engine (not module-global) so N cluster replicas pipeline
        #: their device loops CONCURRENTLY instead of serializing through
        #: one shared FIFO thread — and so one engine's donated-buffer
        #: lifetime can never interleave with another's. ``close()`` (or
        #: the context manager) shuts it down.
        self._exec: Optional[_cf.ThreadPoolExecutor] = None
        self._mode_pb: Dict[int, int] = {}   # per-mode wire bytes memo
        #: not-yet-"arrived" requests as a min-heap on (arrival_tick, seq):
        #: a fleet-scale load script submits thousands of future arrivals
        #: up front, so the per-tick due-scan and the idle-skip peek must
        #: be O(log n)/O(1), not O(n) list scans
        self._pending: List[Tuple[int, int, Request]] = []
        self._pending_seq = 0                         # FIFO tiebreak

        self._mono_step = steps.mono_step
        self._mono_step_dev = steps.mono_step_dev
        self._mono_prefill = steps.mono_prefill
        self._mixed_step = steps.mixed_step
        self._mixed_step_dev = steps.mixed_step_dev
        self._mixed_prefill = steps.mixed_prefill

        #: host-side fold of the device telemetry blocks (wire bytes,
        #: decoded slot-ticks, per-mode tick histogram) — the oracle the
        #: telemetry tests cross-check against host wire accounting
        self.device_tel = {"wire_bytes": 0, "slot_ticks": 0,
                           "mode_ticks": np.zeros(0, np.int64)}
        self._pb_table = None
        if self._tel is not None:
            n_modes = (cfg.split.n_modes
                       if self.stacked_bank is not None else 1)
            self.device_tel["mode_ticks"] = np.zeros(n_modes, np.int64)
            self._pb_table = sharding.replicate(
                jnp.asarray([self._payload_bytes(m)
                             for m in range(n_modes)], jnp.int32), mesh)
            if self.controller is not None:
                tel = self._tel
                self.controller.on_escalate = (
                    lambda rid, tick, frm, to: (
                        tel.inc("engine.mode_escalations"),
                        tel.instant("mode_escalate", rid=rid, tick=tick,
                                    cat="mode", frm=frm, to=to)))

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request for its arrival tick. Returns False if the
        admission queue rejected it (back-pressure)."""
        req.t_submit = _now()
        if req.arrival_tick > self.tick:
            heapq.heappush(self._pending,
                           (req.arrival_tick, self._pending_seq, req))
            self._pending_seq += 1
            return True
        return self.queue.submit(req)

    def _deliver_arrivals(self):
        # heap order == (arrival_tick, submission order): identical to the
        # old sort-by-arrival_tick drain (Python sorts are stable)
        while self._pending and self._pending[0][0] <= self.tick:
            r = heapq.heappop(self._pending)[2]
            r.t_submit = _now()
            self.queue.submit(r)

    # -- admission ------------------------------------------------------------
    def _admit(self):
        """Pop admissible requests into free slots, then prefill every new
        prompt in one batched full-sequence forward per length bucket.

        Loops because a budget-1 session completes inside its own prefill
        (the prefill argmax is its whole generation) and frees its slot for
        the next queued request within the same tick."""
        while self.pool.n_free and len(self.queue):
            if not self.host_loop:
                # admission scatters into the resident pool buffers — the
                # pipeline must land the in-flight step first
                self._sync_device_state()
            admits = self._collect_admits()
            if not admits:            # everything popped was over capacity
                break
            for blen, group in sorted(_group_by_bucket(admits).items()):
                self._prefill_group(blen, group)

    def _collect_admits(self) -> List[tuple]:
        admits: List[tuple] = []      # (req, slot, mode, budget, capacity)
        while self.pool.n_free and len(self.queue):
            req = self.queue.peek()
            budget = req.max_new_tokens
            if self.max_context is not None:
                if req.prompt_len > self.max_context:
                    # the prompt alone cannot fit: admitting would wrap the
                    # rolling cache over its own context — reject instead
                    self.queue.pop()
                    self.requests_over_capacity += 1
                    if self._tel is not None:
                        self._tel.instant("reject_over_capacity",
                                          cat="admission", rid=req.rid,
                                          prompt_len=req.prompt_len)
                    continue
                # the first generated token is the prefill argmax (no cache
                # write); decode writes land at prompt_len..prompt_len+b-2,
                # so b <= max_context - prompt_len + 1 never wraps
                fit = self.max_context - req.prompt_len + 1
                budget = min(budget, fit)  # session-level clip; the caller's
                #                            Request is not mutated
            worst = 0
            if self.paged:
                # worst-case footprint: prompt rows + every decode write
                worst = -(-(req.prompt_len + budget - 1)
                          // self.pool.page_len)
                if worst > self.pool.pages_available:
                    # arena backpressure: PARK at the queue head (FIFO)
                    # until retirements free enough pages, instead of
                    # rejecting a request the arena could serve later
                    if req.rid not in self._parked_rids:
                        self._parked_rids.add(req.rid)
                        self.requests_parked += 1
                        if self._tel is not None:
                            self._tel.instant(
                                "park_arena", cat="admission", rid=req.rid,
                                pages_needed=worst,
                                pages_available=self.pool.pages_available)
                    break
            self.queue.pop()
            req.t_admit = _now()
            if budget < req.max_new_tokens:
                self.requests_truncated += 1
            slot = self.pool.acquire()
            if self.paged:
                self.pool.commit_pages(slot, worst)
                self.pool.alloc_pages(slot, req.prompt_len)
            if req.channel is None:
                req.channel = self.default_channel
            mode, cap = 0, None
            if self.orch is not None:
                if self.controller is not None:
                    if req.channel is not None:
                        cap = req.channel.step()
                    mode = self.controller.admit(req.rid, req.requirement,
                                                 cap, self.tick)
                else:
                    self.orch.register(req.rid, req.requirement)
                    if req.channel is not None:
                        cap = req.channel.step()
                        self.orch.observe_capacity(cap, rid=req.rid)
                    if self._mixed_prefill is not None:
                        mode = self.orch.choose_mode(rid=req.rid)
            admits.append((req, slot, mode, budget, cap))
        return admits

    def _prefill_group(self, blen: int, group: List[tuple]):
        """ONE jitted full-sequence prefill for every request in a bucket:
        prompts right-padded to ``blen``, batch padded to a power of two,
        each row's boundary routed through its admission-chosen mode."""
        n = len(group)
        t_pre = _now() if self._tel is not None else 0.0
        bp = _bucket_len(n, lo=1)          # pow2 batch: bounded compile set
        audio = (self.cfg.frontend == "audio" and self.cfg.n_codebooks > 1)
        shape = (bp, self.cfg.n_codebooks, blen) if audio else (bp, blen)
        toks = np.zeros(shape, np.int32)
        lens = np.ones(bp, np.int32)       # pad rows: harmless length-1 rows
        modes = np.zeros(bp, np.int32)
        for i, (req, _, mode, _, _) in enumerate(group):
            toks[i, ..., :req.prompt_len] = req.prompt
            lens[i] = req.prompt_len
            modes[i] = mode
        if self.paged:
            # per-row block tables at the bucket's static width (pad rows
            # get all-zero rows: their one valid position lands in the
            # scratch page); the prefill scatters prompt K/V straight into
            # the admit-time-allocated arena pages
            nb_p = max(-(-blen // self.pool.page_len), 1)
            bt_np = np.zeros((bp, nb_p), np.int32)
            for i, (_, slot, _, _, _) in enumerate(group):
                bt_np[i] = self.pool.block_np[slot, :nb_p]
            bt = jnp.asarray(bt_np)
            if self._mixed_prefill is not None:
                first_dev, new_states = self._mixed_prefill(
                    self.params, self.stacked_bank, jnp.asarray(toks),
                    jnp.asarray(lens), self.pool.states,
                    jnp.asarray(modes), bt)
            else:
                first_dev, new_states = self._mono_prefill(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    self.pool.states, bt)
            self.pool.states = new_states      # the updated (donated) arena
        elif self._mixed_prefill is not None:
            first_dev, new_states = self._mixed_prefill(
                self.params, self.stacked_bank, jnp.asarray(toks),
                jnp.asarray(lens), jnp.asarray(modes))
        else:
            first_dev, new_states = self._mono_prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
        self.prefill_calls += 1
        self.prefill_tokens += int(lens[:n].sum())
        self.prefill_padded_tokens += bp * blen
        # admission-time sync: the argmax already ran inside the jit, so
        # this materializes a tiny int32 array (once per admitted bucket,
        # not once per decode tick)
        first = np.asarray(first_dev, np.int32)
        now = _now()
        if self._tel is not None:
            self._tel.complete("prefill", t_pre, now - t_pre, cat="window",
                               rows=n, bucket=blen)
            self._tel.observe("engine.prefill_s", now - t_pre)
        slots = [a[1] for a in group]
        plens = [a[0].prompt_len for a in group]
        if self.paged:
            # the prefill already scattered the arena through the block
            # tables — only positions (and, on the device loop, the
            # device-resident token/position buffers) remain
            for s, p in zip(slots, plens):
                self.pool.positions[s] = p
            if not self.host_loop:
                self._positions, self.cur_tokens = _admit_meta(
                    self._positions, self.cur_tokens,
                    jnp.asarray(slots, jnp.int32),
                    jnp.asarray(plens, jnp.int32), first_dev)
        elif self.host_loop:
            # ONE scatter moves every admitted row into its pool slot
            self.pool.write_rows(new_states, slots, plens)
        else:
            # device-resident admission: states, positions, and first
            # tokens land in the donated pool buffers in one dispatch
            self.pool.states, self._positions, self.cur_tokens = \
                _admit_scatter(self.pool.states, self._positions,
                               self.cur_tokens, new_states,
                               jnp.asarray(slots, jnp.int32),
                               jnp.asarray(plens, jnp.int32),
                               _slot_axis(self.cfg), first_dev)
            for s, p in zip(slots, plens):
                self.pool.positions[s] = p          # host-side bookkeeping
        for i, (req, slot, mode, budget, cap) in enumerate(group):
            tok = first[i]
            if self.host_loop:
                self.cur_tokens[slot] = tok
            sess = Session(request=req, slot=slot, admitted_tick=self.tick,
                           gen_budget=budget, admission_mode=mode,
                           mode_trace=[(self.tick, mode)])
            sess.pos = req.prompt_len
            # the prefill's argmax IS the first generated token — deliver it
            sess.tokens.append(int(tok.reshape(-1)[0]) if tok.ndim
                               else int(tok))
            sess.ttft_s = now - req.t_submit if req.t_submit else 0.0
            if self._tel is not None:
                if req.t_submit:
                    self._tel.observe("engine.ttft_s", sess.ttft_s)
                if req.t_admit:
                    self._tel.observe("engine.admit_to_first_token_s",
                                      now - req.t_admit)
                self._tel.instant("admit", cat="admission", rid=req.rid,
                                  slot=slot, mode=mode, t=now)
            # the prompt's boundary activations cross the uplink once, in
            # the admission-chosen mode (and the prefill really ran them
            # through that mode's bottleneck head), with the transfer
            # simulated against the link capacity observed at admission
            pb = bottleneck.mode_payload_bytes(self.cfg, 1, req.prompt_len,
                                               mode)
            sess.prefill_wire_bytes = pb
            sess.wire_bytes += pb
            if self.orch is not None:
                link = self.orch.register(req.rid)
                sess.transfer_s += tx_seconds(
                    pb, cap if cap is not None else link.capacity_ema)
            if sess.done:                # budget == 1: already complete
                sess.finished_tick = self.tick
                self._release_links(sess)
                self.pool.release(slot)
                self.finished.append(sess)
            else:
                self.active[slot] = sess

    def _release_links(self, sess: Session):
        """Drop a retiring session's orchestrator/controller state, folding
        the controller's escalation count into the session record (its
        switch trace is already on the session)."""
        if self.controller is not None:
            ctl = self.controller.finish(sess.request.rid)
            if ctl is not None:
                sess.escalations = ctl.escalations
        elif self.orch is not None:
            self.orch.release(sess.request.rid)

    # -- decode ---------------------------------------------------------------
    def _payload_bytes(self, mode: int) -> int:
        """Per-token wire bytes for ``mode`` — a pure function of the fixed
        config, memoized because mode accounting runs K x B times per decode
        window on the host, squarely on the dispatch critical path."""
        pb = self._mode_pb.get(mode)
        if pb is None:
            pb = self._mode_pb[mode] = bottleneck.mode_payload_bytes(
                self.cfg, 1, 1, mode)
        return pb

    def _choose_modes(self, tick: Optional[int] = None,
                      items=None) -> np.ndarray:
        """Per-slot mode selection for ONE decode tick (``tick`` defaults
        to the current one; the device loop calls this for each tick of a
        decode window before dispatching the whole window — mode selection
        depends only on channel observations and counts, never on decoded
        token values, so whole windows are decidable up front).

        Every live session's own channel advances exactly one tick
        regardless of policy (identical observation streams make
        adaptive-vs-frozen comparisons apples-to-apples); the policy only
        decides what to do with the observation:

        * ``controller`` set — adaptive: one vectorized
          ``ModeController.step_modes`` call re-selects the whole pool;
        * ``freeze_modes`` — the admission-chosen mode for the session's
          whole life (the EMA still tracks, for transfer accounting);
        * otherwise — the orchestrator's scalar per-request loop (legacy).

        Also accounts per-token wire bytes/transfer under the time-varying
        mode, records mode-switch traces, and counts a deadline miss for
        every decode token whose simulated transfer exceeded the session's
        latency budget.
        """
        tick = self.tick if tick is None else tick
        modes = np.zeros(self.pool.n_slots, np.int32)
        if items is None:                          # deterministic slot order
            items = sorted(self.active.items())    # (window loops hoist this)
        caps = [sess.request.channel.step()
                if self.orch is not None and sess.request.channel is not None
                else None
                for _, sess in items]
        chosen = None
        if self.controller is not None and items:
            chosen = self.controller.step_modes(
                [sess.request.rid for _, sess in items], caps, tick)
        for i, (slot, sess) in enumerate(items):
            mode = 0
            if self.orch is not None:
                rid = sess.request.rid
                cap = caps[i]
                if chosen is not None:
                    mode = int(chosen[i])
                else:
                    if cap is not None:
                        self.orch.observe_capacity(cap, rid=rid)
                    if self._mixed_step is not None:
                        mode = (sess.admission_mode if self.freeze_modes
                                else self.orch.choose_mode(rid=rid))
                    # else: no bottleneck bank in params — the decode path
                    # can only transmit the raw boundary, so account mode 0
                    # rather than charging for compression that never runs
                pb = self._payload_bytes(mode)
                link = self.orch.register(rid)
                tx = tx_seconds(pb, cap if cap is not None
                                else link.capacity_ema)
                sess.account(mode, pb, tx)
                # deadline misses are only meaningful against an observed
                # link: with no channel the capacity EMA is a phantom 0.0
                # and every token would count as a miss
                if link.ticks > 0 and \
                        tx > self.orch.requirement_for(rid).latency_budget_s:
                    sess.deadline_misses += 1
            else:
                sess.account(0, self._payload_bytes(0), 0.0)
            if sess.mode_trace and sess.mode_trace[-1][1] != mode:
                if self._tel is not None:
                    self._tel.inc("engine.mode_switches")
                    self._tel.instant("mode_switch", cat="mode",
                                      rid=sess.request.rid, tick=tick,
                                      frm=sess.mode_trace[-1][1], to=mode)
                sess.mode_trace.append((tick, mode))
            modes[slot] = mode
        return modes

    def step(self) -> bool:
        """One engine tick: admit, then one mixed-mode decode step over the
        pool. Returns False when there is nothing left to do.

        The default loop is *device-resident*: argmax, token feedback, and
        position increments happen inside the jitted step against donated
        buffers, and the host only materializes the PREVIOUS tick's int32
        tokens after dispatching the current one — so orchestrator /
        controller / channel bookkeeping overlaps device compute instead of
        serializing with it. ``host_loop=True`` keeps the legacy
        synchronous loop (one argmax dispatch + blocking host round-trip
        per tick) as the measured baseline and equivalence oracle.
        """
        return self._step_host() if self.host_loop else self._step_device()

    def _step_host(self) -> bool:
        """Legacy synchronous tick (the pre-device-loop engine, preserved
        verbatim for A/B benchmarks and token-identity tests)."""
        self._deliver_arrivals()
        self._admit()
        if not self.active:
            if self._pending:          # idle until the next arrival
                self.tick = self._pending[0][0]
                return True
            return False

        t0 = _now() if self._tel is not None else 0.0
        modes = self._choose_modes()
        bt = None
        if self.paged:
            # on-demand growth: this tick writes each live slot's row at
            # its current position
            for slot in self.active:
                self.pool.alloc_pages(slot,
                                      int(self.pool.positions[slot]) + 1)
            bt = self.pool.block_table()
        positions = sharding.shard_batch(jnp.asarray(self.pool.positions),
                                         self.mesh)
        toks = sharding.shard_batch(jnp.asarray(self.cur_tokens), self.mesh)
        modes_dev = sharding.shard_batch(jnp.asarray(modes), self.mesh)
        if self._mixed_step is not None:
            if bt is not None:
                logits, new_states = self._mixed_step(
                    self.params, self.stacked_bank, toks, self.pool.states,
                    positions, modes_dev, bt)
            else:
                logits, new_states = self._mixed_step(
                    self.params, self.stacked_bank, toks, self.pool.states,
                    positions, modes_dev)
        elif bt is not None:
            logits, new_states = self._mono_step(self.params, toks,
                                                 self.pool.states, positions,
                                                 bt)
        else:                          # no bottleneck bank: raw mode only
            logits, new_states = self._mono_step(self.params, toks,
                                                 self.pool.states, positions)
        self.pool.states = new_states
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        if self._tel is not None:
            # one synchronous host tick == one token per live slot
            self._tel.observe("engine.intertoken_s", _now() - t0,
                              len(self.active))
            self._tel.set("engine.queue_depth", len(self.queue))
            self._tel.set("engine.slot_occupancy",
                          len(self.active) / self.pool.n_slots)
            self._tel.inc("engine.decode_wire_bytes",
                          sum(self._payload_bytes(int(modes[s]))
                              for s in self.active))
            self._tel.inc("engine.decode_tokens", len(self.active))
            if self.paged:
                self._tel.set("engine.page_occupancy",
                              self.pool.pages_in_use
                              / max(self.pool.n_pages, 1))

        self.decode_ticks += 1
        self.decoded_slot_ticks += len(self.active)
        if len({int(m) for s, m in enumerate(modes) if s in self.active}) > 1:
            self.mode_mix_ticks += 1

        for slot in list(self.active):
            sess = self.active[slot]
            tok = nxt[slot]
            sess.tokens.append(int(tok.reshape(-1)[0]) if tok.ndim
                               else int(tok))
            self.cur_tokens[slot] = tok
            self.pool.positions[slot] += 1
            sess.pos += 1
            if sess.done:
                sess.finished_tick = self.tick
                self._release_links(sess)
                del self.active[slot]
                self.pool.release(slot)
                self.finished.append(sess)
        self.tick += 1
        return True

    def _window_len(self) -> int:
        """How many ticks the next device dispatch may cover: bounded by
        the earliest session completion (retirement frees a slot — an
        admission opportunity), the next pending arrival, and
        ``max_window``; floored to a power of two so the jitted scan sees
        O(log max_window) distinct lengths."""
        rem = min((sess.gen_budget or sess.request.max_new_tokens)
                  - (sess.pos - sess.request.prompt_len + 1)
                  for sess in self.active.values())
        k = max(rem, 1)
        if self._pending:
            k = min(k, max(self._pending[0][0] - self.tick, 1))
        k = min(k, self.max_window)
        return 1 << (k.bit_length() - 1)

    def _step_device(self) -> bool:
        """Device-resident decode window with a one-window-lagged host sync.

        Mode selection and budget-based retirement depend only on channel
        observations and token COUNTS — never on decoded token VALUES — so
        the host decides a whole window of ticks up front ([K, B] mode
        matrix, K from ``_window_len``) and dispatches it as ONE jitted
        lax.scan on the pipeline worker (XLA releases the GIL, so the next
        window's orchestrator / controller / channel bookkeeping overlaps
        device compute). Slot lifecycle stays tick-exact with the host
        loop; token values land one window late, materialized while the
        device crunches the next window. The decoded streams are
        token-identical to ``host_loop=True`` — pinned by tests.
        """
        self._deliver_arrivals()
        self._admit()
        if not self.active:
            self._materialize_inflight()
            self._sync_device_state()
            if self._pending:          # idle until the next arrival
                self.tick = self._pending[0][0]
                return True
            return False

        t0 = _now() if self._tel is not None else 0.0
        k = self._window_len()
        bt = None
        if self.paged:
            # the host precomputes the window's page appends exactly like
            # the [K, B] mode matrix: every row the window will write
            # (positions pos..pos+k-1 per live slot) gets its page BEFORE
            # dispatch, and the block table ships as a fresh device copy
            for slot in self.active:
                self.pool.alloc_pages(slot,
                                      int(self.pool.positions[slot]) + k)
            bt = self.pool.block_table()
        # the live-session set is frozen for the whole window (retirement
        # is budget-driven and happens after dispatch), so sort once and
        # reuse the ordering for every tick's mode selection AND as the
        # materialization snapshot
        snapshot = sorted(self.active.items())
        modes_k = np.stack([self._choose_modes(self.tick + i,
                                               items=snapshot)
                            for i in range(k)])
        prev = self._inflight
        active = None
        if self._tel is not None:
            # the live set is frozen per window — the int32 mask both
            # masks free slots out of the device telemetry block and lets
            # its wire sum match host accounting exactly
            active = np.zeros(self.pool.n_slots, np.int32)
            for slot, _ in snapshot:
                active[slot] = 1
        fut = self._dispatch_device_step(modes_k, bt, active)
        # snapshot BEFORE retirement: these sessions each emit one token
        # per window tick, whose values land at the next materialization
        self._inflight = (snapshot, fut, k, _now() if self._tel is not None
                          else 0.0)
        if self._tel is not None:
            self._tel.complete("window_dispatch", t0, _now() - t0,
                               cat="window", k=k, live=len(snapshot),
                               tick=self.tick)
            self._tel.observe("engine.window_dispatch_s", _now() - t0)
            self._tel.set("engine.queue_depth", len(self.queue))
            self._tel.set("engine.slot_occupancy",
                          len(snapshot) / self.pool.n_slots)
            if self.paged:
                self._tel.set("engine.page_occupancy",
                              self.pool.pages_in_use
                              / max(self.pool.n_pages, 1))

        self.decode_ticks += k
        self.decoded_slot_ticks += k * len(snapshot)
        active_slots = set(self.active)
        for i in range(k):
            if len({int(m) for s, m in enumerate(modes_k[i])
                    if s in active_slots}) > 1:
                self.mode_mix_ticks += 1

        # budget-based retirement at dispatch time: frees slots for the
        # next tick's admission without waiting for token values (sessions
        # can only complete at the window's last tick — _window_len never
        # overshoots the earliest completion)
        for slot, sess in snapshot:
            sess.pos += k
            self.pool.positions[slot] += k
            emitted = sess.pos - sess.request.prompt_len + 1  # incl. prefill
            budget = sess.gen_budget or sess.request.max_new_tokens
            if emitted >= budget:
                sess.finished_tick = self.tick + k - 1
                self._release_links(sess)
                del self.active[slot]
                self.pool.release(slot)
        # sync the PREVIOUS window's tokens while the device runs this one
        if prev is not None:
            self._materialize(prev)
        self.tick += k
        return True

    def _dispatch_device_step(self, modes_k: np.ndarray, bt=None,
                              active: Optional[np.ndarray] = None) \
            -> _cf.Future:
        """Enqueue one fused decode window on the pipeline worker. The
        closure chains on the previous window's future (single worker =
        FIFO, so ``prev.result()`` never blocks the worker on unfinished
        work); the main thread returns immediately and keeps doing host
        bookkeeping while XLA executes. ``bt`` (paged pools) is the
        window's frozen block table — a fresh device buffer, never
        donated. ``active`` (telemetry engines) is the window's frozen
        int32 live mask feeding the instrumented bodies' telemetry
        block."""
        prev, cur = self._future, (self.cur_tokens, self.pool.states,
                                   self._positions)
        # [K, B]: the slot axis is axis 1 inside the window scan
        modes_dev = sharding.shard_batch(jnp.asarray(modes_k), self.mesh,
                                         axis=1)
        params, stacked = self.params, self.stacked_bank
        mixed, mono = self._mixed_step_dev, self._mono_step_dev
        tel_args = ()
        if self._tel is not None:
            tel_args = (self._pb_table,
                        sharding.shard_batch(jnp.asarray(active),
                                             self.mesh))

        def work():
            tok, states, positions = prev.result()[:3] if prev is not None \
                else cur
            if mixed is not None:
                if bt is not None:
                    return mixed(params, stacked, tok, states, positions,
                                 modes_dev, *tel_args, bt)
                return mixed(params, stacked, tok, states, positions,
                             modes_dev, *tel_args)
            if bt is not None:
                return mono(params, tok, states, positions, modes_dev,
                            *tel_args, bt)
            return mono(params, tok, states, positions, modes_dev,
                        *tel_args)

        fut = self._pipeline().submit(work)
        self._future = fut
        return fut

    def _pipeline(self) -> _cf.ThreadPoolExecutor:
        if self._exec is None:
            self._exec = _cf.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="decode-pipeline")
            # callers that drop the engine without close() must not pin a
            # worker thread for the life of the process: shut the executor
            # down (non-blocking) when the engine is garbage-collected
            self._exec_finalizer = weakref.finalize(
                self, self._exec.shutdown, False)
        return self._exec

    def close(self):
        """Land any in-flight window (tokens are materialized, buffers
        re-homed) and shut this engine's pipeline worker down. Idempotent;
        the engine remains usable afterwards (a new worker spawns lazily on
        the next dispatch)."""
        self._materialize_inflight()
        self._sync_device_state()
        if self._exec is not None:
            self._exec_finalizer.detach()
            self._exec.shutdown(wait=True)
            self._exec = None

    def __enter__(self) -> "ContinuousBatchingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _sync_device_state(self):
        """Land the last dispatched window's buffers back on the engine.
        Must run before anything reads (or scatters into) ``pool.states``,
        ``cur_tokens``, or ``_positions`` — admission, warm/reset, end of
        run — because while a window is in flight those attributes point at
        stale (donated) buffers."""
        if self._future is not None:
            self.cur_tokens, self.pool.states, self._positions = \
                self._future.result()[:3]
            self._future = None

    def _materialize(self, inflight):
        """Host side of the lagged pipeline: copy one window's [K, B]
        int32 token block off the device and append it to the snapshot's
        sessions; sessions whose budget completed in that window move to
        ``finished`` here (their slots were already freed at dispatch).
        On telemetry engines the window's [K, 2 + M] int32 telemetry
        block rides the same result and folds into the registry here —
        one window late, exactly like token values."""
        snapshot, fut, k, t_disp = inflight
        t_mat = _now() if self._tel is not None else 0.0
        arr = np.asarray(fut.result()[3])            # [K, B, ...]
        if self._tel is not None:
            tel_blk = np.asarray(fut.result()[4], np.int64)  # [K, 2 + M]
            wire = int(tel_blk[:, 0].sum())
            slot_ticks = int(tel_blk[:, 1].sum())
            self.device_tel["wire_bytes"] += wire
            self.device_tel["slot_ticks"] += slot_ticks
            self.device_tel["mode_ticks"] += tel_blk[:, 2:].sum(axis=0)
            self._tel.inc("engine.decode_wire_bytes", wire)
            self._tel.inc("engine.decode_tokens", slot_ticks)
            # window wall clock (dispatch -> tokens on host) over k ticks
            # IS the device loop's inter-token latency, weighted by the
            # tokens the window produced
            wall = _now() - t_disp
            if slot_ticks:
                self._tel.observe("engine.intertoken_s", wall / k,
                                  slot_ticks)
        for slot, sess in snapshot:
            for i in range(k):
                tok = arr[i, slot]
                sess.tokens.append(int(tok.reshape(-1)[0]) if tok.ndim
                                   else int(tok))
            budget = sess.gen_budget or sess.request.max_new_tokens
            if len(sess.tokens) >= budget:
                self.finished.append(sess)
        if self._tel is not None:
            dur = _now() - t_mat
            self._tel.complete("window_materialize", t_mat, dur,
                               cat="window", k=k)
            self._tel.observe("engine.window_materialize_s", dur)

    def _materialize_inflight(self):
        if self._inflight is not None:
            prev, self._inflight = self._inflight, None
            self._materialize(prev)

    def warm(self, prompt: np.ndarray, gen: int = 2):
        """Trace every compiled path a measured run can hit — decode plus
        each power-of-two prefill batch bucket up to the slot pool, and (on
        the device loop) each power-of-two decode-window length up to
        ``max_window`` — then zero the counters. ``prompt`` should have the
        measured run's prompt length so the same length bucket compiles."""
        k = 1
        while True:
            n = min(k, self.pool.n_slots)
            self.run([Request(rid=-1 - i, prompt=np.asarray(prompt),
                              max_new_tokens=gen) for i in range(n)])
            if k >= self.pool.n_slots:
                break
            k <<= 1
        if not self.host_loop:
            w = 1
            while w <= self.max_window:
                # budget w+1 = prefill token + exactly one window of w ticks
                # (w starts at 1: single-tick windows occur at stream tails,
                # and their scan otherwise compiles inside the measured run)
                self.run([Request(rid=-1 - i, prompt=np.asarray(prompt),
                                  max_new_tokens=w + 1)
                          for i in range(self.pool.n_slots)])
                w <<= 1
        self.reset_counters()

    def reset_counters(self):
        """Zero every aggregate stat (after a warm-up run) while keeping the
        compiled paths, pool state, and orchestrator calibration."""
        self._materialize_inflight()
        self._sync_device_state()
        self.finished.clear()
        self.tick = 0
        self.decode_ticks = self.mode_mix_ticks = 0
        self.decoded_slot_ticks = 0
        self.prefill_calls = self.prefill_tokens = 0
        self.prefill_padded_tokens = 0
        self.requests_over_capacity = self.requests_truncated = 0
        self.requests_parked = 0
        self._parked_rids.clear()
        if self.paged:
            self.pool.peak_pages_in_use = self.pool.pages_in_use
        self.queue.submitted = self.queue.rejected = 0
        self.device_tel["wire_bytes"] = self.device_tel["slot_ticks"] = 0
        self.device_tel["mode_ticks"] = np.zeros_like(
            self.device_tel["mode_ticks"])
        if self._tel is not None:
            # shared across a cluster's replicas — a reset between warm-up
            # and measurement clears everyone's warm data, which is what
            # every caller wants (warm() runs before the measured window)
            self._tel.registry.reset()

    def run(self, requests: Optional[List[Request]] = None,
            max_ticks: int = 100_000) -> List[Session]:
        """Drive the engine until every submitted request completes (or the
        tick budget runs out). Returns the finished sessions."""
        for r in requests or []:
            self.submit(r)
        for _ in range(max_ticks):
            if not self.step():
                break
        self._materialize_inflight()   # tick-budget exhaustion: don't drop
        self._sync_device_state()      # the last dispatched tick's tokens
        return self.finished

    # -- aggregate stats ------------------------------------------------------
    def stats(self) -> dict:
        toks = sum(len(s.tokens) for s in self.finished)
        # the first token of every session came from its prefill, not a
        # decode tick — decode-side rates divide by decode-tick tokens only
        dec_toks = sum(max(len(s.tokens) - 1, 0) for s in self.finished)
        wire = sum(s.wire_bytes for s in self.finished)
        prefill_wire = sum(s.prefill_wire_bytes for s in self.finished)
        decode_wire = wire - prefill_wire
        mix: Dict[int, int] = {}
        for s in self.finished:
            for m, c in s.mode_counts.items():
                mix[m] = mix.get(m, 0) + c
        switches = sum(max(len(s.mode_trace) - 1, 0) for s in self.finished)
        misses = sum(s.deadline_misses for s in self.finished)
        policy = ("adaptive" if self.controller is not None
                  else "frozen" if self.freeze_modes
                  else "per-tick" if self.orch is not None else "static")
        paged_stats = {}
        if self.paged:
            paged_stats = {
                "page_len": self.pool.page_len,
                "n_pages": self.pool.n_pages,
                "pages_in_use": int(self.pool.pages_in_use),
                "peak_pages_in_use": int(self.pool.peak_pages_in_use),
                "page_occupancy": (self.pool.peak_pages_in_use
                                   / max(self.pool.n_pages, 1)),
                "requests_parked": self.requests_parked,
            }
        out = {
            "mode_policy": policy,
            "paged": self.paged,
            **paged_stats,
            "mode_switches": switches,
            "mode_escalations": sum(s.escalations for s in self.finished),
            "deadline_misses": misses,
            "deadline_miss_rate": misses / max(dec_toks, 1),
            "requests_finished": len(self.finished),
            "requests_rejected": self.queue.rejected,
            "requests_over_capacity": self.requests_over_capacity,
            "requests_truncated": self.requests_truncated,
            "generated_tokens": toks,
            "decode_tokens": dec_toks,
            "wire_bytes": wire,
            # prefill bytes scale with prompt length, decode bytes with
            # generated tokens — folding them into one per-token figure
            # skewed mode comparisons, so they are reported separately
            "prefill_wire_bytes": prefill_wire,
            "decode_wire_bytes": decode_wire,
            "decode_wire_bytes_per_token": decode_wire / max(dec_toks, 1),
            "mode_counts": mix,
            "decode_ticks": self.decode_ticks,
            "decoded_slot_ticks": self.decoded_slot_ticks,
            "mixed_mode_ticks": self.mode_mix_ticks,
            "prefill_calls": self.prefill_calls,
            "prefill_tokens": self.prefill_tokens,
            "prefill_padded_tokens": self.prefill_padded_tokens,
            "mean_ttft_s": (float(np.mean([s.ttft_s for s in self.finished]))
                            if self.finished else 0.0),
        }
        if self._tel is not None:
            # mirror the legacy totals into the registry so the JSON /
            # Prometheus exports always agree with this dict (the dict
            # itself is computed exactly as before — key/value parity
            # with telemetry off is pinned by tests)
            self._tel.registry.ingest("engine.stats", out)
        return out
