"""Continuous-batching split-serving: slot pool + mixed-mode decode loop.

The engine keeps a fixed pool of ``n_slots`` decode slots (KV caches /
recurrent states allocated once, recycled as sequences finish). Every engine
tick it:

1. admits pending requests from the bounded queue into free slots (each
   admission prefetches the prompt through a batch-1 prefill and scatters
   the resulting state into the slot);
2. steps each active request's *own* simulated mmWave channel, lets the
   shared orchestrator pick that request's bottleneck mode from its link
   EMA, and
3. runs ONE jitted mixed-mode decode step for the whole pool — per-slot
   positions (sequences are at different depths) and per-slot mode indices
   (the bottleneck head is a gather over the stacked mode bank, not a
   Python branch), so a single compiled executable serves any mode mixture;
4. accounts uplink bytes and simulated transfer latency per request and
   retires finished sessions, freeing their slots.

Free slots still ride through the decode step (the batch shape is static for
jit); their outputs are ignored and their state is fully overwritten at the
next admission.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.core import split as SP
from repro.core.channel import Channel, tx_seconds
from repro.core.orchestrator import Orchestrator
from repro.models import transformer as T
from repro.serving.session import Request, RequestQueue, Session


def _slot_axis(cfg: ModelConfig) -> int:
    # homogeneous archs stack per-layer states into [L, B, ...] leaves;
    # heterogeneous archs keep a tuple of per-layer [B, ...] pytrees
    return 1 if cfg.homogeneous else 0


@functools.partial(jax.jit, static_argnums=(3,))
def _scatter_slot(pool_states, one_states, slot, axis: int):
    return jax.tree.map(
        lambda p, o: jax.lax.dynamic_update_slice_in_dim(p, o, slot,
                                                         axis=axis),
        pool_states, one_states)


class SlotPool:
    """Fixed pool of decode slots with recycled cache/recurrent state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, cache_len: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.states = T.init_decode_state(cfg, n_slots, cache_len)
        self.positions = np.zeros(n_slots, np.int32)
        self._free = list(range(n_slots - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def release(self, slot: int):
        assert slot not in self._free
        self.positions[slot] = 0
        self._free.append(slot)

    def write(self, slot: int, one_states, pos: int):
        """Install a freshly prefilled batch-1 state into ``slot`` (full
        overwrite — whatever a previous occupant left behind is gone)."""
        self.states = _scatter_slot(self.states, one_states,
                                    jnp.int32(slot), _slot_axis(self.cfg))
        self.positions[slot] = pos


class ContinuousBatchingEngine:
    """Split-inference engine with per-request dynamic bottleneck modes.

    ``orchestrator`` is shared (mode calibration is global) but tracks one
    link state per request id; ``default_channel`` serves requests that
    arrive without their own ``Channel``.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 cache_len: int = 128,
                 orchestrator: Optional[Orchestrator] = None,
                 default_channel: Optional[Channel] = None,
                 max_pending: int = 64):
        self.params = params
        self.cfg = cfg
        self.orch = orchestrator
        self.default_channel = default_channel
        self.pool = SlotPool(cfg, n_slots, cache_len)
        self.queue = RequestQueue(max_pending)
        self.active: Dict[int, Session] = {}          # slot -> session
        self.finished: List[Session] = []
        self.tick = 0
        self.mode_mix_ticks = 0       # decode ticks with >= 2 distinct modes
        self.decode_ticks = 0
        bank = params.get("bneck_modes") or ()
        self.stacked_bank = (bottleneck.bank_stack(bank, cfg.split)
                             if len(bank) else None)
        self._tok_shape = ((n_slots, cfg.n_codebooks, 1)
                           if cfg.frontend == "audio" and cfg.n_codebooks > 1
                           else (n_slots, 1))
        self.cur_tokens = np.zeros(self._tok_shape, np.int32)
        self._pending: List[Request] = []             # not yet "arrived"

        @jax.jit
        def mono_step(params, tok, states, pos):
            return T.decode_step(params, tok, states, pos, cfg)
        self._mono_step = mono_step

        if self.stacked_bank is not None:
            @jax.jit
            def mixed_step(params, stacked, tok, states, positions, modes):
                return SP.split_decode_step_mixed(params, stacked, tok,
                                                  states, positions, cfg,
                                                  modes)
            self._mixed_step = mixed_step
        else:
            self._mixed_step = None

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request for its arrival tick. Returns False if the
        admission queue rejected it (back-pressure)."""
        if req.arrival_tick > self.tick:
            self._pending.append(req)
            return True
        return self.queue.submit(req)

    def _deliver_arrivals(self):
        due = [r for r in self._pending if r.arrival_tick <= self.tick]
        self._pending = [r for r in self._pending
                         if r.arrival_tick > self.tick]
        for r in sorted(due, key=lambda r: r.arrival_tick):
            self.queue.submit(r)

    # -- admission ------------------------------------------------------------
    def _prefill_one(self, prompt: np.ndarray):
        """Batch-1 prefill via repeated decode steps (exact for attention
        caches and recurrent states alike). Returns (first_token, states)."""
        states = T.init_decode_state(self.cfg, 1, self.pool.cache_len)
        toks = jnp.asarray(prompt)[None]              # [1, S] / [1, K, S]
        logits = None
        for t in range(toks.shape[-1]):
            logits, states = self._mono_step(self.params, toks[..., t:t + 1],
                                             states, jnp.int32(t))
        first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # [1, ...]
        return first, states

    def _admit(self):
        while self.pool.n_free and len(self.queue):
            req = self.queue.pop()
            slot = self.pool.acquire()
            sess = Session(request=req, slot=slot, admitted_tick=self.tick)
            if req.channel is None:
                req.channel = self.default_channel
            mode = 0
            if self.orch is not None:
                self.orch.register(req.rid, req.requirement)
                if req.channel is not None:
                    self.orch.observe_capacity(req.channel.step(),
                                               rid=req.rid)
                if self._mixed_step is not None:
                    mode = self.orch.choose_mode(rid=req.rid)
            first, one_states = self._prefill_one(req.prompt)
            self.pool.write(slot, one_states, req.prompt_len)
            self.cur_tokens[slot] = first[0]
            sess.pos = req.prompt_len
            # the prompt's boundary activations cross the uplink once, in
            # the admission-chosen mode
            pb = bottleneck.mode_payload_bytes(self.cfg, 1, req.prompt_len,
                                               mode)
            sess.prefill_wire_bytes = pb
            sess.wire_bytes += pb
            self.active[slot] = sess

    # -- decode ---------------------------------------------------------------
    def _choose_modes(self) -> np.ndarray:
        modes = np.zeros(self.pool.n_slots, np.int32)
        for slot, sess in self.active.items():
            mode = 0
            if self.orch is not None:
                rid = sess.request.rid
                cap = None
                if sess.request.channel is not None:
                    cap = sess.request.channel.step()
                    self.orch.observe_capacity(cap, rid=rid)
                if self._mixed_step is not None:
                    mode = self.orch.choose_mode(rid=rid)
                # else: no bottleneck bank in params — the decode path can
                # only transmit the raw boundary, so account mode 0 rather
                # than charging for compression that never runs
                pb = bottleneck.mode_payload_bytes(self.cfg, 1, 1, mode)
                link = self.orch.register(rid)
                sess.account(mode, pb,
                             tx_seconds(pb, cap if cap is not None
                                        else link.capacity_ema))
            else:
                pb = bottleneck.mode_payload_bytes(self.cfg, 1, 1, 0)
                sess.account(0, pb, 0.0)
            modes[slot] = mode
        return modes

    def step(self) -> bool:
        """One engine tick: admit, then one mixed-mode decode step over the
        pool. Returns False when there is nothing left to do."""
        self._deliver_arrivals()
        self._admit()
        if not self.active:
            if self._pending:          # idle until the next arrival
                self.tick = min(r.arrival_tick for r in self._pending)
                return True
            return False

        modes = self._choose_modes()
        positions = jnp.asarray(self.pool.positions)
        toks = jnp.asarray(self.cur_tokens)
        if self._mixed_step is not None:
            logits, new_states = self._mixed_step(
                self.params, self.stacked_bank, toks, self.pool.states,
                positions, jnp.asarray(modes))
        else:                          # no bottleneck bank: raw mode only
            logits, new_states = self._mono_step(self.params, toks,
                                                 self.pool.states, positions)
        self.pool.states = new_states
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        self.decode_ticks += 1
        if len({int(m) for s, m in enumerate(modes) if s in self.active}) > 1:
            self.mode_mix_ticks += 1

        for slot in list(self.active):
            sess = self.active[slot]
            tok = nxt[slot]
            sess.tokens.append(int(tok.reshape(-1)[0]) if tok.ndim
                               else int(tok))
            self.cur_tokens[slot] = tok
            self.pool.positions[slot] += 1
            sess.pos += 1
            if sess.done:
                sess.finished_tick = self.tick
                if self.orch is not None:
                    self.orch.release(sess.request.rid)
                del self.active[slot]
                self.pool.release(slot)
                self.finished.append(sess)
        self.tick += 1
        return True

    def run(self, requests: Optional[List[Request]] = None,
            max_ticks: int = 100_000) -> List[Session]:
        """Drive the engine until every submitted request completes (or the
        tick budget runs out). Returns the finished sessions."""
        for r in requests or []:
            self.submit(r)
        for _ in range(max_ticks):
            if not self.step():
                break
        return self.finished

    # -- aggregate stats ------------------------------------------------------
    def stats(self) -> dict:
        toks = sum(len(s.tokens) for s in self.finished)
        wire = sum(s.wire_bytes for s in self.finished)
        mix: Dict[int, int] = {}
        for s in self.finished:
            for m, c in s.mode_counts.items():
                mix[m] = mix.get(m, 0) + c
        return {
            "requests_finished": len(self.finished),
            "requests_rejected": self.queue.rejected,
            "decode_tokens": toks,
            "wire_bytes": wire,
            "wire_bytes_per_token": wire / max(toks, 1),
            "mode_counts": mix,
            "decode_ticks": self.decode_ticks,
            "mixed_mode_ticks": self.mode_mix_ticks,
        }
