"""Serving telemetry: metrics registry + structured trace timeline.

Two halves, both dependency-free (numpy only):

1. :class:`MetricsRegistry` — named counters, gauges, and log-bucketed
   histograms (geometric bucket edges, ``np.searchsorted`` placement)
   with p50/p90/p99/max summaries, exportable as a JSON snapshot
   (:meth:`MetricsRegistry.snapshot`) or Prometheus text exposition
   (:meth:`MetricsRegistry.prometheus`). The serving engines observe
   TTFT, inter-token latency, window dispatch/materialize wall time,
   wire bytes, queue depth, and pool occupancy into it; the existing
   ``stats()`` dicts are mirrored in via :meth:`MetricsRegistry.ingest`
   so both views always agree.

2. :class:`TraceRecorder` — a bounded ring buffer of structured events
   (admission verdicts, mode switches and escalations, migration
   send/inject, handovers, autoscale decisions, decode-window spans)
   stamped on the shared monotonic clock and exportable as Chrome
   trace-event JSON (:meth:`TraceRecorder.chrome_trace`), loadable in
   Perfetto / ``chrome://tracing``. Lanes (one per cluster replica,
   plus a control-plane lane) render as separate processes.

:class:`Telemetry` bundles one registry + one recorder + a lane id; an
``EdgeCluster`` hands each replica a :meth:`Telemetry.for_lane` view so
every engine writes the same registry and the same merged timeline.

The module also owns the ONE serving wall clock (:func:`now` —
``time.monotonic``; ``Session.t_submit``, engine spans, launcher timing
and the training loop all read it) and the shared bench timing helpers
(:class:`Stopwatch`, :func:`best_of`, :func:`time_us`) that the
benchmarks previously each re-implemented.

The device-resident decode loop never calls into this module from
traced code: per-tick occupancy/mode/wire counters ride the windowed
``lax.scan`` as an int32 telemetry block (see
``batcher._window_scan_body``) and are folded into the registry one
window late, on the host, exactly like token values.
"""
from __future__ import annotations

import contextlib
import json
import re
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# the one clock
# ---------------------------------------------------------------------------

def now() -> float:
    """THE serving wall clock (monotonic seconds). Every span, TTFT and
    bench wall-time measurement reads this one function, so timestamps
    from different layers are always comparable."""
    return time.monotonic()


class Stopwatch:
    """Wall-time span on the shared clock.

    >>> with Stopwatch() as sw:
    ...     work()
    >>> sw.seconds        # frozen at exit
    ``sw.lap()`` reads the running time while the block is still open.
    """

    def __enter__(self) -> "Stopwatch":
        self.t0 = now()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = now() - self.t0

    def lap(self) -> float:
        return now() - self.t0


def best_of(fn, *args, repeats: int = 3):
    """Best-of-``repeats`` wall seconds for ``fn(*args)`` — the bench
    timing idiom (min over repeats rejects scheduler noise). Returns
    ``(best_seconds, last_result)``."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best, out


def time_us(fn, *args, iters: int = 20) -> float:
    """Best-of-``iters`` microseconds for a jitted callable: one warmup
    call compiles, then the minimum over ``iters`` timed calls (each
    blocked on via ``block_until_ready`` when the result supports it)."""
    out = fn(*args)
    _block(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _block(out):
    for leaf in (out if isinstance(out, (tuple, list)) else (out,)):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class Counter:
    """Monotone event/byte counter."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def reset(self):
        self.value = 0

    def summary(self):
        return self.value


class Gauge:
    """Last-written instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v):
        self.value = float(v)

    def reset(self):
        self.value = 0.0

    def summary(self):
        return self.value


class Histogram:
    """Log-bucketed histogram with percentile summaries.

    ``n_buckets`` geometric upper edges span ``[lo, hi]``; an overflow
    bucket catches values past ``hi``. A quantile estimate is the upper
    edge of the bucket holding the target rank, so it is exact to within
    one bucket ratio (``(hi/lo) ** (1 / (n_buckets - 1))`` — ~1.21x at
    the defaults, 8 decades over 96 buckets). ``observe(v, n)`` records
    ``n`` identical observations in one update (the windowed decode loop
    lands whole windows at once).
    """

    kind = "histogram"

    def __init__(self, name: str, *, lo: float = 1e-6, hi: float = 100.0,
                 n_buckets: int = 96):
        if not (0 < lo < hi) or n_buckets < 2:
            raise ValueError(f"bad histogram range [{lo}, {hi}] "
                             f"x {n_buckets}")
        self.name = name
        self.edges = np.geomspace(lo, hi, n_buckets)
        self.counts = np.zeros(n_buckets + 1, np.int64)   # +1: overflow
        self.sum = 0.0
        self.count = 0
        self.max = 0.0

    def observe(self, value, n: int = 1):
        v = float(value)
        self.counts[int(np.searchsorted(self.edges, v))] += n
        self.sum += v * n
        self.count += n
        if v > self.max:
            self.max = v

    def reset(self):
        self.counts[:] = 0
        self.sum = 0.0
        self.count = 0
        self.max = 0.0

    def quantile(self, q: float) -> float:
        """Upper bucket edge at rank ``ceil(q * count)`` (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = max(int(np.ceil(q * self.count)), 1)
        idx = int(np.searchsorted(np.cumsum(self.counts), target))
        if idx >= len(self.edges):        # overflow bucket
            return self.max
        return float(self.edges[idx])

    def summary(self) -> dict:
        return {
            "count": int(self.count),
            "sum": float(self.sum),
            "mean": self.sum / self.count if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "max": float(self.max),
        }


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    One registry serves a whole cluster: engines address metrics by name
    (``inc`` / ``set`` / ``observe`` auto-create), exporters walk the
    registry. Hot-path writers hold references to the metric objects
    instead of re-resolving names per tick.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def inc(self, name: str, n=1):
        self.counter(name).inc(n)

    def set(self, name: str, v):
        self.gauge(name).set(v)

    def observe(self, name: str, v, n: int = 1):
        self.histogram(name).observe(v, n)

    def get(self, name: str):
        return self._metrics.get(name)

    def reset(self):
        """Zero every metric in place (bucket layouts and references
        survive) — the engines call this from ``reset_counters`` so a
        warm-up run's compile-time spikes never land in measured
        percentiles."""
        for m in self._metrics.values():
            m.reset()

    def ingest(self, prefix: str, stats: dict):
        """Mirror a ``stats()`` dict into gauges (``prefix.key``), nested
        dicts flattened — the registry view of the legacy totals, so JSON
        snapshot and Prometheus exposition carry them too."""
        for k, v in stats.items():
            name = f"{prefix}.{k}"
            if isinstance(v, dict):
                self.ingest(name, v)
            elif isinstance(v, (bool, int, float, np.integer, np.floating)):
                self.set(name, float(v))

    def snapshot(self) -> dict:
        """JSON-able view: counters/gauges as numbers, histograms as
        count/sum/mean/p50/p90/p99/max summaries."""
        return {name: m.summary()
                for name, m in sorted(self._metrics.items())}

    def prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4): counters and
        gauges as single samples, histograms as the standard cumulative
        ``_bucket{le=...}`` / ``_sum`` / ``_count`` series."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} {m.kind}")
            if m.kind == "histogram":
                cum = 0
                for edge, c in zip(m.edges, m.counts):
                    cum += int(c)
                    lines.append(f'{pn}_bucket{{le="{edge:.9g}"}} {cum}')
                lines.append(f'{pn}_bucket{{le="+Inf"}} {int(m.count)}')
                lines.append(f"{pn}_sum {m.sum:.9g}")
                lines.append(f"{pn}_count {int(m.count)}")
            else:
                lines.append(f"{pn} {m.summary():.9g}"
                             if isinstance(m.summary(), float)
                             else f"{pn} {m.summary()}")
        return "\n".join(lines) + "\n"

    def latency_summary(self, *names: str) -> dict:
        """Millisecond p50/p90/p99/max for the named second-valued
        histograms — the bench artifact's percentile section."""
        out = {}
        for name in names:
            h = self._metrics.get(name)
            if isinstance(h, Histogram) and h.count:
                s = h.summary()
                out[name] = {k: round(s[k] * 1e3, 3)
                             for k in ("p50", "p90", "p99", "max")}
                out[name]["count"] = s["count"]
        return out


# ---------------------------------------------------------------------------
# trace timeline
# ---------------------------------------------------------------------------

class TraceRecorder:
    """Bounded ring buffer of Chrome trace events.

    Events are plain dicts in the Chrome trace-event JSON schema
    (``ph="i"`` instants, ``ph="X"`` complete spans; timestamps in
    microseconds since the recorder's epoch on the shared monotonic
    clock). ``pid`` carries the lane (cluster replica); Perfetto renders
    each lane as its own process track, named via ``M`` metadata events
    emitted at export. The deque drops the OLDEST events under pressure
    (``dropped`` counts them) — a trace is a window onto the recent
    past, never a memory leak.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._emitted = 0
        self.t0 = now()
        self._lanes: Dict[int, str] = {}

    @property
    def dropped(self) -> int:
        return self._emitted - len(self._events)

    def set_lane(self, lane: int, name: str):
        self._lanes[int(lane)] = str(name)

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def _emit(self, ev: dict):
        self._events.append(ev)
        self._emitted += 1

    def instant(self, name: str, *, lane: int = 0, cat: str = "serving",
                t: Optional[float] = None, **args):
        """A point event (``ph="i"``, process-scoped)."""
        self._emit({"name": name, "ph": "i", "s": "p", "cat": cat,
                    "ts": self._us(now() if t is None else t),
                    "pid": int(lane), "tid": 0, "args": args})

    def complete(self, name: str, t_start: float, dur_s: float, *,
                 lane: int = 0, cat: str = "serving", **args):
        """A closed span (``ph="X"`` with an explicit duration)."""
        self._emit({"name": name, "ph": "X", "cat": cat,
                    "ts": self._us(t_start), "dur": dur_s * 1e6,
                    "pid": int(lane), "tid": 0, "args": args})

    @contextlib.contextmanager
    def span(self, name: str, *, lane: int = 0, cat: str = "serving",
             **args):
        t0 = now()
        try:
            yield
        finally:
            self.complete(name, t0, now() - t0, lane=lane, cat=cat, **args)

    def events(self) -> list:
        return list(self._events)

    def chrome_trace(self) -> dict:
        """The exportable ``{"traceEvents": [...]}`` document: lane-name
        ``M`` metadata first, then the buffered events."""
        meta = [{"name": "process_name", "ph": "M", "pid": lane, "tid": 0,
                 "args": {"name": name}}
                for lane, name in sorted(self._lanes.items())]
        return {"traceEvents": meta + list(self._events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


# ---------------------------------------------------------------------------
# the facade engines carry
# ---------------------------------------------------------------------------

class Telemetry:
    """One registry + one trace timeline + this writer's lane.

    ``for_lane(i, name)`` returns a view sharing both halves but
    stamping events into lane ``i`` — the ``EdgeCluster`` keeps lane 0
    for control-plane events (admission, autoscale, routing) and hands
    replica ``r`` lane ``r + 1``, so one exported trace shows every
    replica's decode windows against the cluster's decisions.
    """

    def __init__(self, *, trace_capacity: int = 65536, lane: int = 0,
                 lane_name: str = "serving"):
        self.registry = MetricsRegistry()
        self.trace = TraceRecorder(capacity=trace_capacity)
        self.lane = int(lane)
        self.trace.set_lane(self.lane, lane_name)

    def for_lane(self, lane: int, name: Optional[str] = None) -> "Telemetry":
        view = Telemetry.__new__(Telemetry)
        view.registry = self.registry
        view.trace = self.trace
        view.lane = int(lane)
        if name is not None:
            self.trace.set_lane(lane, name)
        return view

    # thin lane-stamped pass-throughs
    def instant(self, name: str, **args):
        self.trace.instant(name, lane=self.lane, **args)

    def span(self, name: str, **args):
        return self.trace.span(name, lane=self.lane, **args)

    def complete(self, name: str, t_start: float, dur_s: float, **args):
        self.trace.complete(name, t_start, dur_s, lane=self.lane, **args)

    def inc(self, name: str, n=1):
        self.registry.inc(name, n)

    def set(self, name: str, v):
        self.registry.set(name, v)

    def observe(self, name: str, v, n: int = 1):
        self.registry.observe(name, v, n)


# ---------------------------------------------------------------------------
# optional jax.profiler capture
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def profile_capture(profile_dir: Optional[str]):
    """Wrap a region in a ``jax.profiler`` trace when ``profile_dir`` is
    set (the launcher's ``--profile-dir``); a no-op otherwise, and a
    no-op (with a warning) when the profiler backend is unavailable."""
    if not profile_dir:
        yield
        return
    import jax
    try:
        jax.profiler.start_trace(profile_dir)
    except Exception as e:                     # pragma: no cover - env dep
        print(f"telemetry: jax.profiler unavailable ({e}); skipping")
        yield
        return
    try:
        yield
    finally:
        jax.profiler.stop_trace()
