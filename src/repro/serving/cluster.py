"""Edge-cluster serving: N decoder replicas behind a router, with live
session migration on mmWave cell handover.

The paper's mobile-edge setting has one decoder per cell's edge server.
Serving real traffic therefore means a *cluster*: ``EdgeCluster`` owns N
``ContinuousBatchingEngine`` replicas (replica ``i`` fronts cell ``i``), a
router with pluggable placement policies, and a handover loop driven by
each UE's :class:`~repro.core.channel.MobilityChannel` — when a UE crosses
a cell boundary mid-generation, the cluster applies one of three policies:

``migrate``
    Live migration (``serving/migration.py``): extract the session's slot
    state as a :class:`~repro.serving.migration.MigrationSnapshot`
    (optionally quantized at ``snapshot_bits``), charge the simulated
    backhaul for its bytes/latency, and inject it into a free slot on the
    new cell's replica. Raw snapshots keep the remaining token stream
    bit-identical to an unmigrated run.
``stay``
    Stay-and-degrade: the session keeps decoding on the old replica while
    the channel's ``detach_factor`` throttles every subsequent uplink
    transfer — the baseline migration is measured against.
``drop``
    Drop-and-replay: retire the partial session and resubmit
    ``prompt + emitted tokens`` as a fresh prompt on the new replica —
    no state crosses the backhaul, but the whole context re-uploads and
    re-prefills. The cluster folds the partial accounting into the replay
    session's final result.

Placement policies (new-request routing):

``least-loaded``   replica with the fewest active + queued sessions;
``best-channel``   the replica fronting the UE's current physical cell
                   (mobility channels; others fall back to least-loaded);
``round-robin``    strict rotation.

Replicas are independent engines: each has its own slot pool, its own
orchestrator/controller (per-edge-server control plane — migrated sessions
carry their link EWMA and dwell state across, see ``migration.py``), and —
since the pipeline executor is per-engine — its own device-loop pipeline
thread, so N replicas overlap their decode windows instead of serializing
through one FIFO.

With ``dp``/``mp`` set, replicas additionally map onto DISJOINT device
subsets: replica ``i`` gets devices ``[i*dp*mp, (i+1)*dp*mp)`` as its own
``('dp','mp')`` serving mesh (``models.sharding.serving_mesh``), so N
replicas really do run on N separate slices of the machine instead of
timesharing device 0. Migration between same-shape meshes stays
bit-identical: snapshots are host-addressable numpy blocks regardless of
the source mesh, and inject re-places them onto the target's mesh.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.core.channel import MobilityChannel, tx_seconds
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.models.sharding import serving_mesh
from repro.serving.batcher import ContinuousBatchingEngine
from repro.serving.migration import (detach_session, extract_session,
                                     inject_session)
from repro.serving.session import Request, Session

PLACEMENTS = ("least-loaded", "best-channel", "round-robin")
HANDOVER_POLICIES = ("migrate", "stay", "drop")


def default_orchestrator(cfg: ModelConfig,
                         latency_budget_s: float = 0.006, *,
                         ema: float = 0.5,
                         hysteresis: float = 1.0) -> Orchestrator:
    """One per-replica control plane from the analytic payload model (the
    same calibration ``launch/serve.py`` uses for smoke weights). The
    serving benchmarks build theirs through here too, so an A/B bench and
    the cluster can never drift onto different calibrations."""
    return Orchestrator(
        [ModeProfile(m, bottleneck.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=latency_budget_s),
        ema=ema, hysteresis=hysteresis)


class EdgeCluster:
    """N-replica split-serving cluster with handover-aware routing.

    ``make_orchestrator``/``make_controller`` are per-replica factories
    ``(replica_idx) -> Orchestrator | ModeController | None``; the default
    builds an independent :func:`default_orchestrator` per replica. Every
    engine kwarg (``host_loop``, ``max_window``, ``max_pending``, ...)
    passes through ``engine_kwargs``.

    ``dp``/``mp`` give every replica its own ``(dp, mp)`` serving mesh on
    a disjoint contiguous device block (``devices`` overrides the global
    ``jax.devices()`` order); both unset keeps the legacy single-device
    replicas (``mesh=None`` engines).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_replicas: int = 2,
                 n_slots: int = 4, cache_len: int = 128,
                 placement: str = "least-loaded",
                 handover: str = "migrate",
                 snapshot_bits: int = 0,
                 backhaul_bps: float = 1.25e9,
                 latency_budget_s: float = 0.006,
                 make_orchestrator=None, make_controller=None,
                 dp: Optional[int] = None, mp: Optional[int] = None,
                 devices=None,
                 **engine_kwargs):
        if placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}")
        if handover not in HANDOVER_POLICIES:
            raise ValueError(
                f"handover must be one of {HANDOVER_POLICIES}")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        meshes: List = [None] * n_replicas
        if dp is not None or mp is not None:
            dp, mp = int(dp or 1), int(mp or 1)
            devices = list(jax.devices() if devices is None else devices)
            per = dp * mp
            if n_replicas * per > len(devices):
                raise ValueError(
                    f"{n_replicas} replicas x ({dp} x {mp}) mesh need "
                    f"{n_replicas * per} devices, only {len(devices)} "
                    "available — on CPU, set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N")
            meshes = [serving_mesh(dp, mp,
                                   devices=devices[i * per:(i + 1) * per])
                      for i in range(n_replicas)]
        self.cfg = cfg
        self.placement = placement
        self.handover = handover
        self.snapshot_bits = int(snapshot_bits)
        self.backhaul_bps = float(backhaul_bps)
        self.replicas: List[ContinuousBatchingEngine] = []
        for i in range(n_replicas):
            kw = dict(engine_kwargs)
            if make_controller is not None:
                ctl = make_controller(i)
                if ctl is not None:
                    kw["controller"] = ctl
            elif make_orchestrator is not None:
                kw["orchestrator"] = make_orchestrator(i)
            else:
                kw["orchestrator"] = default_orchestrator(cfg,
                                                          latency_budget_s)
            self.replicas.append(ContinuousBatchingEngine(
                params, cfg, n_slots=n_slots, cache_len=cache_len,
                mesh=meshes[i], **kw))
        self._rr = 0                       # round-robin cursor
        self._home: Dict[Hashable, int] = {}
        #: snapshots/replays that could not land yet (target pool or queue
        #: full); retried every cluster step
        self._parked: List[tuple] = []
        #: partial sessions superseded by a drop-and-replay, folded into
        #: the replay session's result at collection
        self._replay_base: Dict[Hashable, Session] = {}
        self.finished: List[Session] = []
        self._collected: set = set()       # id()s already merged
        # cluster-level counters
        self.migrations = 0
        self.migration_bytes = 0
        self.migration_transfer_s = 0.0
        self.replays = 0
        self.replayed_tokens = 0
        self.handovers = 0                 # boundary crossings acted on
        self.handovers_ignored = 0         # crossings under the stay policy
        self.rejected = 0                  # router-level submit rejections

    # -- routing --------------------------------------------------------------
    def _load(self, eng: ContinuousBatchingEngine) -> int:
        return len(eng.active) + len(eng.queue) + len(eng._pending)

    def place(self, req: Request) -> int:
        """Pick the home replica for a new request under the configured
        placement policy (exposed for tests and custom routers)."""
        if self.placement == "round-robin":
            r = self._rr % len(self.replicas)
            self._rr += 1
            return r
        if self.placement == "best-channel" and \
                isinstance(req.channel, MobilityChannel):
            return req.channel.current_cell % len(self.replicas)
        return min(range(len(self.replicas)),
                   key=lambda i: (self._load(self.replicas[i]), i))

    def submit(self, req: Request) -> bool:
        """Route a request to its home replica. Returns False when that
        replica's admission queue rejected it (back-pressure).

        Mobility scripts must only name cells this cluster fronts
        (replica ``i`` fronts cell ``i``): a cell id >= ``n_replicas``
        would alias onto some replica under the modulo map and a crossing
        into it could be misread as "crossed back into the serving cell",
        silently disabling migration for the session — so it is an error.
        """
        if isinstance(req.channel, MobilityChannel) and \
                int(req.channel.cells.max()) >= len(self.replicas):
            raise ValueError(
                f"request {req.rid!r}: mobility script names cell "
                f"{int(req.channel.cells.max())} but the cluster has only "
                f"{len(self.replicas)} replicas (replica i fronts cell i)")
        r = self.place(req)
        if isinstance(req.channel, MobilityChannel):
            # the session will be served from replica r's cell until a
            # migration (or drop-and-replay) re-homes it
            req.channel.serving_cell = r
        ok = self.replicas[r].submit(req)
        if ok:
            self._home[req.rid] = r
        else:
            self.rejected += 1
        return ok

    # -- the cluster tick -----------------------------------------------------
    def step(self) -> bool:
        """One cluster tick: every replica advances one engine step (device
        replicas may cover a whole decode window), then pending handovers
        are applied and parked migrations/replays retried. Returns False
        when no replica has work and nothing is parked."""
        progressed = [eng.step() for eng in self.replicas]
        acted = self._process_handovers()
        drained = self._drain_parked()
        return any(progressed) or acted or drained or bool(self._parked)

    def _process_handovers(self) -> bool:
        acted = False
        for r, eng in enumerate(self.replicas):
            for slot, sess in sorted(eng.active.items()):
                ch = sess.request.channel
                if not isinstance(ch, MobilityChannel):
                    continue
                pending = ch.pending_handover
                if pending is not None:
                    sess.handover_ticks = list(ch.handover_ticks)
                    acted = True
                    self.handovers += 1
                    if self.handover == "stay":
                        # acknowledge the event but keep the session where
                        # it is: every later uplink transfer pays
                        # detach_factor
                        ch.pending_handover = None
                        self.handovers_ignored += 1
                        continue
                    target = pending % len(self.replicas)
                elif self.handover != "stay" and ch.detached:
                    # no crossing *event*, but the session is serving
                    # detached anyway — e.g. least-loaded placement put it
                    # on a replica that never fronted its cell. A migrating
                    # cluster corrects that instead of paying detach_factor
                    # for the session's whole life.
                    target = ch.last_cell % len(self.replicas)
                    acted = True
                else:
                    continue
                if target == r:
                    ch.ack_handover(r)      # crossed back into home cell
                elif self.handover == "migrate":
                    self._migrate(eng, r, sess, target)
                else:                        # drop-and-replay
                    self._drop_replay(eng, r, sess, target)
        return acted

    def _migrate(self, eng, r: int, sess: Session, target: int):
        snap = extract_session(eng, sess.request.rid,
                               bits=self.snapshot_bits, source_replica=r)
        t = tx_seconds(snap.nbytes, self.backhaul_bps)
        sess.migrations.append({
            "kind": "migrate", "tick": eng.tick, "from_replica": r,
            "to_replica": target, "bytes": snap.nbytes,
            "bits": snap.bits, "transfer_s": round(t, 6)})
        sess.transfer_s += t
        self.migrations += 1
        self.migration_bytes += snap.nbytes
        self.migration_transfer_s += t
        if inject_session(self.replicas[target], snap):
            self._land(snap.rid, target, sess.request.channel)
        else:
            self._parked.append(("migrate", snap, target))

    def _drop_replay(self, eng, r: int, sess: Session, target: int):
        rid = sess.request.rid
        if sess.request.prompt.ndim != 1:
            raise NotImplementedError("drop-and-replay cannot reconstruct "
                                      "multi-codebook (audio) prompts from "
                                      "the emitted token stream")
        # drop ships no state: detach lands in-flight windows and frees
        # the slot without the device->host state copy a snapshot costs
        _, _, requirement, _ = detach_session(eng, rid)
        base = self._replay_base.get(rid)
        if base is not None:                # dropped before: fold the chain
            self._fold(base, sess)
        else:
            base = self._replay_base[rid] = sess
        # the replay prompt is the ORIGINAL prompt plus every token emitted
        # so far (across the whole drop chain) — greedy decode regenerates
        # the decoder state by prefilling the full context on the target
        budget = base.gen_budget or base.request.max_new_tokens
        remaining = budget - len(base.tokens)
        base.migrations.append({
            "kind": "replay", "tick": eng.tick, "from_replica": r,
            "to_replica": target, "bytes": 0, "bits": 0,
            "replayed_tokens": len(base.tokens)})
        self.replays += 1
        self.replayed_tokens += len(base.tokens)
        prompt = base.request.prompt
        req = Request(
            rid=rid,
            prompt=np.concatenate([prompt,
                                   np.asarray(base.tokens, prompt.dtype)]),
            max_new_tokens=max(remaining, 1),
            channel=base.request.channel,
            requirement=requirement or base.request.requirement,
            arrival_tick=self.replicas[target].tick)
        if self.replicas[target].submit(req):
            self._land(rid, target, req.channel)
        else:
            self._parked.append(("replay", req, target))

    def _land(self, rid: Hashable, target: int, ch) -> None:
        self._home[rid] = target
        if isinstance(ch, MobilityChannel):
            ch.ack_handover(target)

    def _drain_parked(self) -> bool:
        still, drained = [], False
        for kind, item, target in self._parked:
            if kind == "migrate":
                ok = inject_session(self.replicas[target], item)
                rid, ch = item.rid, item.session.request.channel
            else:
                ok = self.replicas[target].submit(item)
                rid, ch = item.rid, item.channel
            if ok:
                drained = True
                self._land(rid, target, ch)
            else:
                still.append((kind, item, target))
        self._parked = still
        return drained

    # -- collection -----------------------------------------------------------
    @staticmethod
    def _fold(base: Session, cont: Session) -> None:
        """Fold a continuation session's accounting into its base (the
        partial session a drop-and-replay superseded)."""
        base.tokens = base.tokens + cont.tokens
        base.wire_bytes += cont.wire_bytes
        base.prefill_wire_bytes += cont.prefill_wire_bytes
        base.transfer_s += cont.transfer_s
        base.deadline_misses += cont.deadline_misses
        base.escalations += cont.escalations
        base.migrations = base.migrations + cont.migrations
        base.mode_trace = base.mode_trace + cont.mode_trace
        base.finished_tick = cont.finished_tick
        for m, c in cont.mode_counts.items():
            base.mode_counts[m] = base.mode_counts.get(m, 0) + c

    def collect(self) -> List[Session]:
        """Sweep every replica's finished sessions into the cluster-level
        list, folding drop-and-replay chains into one merged session per
        rid. Idempotent across calls; returns the cluster list."""
        for eng in self.replicas:
            for sess in eng.finished:
                if id(sess) in self._collected:
                    continue
                self._collected.add(id(sess))
                rid = sess.request.rid
                base = self._replay_base.pop(rid, None)
                if base is not None:
                    self._fold(base, sess)
                    sess = base
                ch = sess.request.channel
                if isinstance(ch, MobilityChannel):
                    sess.handover_ticks = list(ch.handover_ticks)
                self.finished.append(sess)
        return self.finished

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_ticks: int = 100_000) -> List[Session]:
        """Drive the cluster until every submitted request completes (or
        the tick budget runs out); returns the merged finished sessions."""
        for r in requests or []:
            self.submit(r)
        for _ in range(max_ticks):
            if not self.step():
                break
        for eng in self.replicas:
            eng._materialize_inflight()
            eng._sync_device_state()
        return self.collect()

    def warm(self, prompt: np.ndarray, gen: int = 2):
        """Trace every replica's compiled paths before a measured run.
        Single-device replicas share their jitted step objects (see
        ``batcher._compiled_steps``), so the first replica pays the XLA
        compiles and the rest just trace-hit; mesh replicas live on
        disjoint device subsets and each compile their own steps."""
        for eng in self.replicas:
            eng.warm(np.asarray(prompt), gen=gen)

    def close(self):
        """Shut every replica's pipeline worker down (see
        ``ContinuousBatchingEngine.close``)."""
        for eng in self.replicas:
            eng.close()

    def __enter__(self) -> "EdgeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- aggregate stats ------------------------------------------------------
    def stats(self) -> dict:
        self.collect()
        done = self.finished
        toks = sum(len(s.tokens) for s in done)
        # every admission's first token is a prefill argmax, and a
        # drop-and-replay chain re-admits once per replay — each fold
        # therefore contributes one more prefill-delivered (non-decode)
        # token that per-decode-token rates must not divide by
        dec = sum(max(len(s.tokens) - 1
                      - sum(1 for m in s.migrations
                            if m["kind"] == "replay"), 0)
                  for s in done)
        misses = sum(s.deadline_misses for s in done)
        latencies = []
        for s in done:
            ch = s.request.channel
            if isinstance(ch, MobilityChannel):
                latencies.extend(ch.handover_latencies)
        per_replica = []
        for i, eng in enumerate(self.replicas):
            st = eng.stats()
            per_replica.append({
                "replica": i,
                "finished": st["requests_finished"],
                "active": len(eng.active),
                "queued": len(eng.queue),
                "free_slots": eng.pool.n_free,
                "decode_ticks": st["decode_ticks"],
                "decode_tokens": st["decode_tokens"],
                # decoded_slot_ticks counts work done ON this replica — a
                # migrated-in session's earlier tokens were decoded on its
                # previous home and must not inflate this occupancy
                "occupancy": round(
                    st["decoded_slot_ticks"]
                    / max(st["decode_ticks"] * eng.pool.n_slots, 1), 3),
            })
        return {
            "n_replicas": len(self.replicas),
            "placement": self.placement,
            "handover_policy": self.handover,
            "snapshot_bits": self.snapshot_bits,
            "requests_finished": len(done),
            "requests_rejected": self.rejected,
            "generated_tokens": toks,
            "decode_tokens": dec,
            "wire_bytes": sum(s.wire_bytes for s in done),
            "decode_wire_bytes_per_token": (
                sum(s.wire_bytes - s.prefill_wire_bytes for s in done)
                / max(dec, 1)),
            "deadline_misses": misses,
            "deadline_miss_rate": misses / max(dec, 1),
            "handovers": self.handovers,
            "handovers_ignored": self.handovers_ignored,
            "migrations": self.migrations,
            "migration_bytes": self.migration_bytes,
            "migration_transfer_s": round(self.migration_transfer_s, 6),
            "parked": len(self._parked),
            "replays": self.replays,
            "replayed_tokens": self.replayed_tokens,
            "mean_handover_latency_ticks": (
                float(np.mean(latencies)) if latencies else 0.0),
            "per_replica": per_replica,
        }
