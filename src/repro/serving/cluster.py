"""Edge-cluster serving: N decoder replicas behind a router, with live
session migration on mmWave cell handover and fleet-scale elasticity.

The paper's mobile-edge setting has one decoder per cell's edge server.
Serving real traffic therefore means a *cluster*: ``EdgeCluster`` owns N
``ContinuousBatchingEngine`` replicas (replica ``i`` fronts cell ``i``), a
router with pluggable placement policies, and a handover loop driven by
each UE's mobility channel — when a UE crosses a cell boundary
mid-generation, the cluster applies one of three policies:

``migrate``
    Live migration (``serving/migration.py``): extract the session's slot
    state as a :class:`~repro.serving.migration.MigrationSnapshot`
    (optionally quantized at ``snapshot_bits``), charge the simulated
    backhaul for its bytes/latency, and inject it into a free slot on the
    new cell's replica. Raw snapshots keep the remaining token stream
    bit-identical to an unmigrated run.
``stay``
    Stay-and-degrade: the session keeps decoding on the old replica while
    the channel's ``detach_factor`` throttles every subsequent uplink
    transfer — the baseline migration is measured against.
``drop``
    Drop-and-replay: retire the partial session and resubmit
    ``prompt + emitted tokens`` as a fresh prompt on the new replica —
    no state crosses the backhaul, but the whole context re-uploads and
    re-prefills. The cluster folds the partial accounting into the replay
    session's final result.

Placement policies (new-request routing):

``least-loaded``   replica with the fewest active + queued sessions;
``best-channel``   the replica fronting the UE's current physical cell
                   (mobility channels; others fall back to least-loaded);
``round-robin``    strict rotation.

Mobility is duck-typed (:func:`~repro.core.channel.is_mobile`): scalar
``MobilityChannel`` objects and the vectorized
:class:`~repro.core.channel.FleetChannel` lane views are interchangeable,
so a 10k-UE fleet rides one array-stepped channel with no per-UE Python
objects on the hot path.

**Elasticity** (fleet-scale serving): with an
:class:`~repro.serving.controller.Autoscaler` attached, every cluster
step feeds it live occupancy / queue-backlog / session-SLO-miss signals
and applies its decision — ``scale_up`` adds a replica (same shapes, so
it reuses the module-level ``_compiled_steps`` cache: **no recompile**),
``scale_down`` *retires* one: the replica index stays in place (the
cell-fronting modulo map and ``_home`` entries never shift), new work
routes around it, and its live sessions drain out through the existing
migration path until it is empty — scale-down never strands a session.
With an :class:`~repro.serving.fleet.SLOAdmission` gate attached,
``submit`` rejects requests whose *predicted* completion already misses
their session SLO (hopeless link, or queue wait + service time beyond
``slo_ticks``) and parks requests under transient backlog the autoscaler
may relieve — parked requests retry every step and age out to terminal
rejections after ``park_max_ticks``.

Replicas are independent engines: each has its own slot pool, its own
orchestrator/controller (per-edge-server control plane — migrated sessions
carry their link EWMA and dwell state across, see ``migration.py``), and —
since the pipeline executor is per-engine — its own device-loop pipeline
thread, so N replicas overlap their decode windows instead of serializing
through one FIFO.

With ``dp``/``mp`` set, replicas additionally map onto DISJOINT device
subsets: replica ``i`` gets devices ``[i*dp*mp, (i+1)*dp*mp)`` as its own
``('dp','mp')`` serving mesh (``models.sharding.serving_mesh``), so N
replicas really do run on N separate slices of the machine instead of
timesharing device 0. Migration between same-shape meshes stays
bit-identical: snapshots are host-addressable numpy blocks regardless of
the source mesh, and inject re-places them onto the target's mesh.
(Elastic scaling requires mesh-less replicas: a new replica has no
disjoint device block to claim.)
"""
from __future__ import annotations

import heapq
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck
from repro.core.channel import is_mobile, tx_seconds
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.models.sharding import serving_mesh
from repro.serving.batcher import ContinuousBatchingEngine
from repro.serving.controller import Autoscaler
from repro.serving.fleet import SLOAdmission
from repro.serving.migration import (detach_session, extract_session,
                                     inject_session)
from repro.serving.session import Request, Session
from repro.serving.telemetry import Telemetry

PLACEMENTS = ("least-loaded", "best-channel", "round-robin")
HANDOVER_POLICIES = ("migrate", "stay", "drop")


def default_orchestrator(cfg: ModelConfig,
                         latency_budget_s: float = 0.006, *,
                         ema: float = 0.5,
                         hysteresis: float = 1.0) -> Orchestrator:
    """One per-replica control plane from the analytic payload model (the
    same calibration ``launch/serve.py`` uses for smoke weights). The
    serving benchmarks build theirs through here too, so an A/B bench and
    the cluster can never drift onto different calibrations."""
    return Orchestrator(
        [ModeProfile(m, bottleneck.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=latency_budget_s),
        ema=ema, hysteresis=hysteresis)


class EdgeCluster:
    """N-replica split-serving cluster with handover-aware routing.

    ``make_orchestrator``/``make_controller`` are per-replica factories
    ``(replica_idx) -> Orchestrator | ModeController | None``; the default
    builds an independent :func:`default_orchestrator` per replica. Every
    engine kwarg (``host_loop``, ``max_window``, ``max_pending``, ...)
    passes through ``engine_kwargs``.

    ``admission`` attaches an :class:`SLOAdmission` gate to ``submit``;
    ``autoscaler`` attaches an :class:`Autoscaler` whose per-step
    decisions drive :meth:`scale_up`/:meth:`scale_down`.

    ``dp``/``mp`` give every replica its own ``(dp, mp)`` serving mesh on
    a disjoint contiguous device block (``devices`` overrides the global
    ``jax.devices()`` order); both unset keeps the legacy single-device
    replicas (``mesh=None`` engines).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_replicas: int = 2,
                 n_slots: int = 4, cache_len: int = 128,
                 placement: str = "least-loaded",
                 handover: str = "migrate",
                 snapshot_bits: int = 0,
                 backhaul_bps: float = 1.25e9,
                 latency_budget_s: float = 0.006,
                 make_orchestrator=None, make_controller=None,
                 admission: Optional[SLOAdmission] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 telemetry: Optional[Telemetry] = None,
                 dp: Optional[int] = None, mp: Optional[int] = None,
                 devices=None,
                 **engine_kwargs):
        if placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}")
        if handover not in HANDOVER_POLICIES:
            raise ValueError(
                f"handover must be one of {HANDOVER_POLICIES}")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        meshes: List = [None] * n_replicas
        if dp is not None or mp is not None:
            if autoscaler is not None:
                raise ValueError(
                    "elastic scaling requires mesh-less replicas: a new "
                    "replica has no disjoint device block to claim")
            dp, mp = int(dp or 1), int(mp or 1)
            devices = list(jax.devices() if devices is None else devices)
            per = dp * mp
            if n_replicas * per > len(devices):
                raise ValueError(
                    f"{n_replicas} replicas x ({dp} x {mp}) mesh need "
                    f"{n_replicas * per} devices, only {len(devices)} "
                    "available — on CPU, set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N")
            meshes = [serving_mesh(dp, mp,
                                   devices=devices[i * per:(i + 1) * per])
                      for i in range(n_replicas)]
        self.cfg = cfg
        self.placement = placement
        self.handover = handover
        self.snapshot_bits = int(snapshot_bits)
        self.backhaul_bps = float(backhaul_bps)
        self.admission = admission
        self.autoscaler = autoscaler
        #: shared telemetry: lane 0 carries control-plane events
        #: (admission, migration, autoscale); replica ``i`` writes lane
        #: ``i + 1`` via its engine's Telemetry view (see ``_new_engine``)
        self._tel = telemetry
        if telemetry is not None:
            telemetry.trace.set_lane(telemetry.lane, "cluster")
            if admission is not None:
                admission.telemetry = telemetry
        # replica-construction closure state: scale_up builds new engines
        # from exactly what __init__ built the originals from, so the
        # module-level _compiled_steps lru_cache hits (same cfg/cache_len/
        # mesh key) and a scale-up never pays an XLA recompile
        self._params = params
        self._n_slots = int(n_slots)
        self._cache_len = int(cache_len)
        self._latency_budget_s = float(latency_budget_s)
        self._make_orchestrator = make_orchestrator
        self._make_controller = make_controller
        self._engine_kwargs = dict(engine_kwargs)
        self._meshed = any(m is not None for m in meshes)
        self.replicas: List[ContinuousBatchingEngine] = []
        for i in range(n_replicas):
            self.replicas.append(self._new_engine(i, meshes[i]))
        #: replica indices that are draining toward removal from service.
        #: Indices are STABLE — the list never shrinks, so the cell ->
        #: replica modulo map and every ``_home`` entry stay valid; a
        #: retired index can be revived by a later scale_up.
        self.retired: set = set()
        self._rr = 0                       # round-robin cursor
        self._home: Dict[Hashable, int] = {}
        #: snapshots/replays that could not land yet (target pool or queue
        #: full); retried every cluster step
        self._parked: List[tuple] = []
        #: admission-parked requests (req, parked_since_clock); re-decided
        #: every cluster step, aged out to terminal rejections
        self._slo_parked: List[Tuple[Request, int]] = []
        #: partial sessions superseded by a drop-and-replay, folded into
        #: the replay session's result at collection
        self._replay_base: Dict[Hashable, Session] = {}
        self.finished: List[Session] = []
        #: per-replica high-water mark into eng.finished (append-only), so
        #: collect() is O(new finishes), not O(all finishes) per sweep
        self._collect_offsets: List[int] = [0] * n_replicas
        self.clock = 0                     # cluster steps taken
        # cluster-level counters
        self.submitted = 0                 # router-level submit attempts
        self.migrations = 0
        self.migration_bytes = 0
        self.migration_transfer_s = 0.0
        self.replays = 0
        self.replayed_tokens = 0
        self.handovers = 0                 # boundary crossings acted on
        self.handovers_ignored = 0         # crossings under the stay policy
        self.rejected = 0                  # router-level submit rejections
        self.slo_rejected = 0              # admission-gate rejections
        self.slo_park_expired = 0          # parked past park_max_ticks
        self.scale_ups = 0
        self.scale_downs = 0
        #: (clock, "up"/"down", replica_idx) per elasticity action
        self.scale_events: List[Tuple[int, str, int]] = []
        # windowed session-SLO signal for the autoscaler
        self._obs_finished = 0
        self._obs_late = 0

    def _new_engine(self, i: int, mesh=None) -> ContinuousBatchingEngine:
        kw = dict(self._engine_kwargs)
        if self._tel is not None:
            kw["telemetry"] = self._tel.for_lane(i + 1, f"replica{i}")
        if self._make_controller is not None:
            ctl = self._make_controller(i)
            if ctl is not None:
                kw["controller"] = ctl
        elif self._make_orchestrator is not None:
            kw["orchestrator"] = self._make_orchestrator(i)
        else:
            kw["orchestrator"] = default_orchestrator(
                self.cfg, self._latency_budget_s)
        return ContinuousBatchingEngine(
            self._params, self.cfg, n_slots=self._n_slots,
            cache_len=self._cache_len, mesh=mesh, **kw)

    # -- routing --------------------------------------------------------------
    def _live(self) -> List[int]:
        return [i for i in range(len(self.replicas))
                if i not in self.retired]

    @property
    def n_live(self) -> int:
        return len(self.replicas) - len(self.retired)

    def _load(self, eng: ContinuousBatchingEngine) -> int:
        return len(eng.active) + len(eng.queue) + len(eng._pending)

    def _least_loaded(self) -> int:
        return min(self._live(),
                   key=lambda i: (self._load(self.replicas[i]), i))

    def _route_cell(self, cell: int) -> int:
        """Cell -> replica under the modulo map, detouring around retired
        replicas (a retired index must never receive NEW work)."""
        r = int(cell) % len(self.replicas)
        return r if r not in self.retired else self._least_loaded()

    def place(self, req: Request) -> int:
        """Pick the home replica for a new request under the configured
        placement policy (exposed for tests and custom routers)."""
        if self.placement == "round-robin":
            live = self._live()
            r = live[self._rr % len(live)]
            self._rr += 1
            return r
        if self.placement == "best-channel" and is_mobile(req.channel):
            return self._route_cell(req.channel.current_cell)
        return self._least_loaded()

    def _predicted_wait_ticks(self, req: Request) -> int:
        """Queue-wait prediction the admission gate measures against the
        request's session SLO: waiting requests ahead of it, beyond the
        currently free slots, each occupy a slot for roughly one service
        time (1 token/tick greedy decode)."""
        live = [self.replicas[i] for i in self._live()]
        free = sum(e.pool.n_free for e in live)
        # only DUE backlog counts: scheduled future arrivals (engine
        # ``_pending`` heaps) are not waiting ahead of this request — by
        # their arrival ticks today's occupants will have drained
        waiting = sum(len(e.queue) for e in live) + len(self._slo_parked)
        slots = sum(e.pool.n_slots for e in live)
        if waiting < free:
            return 0
        service = req.max_new_tokens + req.prompt_len
        return int(np.ceil((waiting - free + 1) / max(slots, 1)) * service)

    def _queue_per_slot(self) -> float:
        live = [self.replicas[i] for i in self._live()]
        waiting = sum(len(e.queue) for e in live)
        return waiting / max(sum(e.pool.n_slots for e in live), 1)

    def submit(self, req: Request) -> bool:
        """Route a request to its home replica. Returns False when the
        admission gate rejected it (predicted SLO miss / hopeless link)
        or that replica's admission queue rejected it (back-pressure).

        Mobility scripts must only name cells this cluster fronts
        (replica ``i`` fronts cell ``i``): a cell id >= ``n_replicas``
        would alias onto some replica under the modulo map and a crossing
        into it could be misread as "crossed back into the serving cell",
        silently disabling migration for the session — so it is an error.
        """
        self.submitted += 1
        if is_mobile(req.channel) and \
                int(req.channel.cells.max()) >= len(self.replicas):
            raise ValueError(
                f"request {req.rid!r}: mobility script names cell "
                f"{int(req.channel.cells.max())} but the cluster has only "
                f"{len(self.replicas)} replicas (replica i fronts cell i)")
        if self.admission is not None:
            verdict = self._decide(req)
            if verdict == "reject":
                self.slo_rejected += 1
                return False
            if verdict == "park":
                self._slo_parked.append((req, self.clock))
                return True            # accepted, deferred
        return self._route(req)

    def _decide(self, req: Request) -> str:
        peek = getattr(req.channel, "peek", None)
        return self.admission.decide(
            slo_ticks=req.slo_ticks,
            predicted_wait_ticks=self._predicted_wait_ticks(req),
            service_ticks=req.max_new_tokens,
            capacity_bps=peek() if peek is not None else None,
            queue_per_slot=self._queue_per_slot(), rid=req.rid)

    @staticmethod
    def _try_submit(eng: ContinuousBatchingEngine, req: Request) -> bool:
        """Engine submit that does NOT bump the engine's queue-rejection
        counter on a full queue — the caller rejects/parks and counts the
        outcome itself. This keeps ``eng.queue.rejected`` meaning exactly
        one thing (a deferred arrival came due while the queue was full:
        one bump, one terminated request), so the cluster's conservation
        law balances: a parked replay retried N times against a full
        queue must not count as N rejections."""
        if req.arrival_tick <= eng.tick \
                and len(eng.queue) >= eng.queue.max_pending:
            return False
        return eng.submit(req)

    def _route(self, req: Request) -> bool:
        r = self.place(req)
        if is_mobile(req.channel):
            # the session will be served from replica r's cell until a
            # migration (or drop-and-replay) re-homes it
            req.channel.serving_cell = r
        ok = self._try_submit(self.replicas[r], req)
        if ok:
            self._home[req.rid] = r
        else:
            self.rejected += 1
        return ok

    # -- elasticity -----------------------------------------------------------
    def scale_up(self) -> int:
        """Add serving capacity: revive a fully-drained retired replica if
        one exists (its engine is empty and already compiled), else append
        a new replica built from the constructor's stored state — same
        shapes, so ``_compiled_steps`` cache-hits and no recompile runs.
        Returns the replica index now in service."""
        if self._meshed:
            raise ValueError("elastic scaling requires mesh-less replicas")
        for i in sorted(self.retired):
            if self._load(self.replicas[i]) == 0:
                self.retired.discard(i)
                self.scale_ups += 1
                self.scale_events.append((self.clock, "up", i))
                return i
        self.replicas.append(self._new_engine(len(self.replicas)))
        self._collect_offsets.append(0)
        self.scale_ups += 1
        idx = len(self.replicas) - 1
        self.scale_events.append((self.clock, "up", idx))
        return idx

    def scale_down(self, idx: Optional[int] = None) -> Optional[int]:
        """Retire one replica (default: the least-loaded live one). The
        index stays in the replica list — routing just stops offering it
        new work — and its sessions drain out via the migration path over
        subsequent steps, so no live session is ever stranded. Returns
        the retired index, or None when already at one live replica."""
        if self.n_live <= 1:
            return None
        if idx is None:
            idx = self._least_loaded()
        if idx in self.retired:
            return None
        self.retired.add(idx)
        self.scale_downs += 1
        self.scale_events.append((self.clock, "down", idx))
        # waiting work re-routes immediately; only in-flight slots drain
        eng = self.replicas[idx]
        while True:
            req = eng.queue.pop()
            if req is None:
                break
            self._route(req)
        while eng._pending:
            self._route(heapq.heappop(eng._pending)[2])
        return idx

    def _drain_retired(self) -> bool:
        """Push every retired replica's live sessions out through the
        migration machinery (drop-and-replay under the ``drop`` policy —
        it ships no state). Runs every step until the engines are empty;
        a full target parks the move and the next step retries."""
        acted = False
        for r in sorted(self.retired):
            eng = self.replicas[r]
            if not eng.active:
                continue
            for slot, sess in sorted(eng.active.items()):
                target = self._least_loaded()
                acted = True
                if self.handover == "drop" \
                        and sess.request.prompt.ndim == 1:
                    self._drop_replay(eng, r, sess, target)
                else:
                    self._migrate(eng, r, sess, target)
        return acted

    def _observe_autoscaler(self):
        live = [self.replicas[i] for i in self._live()]
        occ = float(np.mean([len(e.active) / max(e.pool.n_slots, 1)
                             for e in live]))
        finished, late = self._obs_finished, self._obs_late
        self._obs_finished = self._obs_late = 0
        miss_rate = late / finished if finished else 0.0
        decision = self.autoscaler.observe(
            n_replicas=self.n_live, occupancy=occ,
            queue_per_slot=self._queue_per_slot(), miss_rate=miss_rate)
        if decision > 0:
            idx = self.scale_up()
        elif decision < 0:
            idx = self.scale_down()
        if decision and self._tel is not None:
            # the autoscaler just appended its (tick, ±1, reason) event
            reason = self.autoscaler.events[-1][2]
            self._tel.instant(
                "autoscale_up" if decision > 0 else "autoscale_down",
                cat="autoscale", replica=idx, reason=reason,
                n_live=self.n_live, occupancy=round(occ, 3))

    # -- the cluster tick -----------------------------------------------------
    def step(self) -> bool:
        """One cluster tick: every replica advances one engine step (device
        replicas may cover a whole decode window), then pending handovers
        are applied, retired replicas drain, parked migrations/replays and
        admission-parked requests retry, and the autoscaler (if attached)
        observes and acts. Returns False when no replica has work and
        nothing is parked."""
        self.clock += 1
        progressed = [eng.step() for eng in self.replicas]
        acted = self._process_handovers()
        draining = self._drain_retired()
        drained = self._drain_parked()
        readmitted = self._retry_slo_parked()
        self.collect()                     # O(new finishes): SLO window
        if self.autoscaler is not None:
            self._observe_autoscaler()
        if self._tel is not None:
            self._tel.set("cluster.n_live", self.n_live)
            self._tel.set("cluster.queue_per_slot", self._queue_per_slot())
            self._tel.set("cluster.slo_parked", len(self._slo_parked))
            self._tel.set("cluster.parked_moves", len(self._parked))
        return (any(progressed) or acted or draining or drained
                or readmitted or bool(self._parked)
                or bool(self._slo_parked))

    def _retry_slo_parked(self) -> bool:
        if not self._slo_parked:
            return False
        still: List[Tuple[Request, int]] = []
        acted = False
        max_age = (self.admission.cfg.park_max_ticks
                   if self.admission is not None else 0)
        for req, since in self._slo_parked:
            if self.clock - since > max_age:
                self.slo_rejected += 1     # aged out: terminal rejection
                self.slo_park_expired += 1
                if self._tel is not None:
                    self._tel.instant("slo_park_expired", cat="admission",
                                      rid=req.rid,
                                      parked_ticks=self.clock - since)
                acted = True
                continue
            verdict = self._decide(req) if self.admission is not None \
                else "admit"
            if verdict == "reject":
                self.slo_rejected += 1
                acted = True
            elif verdict == "admit":
                self._route(req)
                acted = True
            else:
                still.append((req, since))
        self._slo_parked = still
        return acted

    def _process_handovers(self) -> bool:
        acted = False
        for r, eng in enumerate(self.replicas):
            for slot, sess in sorted(eng.active.items()):
                ch = sess.request.channel
                if not is_mobile(ch):
                    continue
                pending = ch.pending_handover
                if pending is not None:
                    sess.handover_ticks = list(ch.handover_ticks)
                    acted = True
                    self.handovers += 1
                    if self._tel is not None:
                        self._tel.inc("cluster.handovers")
                        self._tel.instant(
                            "handover", cat="migration",
                            rid=sess.request.rid, from_replica=r,
                            to_cell=int(pending), policy=self.handover)
                    if self.handover == "stay":
                        # acknowledge the event but keep the session where
                        # it is: every later uplink transfer pays
                        # detach_factor
                        ch.pending_handover = None
                        self.handovers_ignored += 1
                        continue
                    target = self._route_cell(pending)
                elif self.handover != "stay" and ch.detached \
                        and r not in self.retired:
                    # no crossing *event*, but the session is serving
                    # detached anyway — e.g. least-loaded placement put it
                    # on a replica that never fronted its cell. A migrating
                    # cluster corrects that instead of paying detach_factor
                    # for the session's whole life. (Retired replicas use
                    # the drain path instead.)
                    target = self._route_cell(ch.last_cell)
                    acted = True
                else:
                    continue
                if target == r:
                    ch.ack_handover(r)      # crossed back into home cell
                elif self.handover == "migrate":
                    self._migrate(eng, r, sess, target)
                else:                        # drop-and-replay
                    self._drop_replay(eng, r, sess, target)
        return acted

    def _migrate(self, eng, r: int, sess: Session, target: int):
        snap = extract_session(eng, sess.request.rid,
                               bits=self.snapshot_bits, source_replica=r)
        t = tx_seconds(snap.nbytes, self.backhaul_bps)
        sess.migrations.append({
            "kind": "migrate", "tick": eng.tick, "from_replica": r,
            "to_replica": target, "bytes": snap.nbytes,
            "bits": snap.bits, "transfer_s": round(t, 6)})
        sess.transfer_s += t
        self.migrations += 1
        self.migration_bytes += snap.nbytes
        self.migration_transfer_s += t
        if self._tel is not None:
            self._tel.inc("cluster.migrations")
            self._tel.inc("cluster.migration_bytes", snap.nbytes)
            self._tel.observe("cluster.migration_backhaul_s", t)
            self._tel.instant("migrate_send", cat="migration",
                              rid=snap.rid, from_replica=r,
                              to_replica=target, bytes=snap.nbytes,
                              transfer_s=round(t, 6))
        landed = inject_session(self.replicas[target], snap)
        if self._tel is not None:
            self._tel.instant("migrate_inject" if landed
                              else "migrate_park", cat="migration",
                              rid=snap.rid, to_replica=target)
        if landed:
            self._land(snap.rid, target, sess.request.channel)
        else:
            self._parked.append(("migrate", snap, target))

    def _drop_replay(self, eng, r: int, sess: Session, target: int):
        rid = sess.request.rid
        if sess.request.prompt.ndim != 1:
            raise NotImplementedError("drop-and-replay cannot reconstruct "
                                      "multi-codebook (audio) prompts from "
                                      "the emitted token stream")
        # drop ships no state: detach lands in-flight windows and frees
        # the slot without the device->host state copy a snapshot costs
        _, _, requirement, _ = detach_session(eng, rid)
        base = self._replay_base.get(rid)
        if base is not None:                # dropped before: fold the chain
            self._fold(base, sess)
        else:
            base = self._replay_base[rid] = sess
        # the replay prompt is the ORIGINAL prompt plus every token emitted
        # so far (across the whole drop chain) — greedy decode regenerates
        # the decoder state by prefilling the full context on the target
        budget = base.gen_budget or base.request.max_new_tokens
        remaining = budget - len(base.tokens)
        base.migrations.append({
            "kind": "replay", "tick": eng.tick, "from_replica": r,
            "to_replica": target, "bytes": 0, "bits": 0,
            "replayed_tokens": len(base.tokens)})
        self.replays += 1
        self.replayed_tokens += len(base.tokens)
        if self._tel is not None:
            self._tel.inc("cluster.replays")
            self._tel.instant("drop_replay", cat="migration", rid=rid,
                              from_replica=r, to_replica=target,
                              replayed_tokens=len(base.tokens))
        prompt = base.request.prompt
        req = Request(
            rid=rid,
            prompt=np.concatenate([prompt,
                                   np.asarray(base.tokens, prompt.dtype)]),
            max_new_tokens=max(remaining, 1),
            channel=base.request.channel,
            requirement=requirement or base.request.requirement,
            arrival_tick=self.replicas[target].tick,
            slo_ticks=base.request.slo_ticks)
        if self._try_submit(self.replicas[target], req):
            self._land(rid, target, req.channel)
        else:
            self._parked.append(("replay", req, target))

    def _land(self, rid: Hashable, target: int, ch) -> None:
        self._home[rid] = target
        if is_mobile(ch):
            ch.ack_handover(target)

    def _drain_parked(self) -> bool:
        still, drained = [], False
        for kind, item, target in self._parked:
            if target in self.retired:     # re-aim at a live replica
                target = self._least_loaded()
            if kind == "migrate":
                ok = inject_session(self.replicas[target], item)
                rid, ch = item.rid, item.session.request.channel
            else:
                ok = self._try_submit(self.replicas[target], item)
                rid, ch = item.rid, item.channel
            if ok:
                drained = True
                self._land(rid, target, ch)
            else:
                still.append((kind, item, target))
        self._parked = still
        return drained

    # -- collection -----------------------------------------------------------
    @staticmethod
    def _fold(base: Session, cont: Session) -> None:
        """Fold a continuation session's accounting into its base (the
        partial session a drop-and-replay superseded)."""
        base.tokens = base.tokens + cont.tokens
        base.wire_bytes += cont.wire_bytes
        base.prefill_wire_bytes += cont.prefill_wire_bytes
        base.transfer_s += cont.transfer_s
        base.deadline_misses += cont.deadline_misses
        base.escalations += cont.escalations
        base.migrations = base.migrations + cont.migrations
        base.mode_trace = base.mode_trace + cont.mode_trace
        base.finished_tick = cont.finished_tick
        for m, c in cont.mode_counts.items():
            base.mode_counts[m] = base.mode_counts.get(m, 0) + c

    @staticmethod
    def session_slo_late(sess: Session) -> bool:
        """True when the session finished past its request's session SLO
        (relative ticks: queue wait counts, replica clock skew doesn't)."""
        slo = sess.request.slo_ticks
        return (slo is not None and sess.finished_tick >= 0
                and sess.finished_tick - sess.request.arrival_tick > slo)

    def collect(self) -> List[Session]:
        """Sweep every replica's NEW finished sessions (per-replica offsets
        into the append-only ``eng.finished`` lists — O(new), not
        O(all-finished), per sweep) into the cluster-level list, folding
        drop-and-replay chains into one merged session per rid. Idempotent
        across calls; returns the cluster list."""
        while len(self._collect_offsets) < len(self.replicas):
            self._collect_offsets.append(0)
        for i, eng in enumerate(self.replicas):
            new = eng.finished[self._collect_offsets[i]:]
            self._collect_offsets[i] = len(eng.finished)
            for sess in new:
                rid = sess.request.rid
                base = self._replay_base.pop(rid, None)
                if base is not None:
                    self._fold(base, sess)
                    sess = base
                ch = sess.request.channel
                if is_mobile(ch):
                    sess.handover_ticks = list(ch.handover_ticks)
                self.finished.append(sess)
                self._obs_finished += 1
                if self.session_slo_late(sess):
                    self._obs_late += 1
        return self.finished

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_ticks: int = 100_000) -> List[Session]:
        """Drive the cluster until every submitted request completes (or
        the tick budget runs out); returns the merged finished sessions."""
        for r in requests or []:
            self.submit(r)
        for _ in range(max_ticks):
            if not self.step():
                break
        return self._drain_and_collect()

    def run_paced(self, requests: Sequence[Request],
                  max_ticks: int = 100_000) -> List[Session]:
        """Like :meth:`run`, but each request is submitted when its
        ``arrival_tick`` comes due against the live engines' clock — the
        fleet-scale driver. The admission gate then sees the backlog a
        real arrival would see, instead of judging every request at once
        against an empty cluster (or, worse, against thousands of
        scripted future arrivals)."""
        pending = sorted(requests, key=lambda r: r.arrival_tick)
        i = 0
        for _ in range(max_ticks):
            now = max((self.replicas[j].tick for j in self._live()),
                      default=0)
            while i < len(pending) and pending[i].arrival_tick <= now:
                self.submit(pending[i])
                i += 1
            progressed = self.step()
            if i >= len(pending) and not progressed:
                break
            if not progressed and i < len(pending):
                # idle gap before the next arrival: jump the live engines
                # forward instead of burning host steps one tick at a time
                nxt = pending[i].arrival_tick
                for j in self._live():
                    self.replicas[j].tick = max(self.replicas[j].tick, nxt)
        return self._drain_and_collect()

    def _drain_and_collect(self) -> List[Session]:
        for eng in self.replicas:
            eng._materialize_inflight()
            eng._sync_device_state()
        return self.collect()

    def warm(self, prompt: np.ndarray, gen: int = 2):
        """Trace every replica's compiled paths before a measured run.
        Single-device replicas share their jitted step objects (see
        ``batcher._compiled_steps``), so the first replica pays the XLA
        compiles and the rest just trace-hit; mesh replicas live on
        disjoint device subsets and each compile their own steps."""
        for eng in self.replicas:
            eng.warm(np.asarray(prompt), gen=gen)

    def close(self):
        """Shut every replica's pipeline worker down (see
        ``ContinuousBatchingEngine.close``)."""
        for eng in self.replicas:
            eng.close()

    def __enter__(self) -> "EdgeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- aggregate stats ------------------------------------------------------
    def stats(self) -> dict:
        self.collect()
        done = self.finished
        toks = sum(len(s.tokens) for s in done)
        # every admission's first token is a prefill argmax, and a
        # drop-and-replay chain re-admits once per replay — each fold
        # therefore contributes one more prefill-delivered (non-decode)
        # token that per-decode-token rates must not divide by
        dec = sum(max(len(s.tokens) - 1
                      - sum(1 for m in s.migrations
                            if m["kind"] == "replay"), 0)
                  for s in done)
        misses = sum(s.deadline_misses for s in done)
        late = sum(1 for s in done if self.session_slo_late(s))
        with_slo = sum(1 for s in done if s.request.slo_ticks is not None)
        latencies = []
        for s in done:
            ch = s.request.channel
            if is_mobile(ch):
                latencies.extend(ch.handover_latencies)
        per_replica = []
        over_capacity = queue_rejected = in_flight = 0
        for i, eng in enumerate(self.replicas):
            st = eng.stats()
            over_capacity += st["requests_over_capacity"]
            queue_rejected += st["requests_rejected"]
            in_flight += self._load(eng)
            per_replica.append({
                "replica": i,
                "retired": i in self.retired,
                "finished": st["requests_finished"],
                "active": len(eng.active),
                "queued": len(eng.queue),
                "free_slots": eng.pool.n_free,
                "decode_ticks": st["decode_ticks"],
                "decode_tokens": st["decode_tokens"],
                # decoded_slot_ticks counts work done ON this replica — a
                # migrated-in session's earlier tokens were decoded on its
                # previous home and must not inflate this occupancy
                "occupancy": round(
                    st["decoded_slot_ticks"]
                    / max(st["decode_ticks"] * eng.pool.n_slots, 1), 3),
            })
        out = {
            "n_replicas": len(self.replicas),
            "n_live": self.n_live,
            "placement": self.placement,
            "handover_policy": self.handover,
            "snapshot_bits": self.snapshot_bits,
            "requests_submitted": self.submitted,
            "requests_finished": len(done),
            "requests_rejected": self.rejected,
            "slo_rejected": self.slo_rejected,
            "slo_park_expired": self.slo_park_expired,
            "slo_parked_now": len(self._slo_parked),
            "generated_tokens": toks,
            "decode_tokens": dec,
            "wire_bytes": sum(s.wire_bytes for s in done),
            "decode_wire_bytes_per_token": (
                sum(s.wire_bytes - s.prefill_wire_bytes for s in done)
                / max(dec, 1)),
            "deadline_misses": misses,
            "deadline_miss_rate": misses / max(dec, 1),
            "session_slo_late": late,
            "sessions_with_slo": with_slo,
            # the A/B headline: of everything OFFERED, how much either
            # finished late or never ran at all (queue-wait-sensitive —
            # this is what admission + autoscaling move)
            "session_slo_miss_rate": (
                (late + self.slo_rejected + self.rejected + over_capacity)
                / max(self.submitted, 1)),
            "handovers": self.handovers,
            "handovers_ignored": self.handovers_ignored,
            "migrations": self.migrations,
            "migration_bytes": self.migration_bytes,
            "migration_transfer_s": round(self.migration_transfer_s, 6),
            "parked": len(self._parked),
            "replays": self.replays,
            "replayed_tokens": self.replayed_tokens,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "scale_events": list(self.scale_events),
            "mean_handover_latency_ticks": (
                float(np.mean(latencies)) if latencies else 0.0),
            #: submitted == every terminal outcome + work still in flight;
            #: the lifecycle fuzz asserts this balances exactly at drain
            #: (in_flight == 0). over_capacity counts engine-level
            #: admission rejections (prompt can't fit the cache).
            "conservation": {
                "submitted": self.submitted,
                "finished": len(done),
                "queue_rejected_router": self.rejected,
                "queue_rejected_engine": queue_rejected,
                "over_capacity": over_capacity,
                "slo_rejected": self.slo_rejected,
                "in_flight": in_flight,
                "slo_parked": len(self._slo_parked),
                "parked_moves": len(self._parked),
            },
            "per_replica": per_replica,
        }
        if self._tel is not None:
            self._tel.registry.ingest("cluster.stats", out)
        return out
