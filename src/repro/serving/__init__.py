"""Split-serving subsystem.

``engine.ServingEngine``      — synchronous single-batch engine (one static
                                batch, one mode per token for the whole
                                batch); kept for examples/smoke tests.
``batcher.ContinuousBatchingEngine`` — slot-pooled continuous batching with
                                per-request channels and per-slot bottleneck
                                modes inside one jitted decode step.
``controller.ModeController`` — per-slot, per-tick in-flight mode
                                re-selection (EWMA + dwell + deadline
                                escalation) for the continuous engine.
``session``                   — request/queue/session lifecycle records.

See docs/serving.md for the request lifecycle and slot-pool design, and
docs/modes.md for the mode bank and the stats field reference.
"""
from repro.serving.batcher import (ContinuousBatchingEngine,  # noqa: F401
                                   SlotPool)
from repro.serving.controller import (ControllerConfig,  # noqa: F401
                                      ModeController, SlotControl)
from repro.serving.engine import GenStats, ServingEngine  # noqa: F401
from repro.serving.session import (Request, RequestQueue,  # noqa: F401
                                   Session)
