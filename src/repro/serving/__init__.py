"""Split-serving subsystem.

``engine.ServingEngine``      — synchronous single-batch engine (one static
                                batch, one mode per token for the whole
                                batch); kept for examples/smoke tests.
``batcher.ContinuousBatchingEngine`` — slot-pooled continuous batching with
                                per-request channels and per-slot bottleneck
                                modes inside one jitted decode step; a
                                ``PagedPool`` (block-table paged KV arena
                                with page-budget admission) by default on
                                full-attention archs, dense ``SlotPool``
                                otherwise or with ``paged=False``.
``controller.ModeController`` — per-slot, per-tick in-flight mode
                                re-selection (EWMA + dwell + deadline
                                escalation) for the continuous engine.
``cluster.EdgeCluster``       — N engine replicas (one per simulated cell)
                                behind a router with pluggable placement
                                policies, mmWave-handover handling, and
                                elasticity: SLO-driven admission
                                (``fleet.SLOAdmission``) plus replica
                                autoscaling (``controller.Autoscaler``)
                                with migration-drained scale-down.
``fleet``                     — fleet-scale load generation (Poisson /
                                heavy-tail arrivals over ``FleetChannel``
                                lanes) and the predictive SLO admission
                                gate; see docs/fleet.md.
``migration``                 — live session migration: ``read_rows`` slot
                                snapshots (dense pools) or ``read_pages``
                                allocated-pages-only snapshots (paged
                                pools), optional wire quantization,
                                bit-exact injection on the target replica.
``session``                   — request/queue/session lifecycle records.
``telemetry``                 — observability: ``MetricsRegistry``
                                (counters/gauges/log-bucketed histograms
                                with p50/p90/p99, JSON + Prometheus
                                export), ``TraceRecorder`` (Perfetto-
                                loadable Chrome trace timeline with
                                per-replica lanes), the shared monotonic
                                serving clock, and the bench timing
                                helpers; see docs/observability.md.

See docs/serving.md for the request lifecycle and slot-pool design,
docs/cluster.md for the multi-replica router and handover semantics, and
docs/modes.md for the mode bank and the stats field reference.
"""
from repro.serving.batcher import (ContinuousBatchingEngine,  # noqa: F401
                                   PagedPool, SlotPool)
from repro.serving.cluster import (HANDOVER_POLICIES,  # noqa: F401
                                   PLACEMENTS, EdgeCluster,
                                   default_orchestrator)
from repro.serving.controller import (Autoscaler,  # noqa: F401
                                      AutoscalerConfig, ControllerConfig,
                                      ModeController, SlotControl)
from repro.serving.fleet import (FleetLoadConfig,  # noqa: F401
                                 SLOAdmission, SLOAdmissionConfig,
                                 arrival_ticks, fleet_requests)
from repro.serving.engine import GenStats, ServingEngine  # noqa: F401
from repro.serving.migration import (MigrationSnapshot,  # noqa: F401
                                     detach_session, extract_session,
                                     inject_session)
from repro.serving.session import (Request, RequestQueue,  # noqa: F401
                                   Session)
from repro.serving.telemetry import (MetricsRegistry,  # noqa: F401
                                     Telemetry, TraceRecorder,
                                     profile_capture)
