"""Live session migration between edge decoder replicas (mmWave handover).

When a UE crosses a cell boundary mid-generation, its split session's
decode state lives on the *old* cell's edge server. The choices are to keep
serving it over a degraded inter-cell path (stay-and-degrade), to restart
the prompt on the new cell (drop-and-replay), or — this module — to move
the live decode state: one gather extracts the slot's per-layer state,
position, and current token as a :class:`MigrationSnapshot`; the snapshot
is (optionally) quantized for the simulated backhaul wire and charged for
transfer bytes/latency; and :func:`inject_session` installs it into a free
slot on the target replica's pool such that the migrated session's
remaining tokens are **bit-identical** to an unmigrated run (raw snapshots
— the gather/scatter pair is exact; quantized snapshots trade fidelity for
backhaul bytes, and tests measure both).

Dense pools (``SlotPool``) snapshot via ``read_rows`` — the slot's full
``[L, 1, cache_len, ...]`` rows. Paged pools (``PagedPool``) ship only the
session's **allocated pages**: ``PagedPool.read_pages`` gathers the slot's
block-table entries into ``[L, n_pages_used, page_len, ...]`` blocks in
block-table (= logical row) order, so the wire never carries the unused
tail of the arena. Page *ids* don't cross the backhaul — the target
allocates its own pages from its own free list and ``write_pages`` rebuilds
the block table — only the page contents and their logical order do.
Injection on a paged target is admission-equivalent: it re-commits the
session's worst-case page budget and returns ``False`` (park-and-retry at
the cluster) when the target arena can't cover it, exactly like
``_collect_admits`` backpressure.

Orchestration state migrates with the session: the per-link capacity EWMA
(:class:`~repro.core.orchestrator.LinkState`), the session's
``AppRequirement``, and — under the adaptive policy — the controller's
``SlotControl`` (dwell timer, utilization EWMA) all detach from the source
and attach at the target, so mode selection after the handover continues
exactly where it left off instead of re-cold-starting.

Every migration is observable: an ``EdgeCluster`` built with
``telemetry=`` emits ``migrate_send`` / ``migrate_inject`` /
``migrate_park`` trace instants on the cluster lane (snapshot bytes,
simulated backhaul seconds) and folds the totals into
``cluster.migrations`` / ``cluster.migration_bytes`` counters plus the
``cluster.migration_backhaul_s`` histogram — see docs/observability.md.

Wire format (``MigrationSnapshot.wire``): the state pytree is flattened;
each floating leaf is either shipped raw (``bits=0``) or symmetric
row-wise quantized at ``bits`` (codes + one scale per row — the same
``core.quant`` wire rules as the boundary payload, including the ternary
``bits=1`` 2-bit packing); integer leaves (e.g. int8 KV caches) always
ship raw. ``nbytes`` is the accounted backhaul payload:
``quant.payload_bytes`` per leaf plus the position/token header.

The wire format is **mesh-invariant**: on a sharded engine (see
``docs/sharding.md``) the ``read_rows``/``read_pages`` gathers produce
fully host-addressable arrays whatever the source pool's ``('dp','mp')``
placement, ``_encode_state``'s per-leaf ``np.asarray`` serializes them
into the same host-side blocks a single-device snapshot produces, and
``_decode_state`` rebuilds uncommitted device arrays that inject into ANY
target mesh (the target's scatter re-places them under its own pool
sharding). Snapshots therefore carry no device topology, replicas on
different device subsets interoperate, and raw snapshots stay bit-exact
across the migration — same-shape meshes compile the same step, so the
resumed stream is the unmigrated stream.

The engine-facing functions are deliberately free functions over
``ContinuousBatchingEngine`` internals rather than engine methods — the
cluster router (``serving/cluster.py``) is their only intended caller, and
keeping them here keeps the engine unaware of multi-replica topology.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.orchestrator import AppRequirement, LinkState
from repro.serving.batcher import (ContinuousBatchingEngine, _admit_meta,
                                   _admit_scatter, _slot_axis)
from repro.serving.controller import SlotControl
from repro.serving.session import Session

#: accounted wire overhead per snapshot beyond the state leaves: position
#: and rid/routing metadata (the current token is charged separately at
#: 4 bytes per value — audio sessions carry one per codebook)
SNAPSHOT_HEADER_BYTES = 16


@dataclass
class MigrationSnapshot:
    """One live session's complete decode state, off-pool and serializable.

    ``wire`` holds one entry per state leaf: ``("raw", array)`` or
    ``("q", codes, scales, dtype_str)``; ``treedef`` restores the pytree.
    """
    session: Session
    position: int
    cur_token: np.ndarray              # the token the next decode step eats
    wire: List[tuple]
    treedef: Any
    bits: int                          # 0 = raw (bit-exact) snapshot
    nbytes: int                        # accounted backhaul payload
    link: Optional[LinkState] = None
    requirement: Optional[AppRequirement] = None
    control: Optional[SlotControl] = None
    source_replica: int = -1
    #: True when ``wire`` holds allocated page blocks (source pool was a
    #: ``PagedPool``) rather than dense slot rows; ``page_len`` then records
    #: the source page geometry so the target can reject a mismatch
    paged: bool = False
    page_len: int = 0

    @property
    def rid(self) -> Hashable:
        return self.session.request.rid


def _encode_state(state, bits: int) -> Tuple[List[tuple], Any, int]:
    """Flatten a single-slot state pytree into wire entries + byte count.

    Floating leaves quantize at ``bits`` (row-wise over the last dim, the
    same symmetric scheme as the boundary payload); integer leaves (packed
    KV codes, counters) ship raw — re-quantizing codes would corrupt them.
    """
    leaves, treedef = jax.tree.flatten(state)
    wire: List[tuple] = []
    nbytes = SNAPSHOT_HEADER_BYTES
    for leaf in leaves:
        arr = np.asarray(leaf)              # device -> host: the wire copy
        if bits and arr.ndim and jnp.issubdtype(leaf.dtype, jnp.floating):
            codes, scales = quant.quantize(jnp.asarray(arr), bits)
            wire.append(("q", np.asarray(codes), np.asarray(scales),
                         str(arr.dtype)))
            nbytes += quant.payload_bytes(arr.shape, bits)
        else:
            wire.append(("raw", arr))
            nbytes += quant.payload_bytes(arr.shape, 0,
                                          dtype_bytes=arr.dtype.itemsize)
    return wire, treedef, nbytes


def _decode_state(snap: MigrationSnapshot):
    """Rebuild the batched (batch=1 on the slot axis) state pytree the
    target pool's ``write_rows`` scatter expects."""
    leaves = []
    for entry in snap.wire:
        if entry[0] == "raw":
            leaves.append(jnp.asarray(entry[1]))
        else:
            _, codes, scales, dtype = entry
            x = quant.dequantize(jnp.asarray(codes), jnp.asarray(scales),
                                 snap.bits)
            leaves.append(x.astype(dtype))
    return jax.tree.unflatten(snap.treedef, leaves)


def _land_and_find(eng: ContinuousBatchingEngine, rid: Hashable) -> int:
    """Locate ``rid``'s slot and land the lagged pipeline: token values
    for every dispatched tick must be on the session, and the donated
    pool buffers re-homed, before the slot is read or released. Raises
    ``KeyError`` if ``rid`` is not live on this engine (it may have
    finished already — callers must check before acting on a handover)."""
    slot = next((s for s, sess in eng.active.items()
                 if sess.request.rid == rid), None)
    if slot is None:
        raise KeyError(f"request {rid!r} is not live on this engine")
    eng._materialize_inflight()
    eng._sync_device_state()
    return slot


def _detach(eng: ContinuousBatchingEngine, slot: int, rid: Hashable
            ) -> Tuple[Session, Optional[LinkState],
                       Optional[AppRequirement], Optional[SlotControl]]:
    """Detach the session's orchestrator/controller state and free its
    slot (the pipeline must already be landed — see ``_land_and_find``)."""
    sess = eng.active[slot]
    link = requirement = control = None
    if eng.controller is not None:
        control = eng.controller.detach(rid)
    if eng.orch is not None:
        link, requirement = eng.orch.detach(rid)
    del eng.active[slot]
    eng.pool.release(slot)
    return sess, link, requirement, control


def detach_session(eng: ContinuousBatchingEngine, rid: Hashable
                   ) -> Tuple[Session, Optional[LinkState],
                              Optional[AppRequirement],
                              Optional[SlotControl]]:
    """Remove a live session from ``eng`` WITHOUT snapshotting its decode
    state. This is the whole of what drop-and-replay needs — the state is
    abandoned, so no device->host copy happens."""
    return _detach(eng, _land_and_find(eng, rid), rid)


def extract_session(eng: ContinuousBatchingEngine, rid: Hashable, *,
                    bits: int = 0,
                    source_replica: int = -1) -> MigrationSnapshot:
    """Pull a live session off ``eng`` WITH its decode state: gather the
    slot's state (``SlotPool.read_rows`` dense rows, or the allocated
    page blocks via ``PagedPool.read_pages``), encode them for the
    backhaul wire, then detach. The engine keeps running — the extracted
    session simply stops decoding here.

    ``bits=0`` snapshots are bit-exact; ``bits>0`` quantizes floating
    leaves for the backhaul wire (lossy). Raises ``KeyError`` if ``rid``
    is not live on this engine.
    """
    slot = _land_and_find(eng, rid)
    paged = bool(getattr(eng.pool, "paged", False))
    if paged:
        state = eng.pool.read_pages(slot)
    else:
        state = eng.pool.read_rows([slot])
    wire, treedef, nbytes = _encode_state(state, bits)
    tok = np.asarray(eng.cur_tokens[slot], np.int32)
    nbytes += int(tok.size) * 4
    sess, link, requirement, control = _detach(eng, slot, rid)
    return MigrationSnapshot(session=sess, position=int(sess.pos),
                             cur_token=tok, wire=wire, treedef=treedef,
                             bits=bits, nbytes=nbytes, link=link,
                             requirement=requirement, control=control,
                             source_replica=source_replica, paged=paged,
                             page_len=eng.pool.page_len if paged else 0)


def inject_session(eng: ContinuousBatchingEngine,
                   snap: MigrationSnapshot) -> bool:
    """Install a snapshot into a free slot on ``eng``. Returns ``False``
    (and changes nothing) when the pool is full — or, on a paged target,
    when the arena cannot cover the session's worst-case remaining page
    budget — the caller queues the snapshot and retries after a
    retirement frees slots/pages.

    The scatter is the admission path's own (``write_rows``/``write_pages``
    on the host loop, the donated ``_admit_scatter`` or a synced
    ``write_pages`` + ``_admit_meta`` on the device loop), so an injected
    raw snapshot is indistinguishable from having decoded every prior
    token on this engine — the remaining stream is bit-identical.
    No channel tick is consumed: injection is not an admission, and the
    UE's link realization must continue unbroken across the handover.
    """
    target_paged = bool(getattr(eng.pool, "paged", False))
    if snap.paged != target_paged:
        raise ValueError(
            f"snapshot pool kind ({'paged' if snap.paged else 'dense'}) "
            f"does not match target pool "
            f"({'paged' if target_paged else 'dense'}) — cluster replicas "
            "must share their pool configuration")
    if snap.paged and snap.page_len != eng.pool.page_len:
        raise ValueError(
            f"snapshot page_len {snap.page_len} does not match target "
            f"page_len {eng.pool.page_len}")
    if eng.pool.n_free == 0:
        return False
    sess, rid = snap.session, snap.rid
    if snap.paged:
        # admission-equivalent page budgeting: the migrated session must be
        # able to finish here, so re-commit its worst-case total pages
        # (prompt + clipped budget rows; the last generated token writes no
        # row) before touching the free list — False parks the snapshot at
        # the cluster until retirements free enough pages
        plen = eng.pool.page_len
        budget = sess.gen_budget or sess.request.max_new_tokens
        worst = -(-(sess.request.prompt_len + budget - 1) // plen)
        state = _decode_state(snap)
        nbu = jax.tree.leaves(state)[0].shape[1]
        worst = max(worst, nbu)
        if worst > eng.pool.pages_available:
            return False
        slot = eng.pool.acquire()
        eng.pool.commit_pages(slot, worst)
        if not eng.host_loop:
            # the resident arena may be donated to an in-flight window —
            # land it before scattering (same rule as device-loop admission)
            eng._sync_device_state()
        eng.pool.write_pages(slot, state, snap.position)
        if eng.host_loop:
            eng.cur_tokens[slot] = snap.cur_token
        else:
            eng._positions, eng.cur_tokens = _admit_meta(
                eng._positions, eng.cur_tokens,
                jnp.asarray([slot], jnp.int32),
                jnp.asarray([snap.position], jnp.int32),
                jnp.asarray(snap.cur_token)[None])
    else:
        state = _decode_state(snap)
        slot = eng.pool.acquire()
        if eng.host_loop:
            eng.pool.write_rows(state, [slot], [snap.position])
            eng.cur_tokens[slot] = snap.cur_token
        else:
            # the resident pool may be donated to an in-flight window —
            # land it before scattering (same rule as device-loop admission)
            eng._sync_device_state()
            eng.pool.states, eng._positions, eng.cur_tokens = _admit_scatter(
                eng.pool.states, eng._positions, eng.cur_tokens, state,
                jnp.asarray([slot], jnp.int32),
                jnp.asarray([snap.position], jnp.int32),
                _slot_axis(eng.cfg), jnp.asarray(snap.cur_token)[None])
            eng.pool.positions[slot] = snap.position
    sess.slot = slot
    eng.active[slot] = sess
    if eng.orch is not None:
        # re-attach the migrated link state (capacity EWMA, mode, tick
        # count) so post-handover mode selection continues where it left
        # off; a fresh register() would re-cold-start the EWMA
        eng.orch.attach(rid, snap.link, snap.requirement)
        eng.orch.register(rid, snap.requirement)   # no-op if attached
    if eng.controller is not None and snap.control is not None:
        eng.controller.attach(rid, snap.control)
    return True
