"""Synchronous batched serving engine with split-mode support.

This is the *static-batch* engine: one batch of aligned sequences, one
orchestrator-chosen mode per token applied to the whole batch. It remains
the simplest runnable loop (examples, smoke tests, the dry-run's
``serve_step`` lowering). Production-shaped serving — request queue,
slot-pooled state recycling, per-request channels and per-slot bottleneck
modes in one jitted step — lives in ``repro.serving.batcher``
(``ContinuousBatchingEngine``), with the request lifecycle records in
``repro.serving.session``.

``prefill`` runs the whole prompt in one batched full-sequence forward
(``T.prefill`` — populates attention caches and recurrent states for every
architecture family); a mid-stream continuation (``pos > 0``) falls back to
the exact per-token decode loop. ``decode_tokens`` then decodes with the
orchestrator-selected bottleneck mode, accounting the bytes that cross the
UE->edge boundary per token.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import split as SP
from repro.core.orchestrator import Orchestrator
from repro.models import sharding
from repro.models import transformer as T
from repro.serving.telemetry import Telemetry, now as _now


def make_serve_step(cfg: ModelConfig, *, mode: Optional[int] = None):
    """serve_step(params, token, states, cur_pos) -> (logits, new_states).

    mode None: monolithic model; mode int: split model (bottleneck mode m
    crossing the simulated link)."""
    if mode is None:
        @jax.jit
        def step(params, token, states, cur_pos):
            return T.decode_step(params, token, states, cur_pos, cfg)
        return step

    @jax.jit
    def step(params, token, states, cur_pos):
        logits, new_states, _ = SP.split_decode_step(
            params, token, states, cur_pos, cfg, mode=mode)
        return logits, new_states
    return step


@dataclass
class GenStats:
    tokens: int = 0
    wire_bytes: int = 0
    mode_counts: Dict[int, int] = field(default_factory=dict)


class ServingEngine:
    """``mesh``: optional serving ``('dp','mp')`` mesh — params ride
    TP-over-``mp``, the batch/state rides slot-over-``dp`` (divisibility
    permitting); this static-batch engine makes no bit-identity claim
    (that pinning lives with ``ContinuousBatchingEngine`` in
    ``tests/test_sharded_serving.py``)."""

    def __init__(self, params, cfg: ModelConfig, *, cache_len: int = 512,
                 batch: int = 1,
                 orchestrator: Optional[Orchestrator] = None,
                 mesh=None, telemetry: Optional[Telemetry] = None):
        self.mesh = mesh
        self._tel = telemetry
        self.params = sharding.shard_params(params, mesh)
        self.cfg = cfg
        self.cache_len = cache_len
        self.batch = batch
        self.orch = orchestrator
        self.states = T.init_decode_state(cfg, batch, cache_len)
        if mesh is not None:
            self.states = sharding.shard_pool(
                self.states, mesh, slot_axis=1 if cfg.homogeneous else 0)
        self.pos = 0
        self._steps: Dict[Optional[int], Callable] = {}
        self._tok_steps: Dict[Optional[int], Callable] = {}
        self._prefill_fn: Optional[Callable] = None
        self.stats = GenStats()

    def _step(self, mode: Optional[int]):
        if mode not in self._steps:
            self._steps[mode] = make_serve_step(self.cfg, mode=mode)
        return self._steps[mode]

    def _tok_step(self, mode: Optional[int]):
        """Jitted decode step ending in the fused decode tail
        (``return_tokens=True`` -> ``ops.decode_tail_op``): norm, LM head
        and argmax run as one kernel on TPU (expression-identical reference
        chain on CPU), so only int32 tokens ever cross the host boundary
        (and the per-mode split step is actually compiled instead of
        retraced eagerly every token)."""
        if mode not in self._tok_steps:
            cfg = self.cfg

            if mode is None:
                @jax.jit
                def step(params, tok, states, pos):
                    return T.decode_step(params, tok, states, pos, cfg,
                                         return_tokens=True)
            else:
                @jax.jit
                def step(params, tok, states, pos):
                    nxt, st, _ = SP.split_decode_step(
                        params, tok, states, pos, cfg, mode=mode,
                        return_tokens=True)
                    return nxt, st
            self._tok_steps[mode] = step
        return self._tok_steps[mode]

    def reset(self):
        self.states = T.init_decode_state(self.cfg, self.batch,
                                          self.cache_len)
        if self.mesh is not None:
            self.states = sharding.shard_pool(
                self.states, self.mesh,
                slot_axis=1 if self.cfg.homogeneous else 0)
        self.pos = 0
        self.stats = GenStats()

    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens: [B, S] (or [B, K, S] audio). Returns last-position logits.

        From a fresh state this is ONE batched full-sequence forward; a
        mid-stream continuation (``pos > 0``) keeps the exact per-token
        decode path."""
        S = tokens.shape[-1]
        # the continuous engine's admission rule does the counted version
        T.check_cache_capacity(self.cfg, self.pos, S, self.cache_len,
                               what="prompt")
        if self.pos == 0:
            if self._prefill_fn is None:
                cfg = self.cfg
                self._prefill_fn = jax.jit(
                    lambda p, t, s: T.prefill(p, t, cfg, s))
            t0 = _now()
            logits, self.states = self._prefill_fn(
                self.params, jnp.asarray(tokens), self.states)
            if self._tel is not None:
                jax.block_until_ready(logits)
                self._tel.observe("engine_sync.prefill_s", _now() - t0)
            self.pos = S
            return logits
        step = self._step(None)
        logits = None
        for t in range(S):      # tiny continuations in CPU examples
            tok = tokens[..., t:t + 1]
            logits, self.states = step(self.params, tok, self.states,
                                       jnp.int32(self.pos))
            self.pos += 1
        return logits

    def decode_tokens(self, first_token: jnp.ndarray, n_steps: int, *,
                      greedy: bool = True, capacity_bps_fn=None) -> np.ndarray:
        """Generate ``n_steps`` tokens; per-token the orchestrator picks the
        transmit mode from the live channel capacity."""
        T.check_cache_capacity(self.cfg, self.pos, n_steps, self.cache_len,
                               what="decode")
        from repro.core import bottleneck
        tok = first_token
        out: List[np.ndarray] = []
        t0 = _now()
        for _ in range(n_steps):
            mode: Optional[int] = None
            if self.orch is not None:
                if capacity_bps_fn is not None:
                    self.orch.observe_capacity(capacity_bps_fn())
                mode = self.orch.choose_mode()
            # argmax is fused into the jitted step (only int32 tokens cross
            # the host boundary); wire bytes are host-side static accounting
            nxt, self.states = self._tok_step(mode)(
                self.params, tok, self.states, jnp.int32(self.pos))
            pb = (bottleneck.mode_payload_bytes(
                self.cfg, int(np.shape(tok)[0]), 1, mode)
                if mode is not None else 0)
            self.pos += 1
            tok = nxt
            out.append(np.asarray(nxt))
            self.stats.tokens += int(nxt.size)
            self.stats.wire_bytes += int(pb)
            key = mode if mode is not None else -1
            self.stats.mode_counts[key] = \
                self.stats.mode_counts.get(key, 0) + 1
            if self._tel is not None:
                t1 = _now()
                self._tel.observe("engine_sync.intertoken_s", t1 - t0)
                self._tel.inc("engine_sync.decode_wire_bytes", int(pb))
                self._tel.inc("engine_sync.decode_tokens", int(nxt.size))
                t0 = t1
        return np.concatenate(out, axis=-1)
