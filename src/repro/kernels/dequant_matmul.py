"""Pallas TPU kernel: decoder-side fused dequantize + up-projection
(layer B receive path): y = (codes * scales) @ w_up.

The int8 codes arrive from the wire; dequantization happens in VMEM as the
operand is fed to the MXU, so no f32 copy of the code matrix is ever
materialized in HBM. Grid: (M/BM, D/BD); the bottleneck width N is small
(<= 2048) and rides whole in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, scales_ref, w_ref, out_ref, *, out_dtype):
    z = codes_ref[...].astype(jnp.float32) * scales_ref[...]
    y = jnp.dot(z, w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    out_ref[...] = y.astype(out_dtype)


def dequant_matmul(codes, scales, w, *, out_dtype=jnp.bfloat16,
                   block_m: int = 128, block_d: int = 512,
                   interpret: bool = False):
    """codes: int8 [M, N], scales: f32 [M, 1], w: [N, D] -> [M, D]."""
    M, N = codes.shape
    N2, D = w.shape
    assert N == N2, (codes.shape, w.shape)
    assert M % block_m == 0 and D % block_d == 0, (M, D, block_m, block_d)

    grid = (M // block_m, D // block_d)
    return pl.pallas_call(
        functools.partial(_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, N), lambda m, d: (m, 0)),
            pl.BlockSpec((block_m, 1), lambda m, d: (m, 0)),
            pl.BlockSpec((N, block_d), lambda m, d: (0, d)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda m, d: (m, d)),
        out_shape=jax.ShapeDtypeStruct((M, D), out_dtype),
        interpret=interpret,
    )(codes, scales, w)
