"""Pallas TPU kernel: blocked RG-LRU linear recurrence
h_t = a_t * h_{t-1} + b_t  (recurrentgemma's temporal-mixing hot loop).

TPU adaptation: instead of the GPU pattern (one thread-block per channel
slice scanning global memory), time is tiled into VMEM-resident blocks of
``block_s`` steps; the carry h lives in a VMEM scratch that persists across
sequential grid steps, so HBM traffic is exactly one read of (a, b) and one
write of h — the memory-bound roofline optimum for a recurrence.

Grid: (B * D/BD, S/BS) with the time dimension innermost (TPU grid order is
sequential over the last axis, which is what makes the scratch carry legal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, block_s: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0]                       # [BS, BD]
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry_ref[0])
    carry_ref[0, :] = h


def rglru_scan(a, b, *, block_s: int = 256, block_d: int = 512,
               interpret: bool = False):
    """a, b: [B, S, D] f32 -> h: [B, S, D] f32."""
    B, S, D = a.shape
    assert a.shape == b.shape
    assert S % block_s == 0 and D % block_d == 0, (S, D, block_s, block_d)
    n_d = D // block_d

    grid = (B * n_d, S // block_s)
    spec = pl.BlockSpec((1, block_s, block_d),
                        lambda i, s: (i // n_d, s, i % n_d))
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b)
