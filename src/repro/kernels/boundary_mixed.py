"""Pallas TPU kernel: fused mixed-mode bottleneck boundary — the whole
UE->wire->edge crossing (layer A, quantize -> dequantize, layer B) for a
continuous batch where every row rides its own orchestrator-chosen mode.

This is the operation the paper inserts on *every* query, so its cost — not
just its wire bytes — governs the complexity/relevance tradeoff. The jnp
path (``kernels.ref.boundary_mixed_ref``) pads every row to the widest mode
and gathers a per-row weight tensor; here the caller (``kernels.ops``)
pre-groups rows into mode-uniform blocks so that, per block:

* the block's head weights are gathered ONCE via scalar-prefetch index maps
  (no [B, d, wmax] materialized gather, no cross-mode branching);
* the down-projection runs chunk-by-chunk over the head's TRUE width —
  ``ceil(width / block_w)`` grid steps instead of ``wmax / block_w`` — so
  narrow-mode rows do narrow-mode work instead of wmax-padded work;
* the f32 activation, the quantization scale, and the dequantized code all
  live in VMEM scratch; nothing but the final decoder-side activation (in
  the model dtype) is ever written back to HBM;
* raw-mode rows (mode 0) skip every matmul and pass the boundary through.

Grid: (row_blocks, wmax / block_w) — the width-chunk dimension is innermost
so each block's z accumulator completes before its quantize + up-projection
epilogue. Scalar-prefetch tables (head id, chunk count, true width, bit
width — one entry per row block) drive both the index maps and the in-kernel
``pl.when`` guards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(hid_ref, nch_ref, wid_ref, bit_ref, x_ref, down_ref, up_ref,
            norm_ref, out_ref, h_scr, z_scr, *, n_w: int, block_w: int,
            dtype):
    g = pl.program_id(0)
    w = pl.program_id(1)
    nch = nch_ref[g]                    # chunks of this block's true width
    width = wid_ref[g]                  # true bottleneck width (0 = raw)
    bits = bit_ref[g]                   # wire bit width (0 = unquantized)

    @pl.when((w == 0) & (nch > 0))
    def _prep():
        # layer A prologue: rmsnorm in f32, cast back to the model dtype —
        # shared by every width chunk of this row block
        z_scr[...] = jnp.zeros_like(z_scr)
        xf = x_ref[...].astype(jnp.float32)
        h = xf * jax.lax.rsqrt(
            jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        h = h * norm_ref[0].astype(jnp.float32)
        h_scr[...] = h.astype(h_scr.dtype)

    @pl.when(w < nch)
    def _down_chunk():
        # one MXU tile of the down-projection; chunks past ``nch`` are
        # skipped entirely (their index maps clamp to the last real chunk,
        # so no extra weight traffic either). f32 accumulation + explicit
        # round to the model dtype == XLA's own bf16-GEMM semantics, and is
        # reproducible between compiled, interpret, and oracle paths.
        z = jnp.dot(h_scr[...], down_ref[0],
                    preferred_element_type=jnp.float32
                    ).astype(h_scr.dtype).astype(jnp.float32)
        lane = w * block_w + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_w), 1)
        z_scr[:, pl.ds(pl.multiple_of(w * block_w, block_w), block_w)] = \
            jnp.where(lane < width, z, 0.0)

    @pl.when(w == n_w - 1)
    def _epilogue():
        @pl.when(nch == 0)
        def _raw():                      # mode 0: transmit the raw code z
            out_ref[...] = x_ref[...]

        @pl.when(nch > 0)
        def _wire_and_up():
            # wire round-trip in VMEM: row-wise symmetric quantization at
            # this block's bit width (same floor-at-1 as quant.qmax —
            # bits=1 is the ternary code), then layer B
            z = z_scr[...]
            qm = jnp.maximum(
                jnp.left_shift(1, jnp.maximum(bits, 1) - 1) - 1, 1
            ).astype(jnp.float32)
            absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
            scale = jnp.maximum(absmax, 1e-8) / qm
            codes = jnp.clip(jnp.round(z / scale), -qm, qm)
            wired = jnp.where(bits == 0, z, codes * scale)
            y = jnp.dot(wired.astype(dtype), up_ref[0],
                        preferred_element_type=jnp.float32)
            out_ref[...] = y.astype(out_ref.dtype)


def _tail_kernel(hid_ref, x_ref, heads_ref, scale_ref, bias_ref, out_ref,
                 h_scr, best_scr, idx_scr, *, n_v: int, block_v: int,
                 norm_kind: str):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _prep():
        # final-norm prologue in f32, rounded through the model dtype —
        # exactly what norm_apply hands lm_logits — shared by every vocab
        # chunk of this row block; running lane-max/lane-argmax reset
        xf = x_ref[...].astype(jnp.float32)
        if norm_kind == "rmsnorm":
            y = xf * jax.lax.rsqrt(
                jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        else:                            # layernorm
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
            y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * scale_ref[0].astype(jnp.float32)
        y = y + bias_ref[0].astype(jnp.float32)
        h_scr[...] = y.astype(x_ref.dtype).astype(jnp.float32)
        best_scr[...] = jnp.full_like(best_scr, -jnp.inf)
        idx_scr[...] = jnp.zeros_like(idx_scr)

    # one MXU tile of this block's head: the [block_r, block_v] logit chunk
    # lives only in registers/VMEM — argmax folds it into the running
    # per-lane max immediately, so the [B, V] f32 logits never touch HBM.
    # Strict > keeps the EARLIEST chunk on ties, matching jnp.argmax.
    logits = jnp.dot(h_scr[...], heads_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    lane = v * block_v + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    better = logits > best_scr[...]
    best_scr[...] = jnp.where(better, logits, best_scr[...])
    idx_scr[...] = jnp.where(better, lane, idx_scr[...])

    @pl.when(v == n_v - 1)
    def _argmax():
        # cross-lane reduce: global max, then the smallest index holding it
        # (each lane's stored index is already its earliest occurrence)
        best = best_scr[...]
        m = jnp.max(best, axis=-1, keepdims=True)
        tok = jnp.min(jnp.where(best == m, idx_scr[...],
                                jnp.int32(2 ** 31 - 1)),
                      axis=-1, keepdims=True)
        out_ref[...] = jnp.broadcast_to(tok, out_ref.shape).astype(jnp.int32)


def decode_tail_grouped(xp, heads, norm_scale, norm_bias, hid_g, *,
                        block_r: int, block_v: int = 512,
                        norm_kind: str = "rmsnorm",
                        interpret: bool = False):
    """Fused decode tail: final norm -> per-block LM-head gather -> streaming
    argmax -> int32 token, one ``pallas_call`` (the serving tick's second and
    last kernel — see ``ops.decode_tail_op``).

    ``xp``: [P, d] decoder-output rows already permuted so each
    ``block_r``-row block is head-uniform (``ops.head_layout``); ``heads``:
    [H, d, V] stacked LM heads; ``norm_scale``/``norm_bias``: [d] final-norm
    params (bias zeros for rmsnorm); ``hid_g``: [P/block_r] int32 per-block
    head row. Returns [P, 128] int32 (the token broadcast across lanes;
    callers read column 0).

    P % block_r == 0, d % 128 == 0, V % block_v == 0 required (ops.py falls
    back to the jnp reference otherwise).
    """
    P, d = xp.shape
    H, d2, V = heads.shape
    assert d == d2, (xp.shape, heads.shape)
    assert P % block_r == 0 and d % 128 == 0 and V % block_v == 0, \
        (P, d, V, block_r, block_v)
    G = P // block_r
    n_v = V // block_v
    assert hid_g.shape == (G,), (hid_g.shape, G)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, n_v),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda g, v, *s: (g, 0)),
            pl.BlockSpec((1, d, block_v),
                         lambda g, v, hid: (hid[g], 0, v)),
            pl.BlockSpec((1, d), lambda g, v, *s: (0, 0)),
            pl.BlockSpec((1, d), lambda g, v, *s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 128), lambda g, v, *s: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_r, d), jnp.float32),        # normed activation
            pltpu.VMEM((block_r, block_v), jnp.float32),  # running lane max
            pltpu.VMEM((block_r, block_v), jnp.int32),    # running lane argmax
        ],
    )
    return pl.pallas_call(
        functools.partial(_tail_kernel, n_v=n_v, block_v=block_v,
                          norm_kind=norm_kind),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, 128), jnp.int32),
        interpret=interpret,
    )(hid_g, xp, heads, norm_scale.reshape(1, d), norm_bias.reshape(1, d))


def boundary_mixed_grouped(xp, down_w, up_w, norm_scale, hid_g, nchunk_g,
                           width_g, bits_g, *, block_r: int,
                           block_w: int = 128, dtype=jnp.bfloat16,
                           interpret: bool = False):
    """Mode-grouped fused boundary. ``xp``: [P, d] rows already permuted so
    each ``block_r``-row block is mode-uniform (see ``ops._group_rows``);
    ``down_w``/``up_w``/``norm_scale``: the stacked bank ([M, d, wmax] /
    [M, wmax, d] / [M, d]); per-block int32 tables: ``hid_g`` head row,
    ``nchunk_g`` width chunks (0 = raw passthrough), ``width_g`` true
    width, ``bits_g`` wire bits. Returns [P, d] decoder-side activations.

    P % block_r == 0, d % 128 == 0, wmax % block_w == 0 required
    (ops.py falls back to the jnp reference otherwise).
    """
    P, d = xp.shape
    M, d2, wmax = down_w.shape
    assert d == d2, (xp.shape, down_w.shape)
    assert P % block_r == 0 and d % 128 == 0 and wmax % block_w == 0, \
        (P, d, wmax, block_r, block_w)
    G = P // block_r
    n_w = wmax // block_w
    assert hid_g.shape == (G,), (hid_g.shape, G)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G, n_w),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda g, w, *s: (g, 0)),
            pl.BlockSpec(
                (1, d, block_w),
                lambda g, w, hid, nch, wd, bt: (
                    hid[g], 0, jnp.minimum(w, jnp.maximum(nch[g] - 1, 0)))),
            pl.BlockSpec((1, wmax, d), lambda g, w, hid, *s: (hid[g], 0, 0)),
            pl.BlockSpec((1, d), lambda g, w, hid, *s: (hid[g], 0)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda g, w, *s: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_r, d), xp.dtype),          # normed activation
            pltpu.VMEM((block_r, wmax), jnp.float32),    # z accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_w=n_w, block_w=block_w, dtype=dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, d), xp.dtype),
        interpret=interpret,
    )(hid_g, nchunk_g, width_g, bits_g, xp, down_w, up_w, norm_scale)
