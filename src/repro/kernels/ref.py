"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bottleneck_quant_ref(x, w, bits: int = 8):
    """Fused down-projection + row-wise symmetric int8 quantization.

    x: [M, K] bf16/f32, w: [K, N] -> (codes int8 [M, N], scales f32 [M, 1]).
    """
    z = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    qm = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qm
    codes = jnp.clip(jnp.round(z / scale), -qm, qm).astype(jnp.int8)
    return codes, scale


def dequant_matmul_ref(codes, scales, w, out_dtype=jnp.bfloat16):
    """Decoder-side fused dequantize + up-projection.

    codes: int8 [M, N], scales: f32 [M, 1], w: [N, D] -> [M, D].
    """
    z = codes.astype(jnp.float32) * scales
    return (z @ w.astype(jnp.float32)).astype(out_dtype)


def rglru_scan_ref(a, b):
    """Gated linear recurrence h_t = a_t * h_{t-1} + b_t, h_0 = b_1 term.

    a, b: [B, S, D] f32 -> h: [B, S, D] f32.
    """
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    B, S, D = a.shape
    h0 = jnp.zeros((B, D), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
