"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bottleneck_quant_ref(x, w, bits: int = 8):
    """Fused down-projection + row-wise symmetric int8 quantization.

    x: [M, K] bf16/f32, w: [K, N] -> (codes int8 [M, N], scales f32 [M, 1]).
    """
    z = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    # same floor as quant.qmax: bits=1 is the ternary {-1, 0, 1} code, never
    # a zero qmax (which made the scale infinite and the roundtrip NaN)
    qm = max((1 << (bits - 1)) - 1, 1)
    absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qm
    codes = jnp.clip(jnp.round(z / scale), -qm, qm).astype(jnp.int8)
    return codes, scale


def boundary_mixed_ref(stacked, x, mode_idx, *, dtype=jnp.bfloat16):
    """Per-row mixed-mode bottleneck boundary (the fused-kernel oracle).

    x: [B, S, d]; mode_idx: [B] int32 in [0, M] where 0 transmits the raw
    code z and m >= 1 routes row b through head m-1 of ``stacked`` (see
    ``bottleneck.bank_stack``): rmsnorm + down-projection (layer A), the
    quantize -> dequantize wire round-trip at that row's bit width, and the
    up-projection adapter (layer B). Returns [B, S, d] in ``x.dtype``.
    """
    eps = 1e-6
    hid = jnp.clip(mode_idx - 1, 0, stacked["width"].shape[0] - 1)  # [B]
    # layer A: per-row rmsnorm + down-projection
    xf = x.astype(jnp.float32)
    h = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    h = h * stacked["norm_scale"][hid][:, None, :].astype(jnp.float32)
    z = jnp.einsum("bsd,bdw->bsw", h.astype(x.dtype),
                   stacked["down_w"][hid]).astype(jnp.float32)
    lane = jnp.arange(z.shape[-1])
    z = jnp.where(lane[None, None, :] < stacked["width"][hid][:, None, None],
                  z, 0.0)
    # wire: row-wise symmetric quantization with per-row bit width
    # (bits == 0 modes ship the code unquantized, so the roundtrip is skipped)
    bits_h = stacked["bits"][hid][:, None, None]
    # same floor-at-1 as quant.qmax: bits=1 is the ternary code, never a
    # zero qmax (the two wire paths are pinned to agree by tests)
    qm = jnp.maximum(
        jnp.left_shift(1, jnp.maximum(bits_h, 1) - 1) - 1, 1
    ).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qm
    codes = jnp.clip(jnp.round(z / scale), -qm, qm)
    wired = jnp.where(bits_h == 0, z, codes * scale)
    # layer B: up-projection adapter back into the decoder width
    y = jnp.einsum("bsw,bwd->bsd", wired.astype(dtype),
                   stacked["up_w"][hid])
    return jnp.where(mode_idx[:, None, None] == 0, x, y.astype(x.dtype))


def boundary_mixed_grouped_ref(xp, down_w, up_w, norm_scale, hid_g, nchunk_g,
                               width_g, bits_g, *, block_r: int,
                               block_w: int = 128, dtype=jnp.bfloat16):
    """Pure-jnp oracle for ``boundary_mixed.boundary_mixed_grouped`` that
    mirrors the kernel's blocked computation EXACTLY (same block shapes,
    same dtypes, same op order), so the Pallas kernel is pinned bit-for-bit
    against it in tests. It differs from :func:`boundary_mixed_ref` only by
    GEMM accumulation shape (mode-grouped block dots vs one batched-gather
    einsum), i.e. by bf16 rounding noise — never by wire semantics.
    Test-scale only (python loop over row blocks).
    """
    P, d = xp.shape
    M, _, wmax = down_w.shape
    outs = []
    for g in range(P // block_r):
        rows = xp[g * block_r:(g + 1) * block_r]
        hid, nch = int(hid_g[g]), int(nchunk_g[g])
        width, bits = int(width_g[g]), int(bits_g[g])
        if nch == 0:                           # raw passthrough (mode 0)
            outs.append(rows)
            continue
        xf = rows.astype(jnp.float32)
        h = xf * jax.lax.rsqrt(
            jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        h = (h * norm_scale[hid].astype(jnp.float32)).astype(xp.dtype)
        z = jnp.zeros((block_r, wmax), jnp.float32)
        for w in range(nch):
            zc = jnp.dot(
                h, down_w[hid, :, w * block_w:(w + 1) * block_w],
                preferred_element_type=jnp.float32
            ).astype(xp.dtype).astype(jnp.float32)
            lane = w * block_w + jnp.arange(block_w)
            z = z.at[:, w * block_w:(w + 1) * block_w].set(
                jnp.where(lane[None, :] < width, zc, 0.0))
        qm = float(max((1 << (max(bits, 1) - 1)) - 1, 1))
        absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
        scale = jnp.maximum(absmax, 1e-8) / qm
        codes = jnp.clip(jnp.round(z / scale), -qm, qm)
        wired = z if bits == 0 else codes * scale
        y = jnp.dot(wired.astype(dtype), up_w[hid],
                    preferred_element_type=jnp.float32)
        outs.append(y.astype(xp.dtype))
    return jnp.concatenate(outs, axis=0)


def paged_attention_ref(q, k_pages, v_pages, block_table, positions):
    """Blocked jnp oracle for ``paged_attention.paged_attention``.

    Walks (sequence, page) exactly like the kernel grid — same page-skip
    guard, same f32 online softmax, same ``q.dtype`` rounding barriers at
    the score / probability / accumulator hand-offs, same op order — so the
    Pallas kernel is pinned bit-for-bit against it in interpret mode for
    sub-f32 dtypes (bf16); f32 matches to a few ulp (the barriers are no-op
    casts there and cannot quantize away XLA's fusion freedom).
    q: [B, nq, hd]; ``k_pages``/``v_pages``: [n_pages, page_len, n_kv, hd];
    ``block_table``: [B, nb]; ``positions``: [B] (concrete host values —
    they steer the python page loop). Returns [B, nq, hd] in ``q.dtype``.
    Test-scale only (python loop over sequences and pages).
    """
    import math

    NEG_INF = -1e30
    B, nq, hd = q.shape
    plen = k_pages.shape[1]
    n_kv = k_pages.shape[2]
    g = nq // n_kv
    nb = block_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    dt = q.dtype
    outs = []
    for b in range(B):
        pos_b = int(positions[b])
        m = jnp.full((1, nq), NEG_INF, jnp.float32)
        l = jnp.zeros((1, nq), jnp.float32)
        acc = jnp.zeros((nq, hd), jnp.float32)
        qf = q[b].astype(jnp.float32)
        for j in range(nb):
            if j * plen > pos_b:
                continue
            page = block_table[b, j]
            kf = jnp.repeat(k_pages[page].astype(jnp.float32), g, 1)
            vf = jnp.repeat(v_pages[page].astype(jnp.float32), g, 1)
            s = (jnp.einsum("nh,tnh->nt", qf, kf) * scale
                 ).astype(dt).astype(jnp.float32)
            t_abs = j * plen + jnp.arange(plen)[None, :]
            s = jnp.where(t_abs <= pos_b, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1)[None, :])
            p = jnp.exp(s - m_new[0][:, None]).astype(dt).astype(jnp.float32)
            corr = jnp.exp(m - m_new).astype(dt).astype(jnp.float32)
            m = m_new
            l = (l * corr).astype(dt).astype(jnp.float32) \
                + jnp.sum(p, axis=-1)[None, :]
            acc = (acc * corr[0][:, None]).astype(dt).astype(jnp.float32) \
                + jnp.einsum("nt,tnh->nh", p, vf).astype(dt).astype(
                    jnp.float32)
        outs.append((acc / l[0][:, None]).astype(dt))
    return jnp.stack(outs)


def dequant_matmul_ref(codes, scales, w, out_dtype=jnp.bfloat16):
    """Decoder-side fused dequantize + up-projection.

    codes: int8 [M, N], scales: f32 [M, 1], w: [N, D] -> [M, D].
    """
    z = codes.astype(jnp.float32) * scales
    return (z @ w.astype(jnp.float32)).astype(out_dtype)


def rglru_scan_ref(a, b, h0=None):
    """Gated linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: [B, S, D] f32; ``h0``: optional [B, D] initial carry (zeros when
    omitted — the post-reset decode case). Returns h: [B, S, D] f32.
    """
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    B, S, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def decode_tail_ref(x, norm_scale, norm_bias, heads, head_idx=None, *,
                    norm_kind: str = "rmsnorm", tied: bool = False):
    """Serving reference for the fused decode tail (final norm -> LM-head
    gather -> argmax), expression-identical to the legacy
    ``norm_apply(final_norm) -> lm_logits -> jnp.argmax`` chain so routing
    the serving tick through it cannot move a single token on CPU.

    x: [B, S, d]; ``heads``: [H, d, V] stacked LM heads, or the [1, V, d]
    embedding table when ``tied``; ``head_idx``: [B] int32 per-row head (None
    = head 0 everywhere). Returns int32 tokens [B, S].
    """
    xf = x.astype(jnp.float32)
    if norm_kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True)
                               + 1e-6)
    else:                                # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    y = y * norm_scale.astype(jnp.float32)
    if norm_bias is not None:
        y = y + norm_bias.astype(jnp.float32)
    xn = y.astype(x.dtype).astype(jnp.float32)
    if tied:
        logits = jnp.einsum("bsd,vd->bsv", xn, heads[0].astype(jnp.float32))
    elif heads.shape[0] == 1:
        logits = xn @ heads[0].astype(jnp.float32)
    else:
        hid = jnp.zeros(x.shape[0], jnp.int32) if head_idx is None \
            else head_idx.astype(jnp.int32)
        logits = jnp.einsum("bsd,bdv->bsv", xn,
                            heads[hid].astype(jnp.float32))
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def decode_tail_grouped_ref(xp, heads, norm_scale, norm_bias, hid_g, *,
                            block_r: int, block_v: int = 512,
                            norm_kind: str = "rmsnorm"):
    """Pure-jnp oracle for ``boundary_mixed.decode_tail_grouped`` mirroring
    the kernel's blocked computation EXACTLY: same per-row-block head gather,
    same f32 norm rounded through the model dtype, same vocab-chunked MXU
    dots, same strict-``>`` running lane max with earliest-chunk tie-keeping
    and final min-index reduce. Test-scale only (python loop over blocks).
    Returns [P, 128] int32 (token broadcast across lanes, like the kernel).
    """
    P, d = xp.shape
    n_v = heads.shape[-1] // block_v
    outs = []
    for g in range(P // block_r):
        rows = xp[g * block_r:(g + 1) * block_r]
        hid = int(hid_g[g])
        xf = rows.astype(jnp.float32)
        if norm_kind == "rmsnorm":
            y = xf * jax.lax.rsqrt(
                jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        else:
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
            y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * norm_scale.astype(jnp.float32)
        y = y + norm_bias.astype(jnp.float32)
        h = y.astype(xp.dtype).astype(jnp.float32)
        best = jnp.full((block_r, block_v), -jnp.inf, jnp.float32)
        bidx = jnp.zeros((block_r, block_v), jnp.int32)
        for v in range(n_v):
            logits = jnp.dot(
                h, heads[hid, :, v * block_v:(v + 1) * block_v].astype(
                    jnp.float32),
                preferred_element_type=jnp.float32)
            lane = v * block_v + jnp.arange(block_v, dtype=jnp.int32)[None, :]
            better = logits > best
            best = jnp.where(better, logits, best)
            bidx = jnp.where(better, lane, bidx)
        m = jnp.max(best, axis=-1, keepdims=True)
        tok = jnp.min(jnp.where(best == m, bidx, jnp.int32(2 ** 31 - 1)),
                      axis=-1, keepdims=True)
        outs.append(jnp.broadcast_to(tok, (block_r, 128)).astype(jnp.int32))
    return jnp.concatenate(outs, axis=0)
