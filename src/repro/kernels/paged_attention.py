"""Pallas TPU kernel: paged decode attention — one-token GQA against a
block-table-indexed page arena.

The paged pool stores every slot's KV rows in ``page_len``-row pages of a
global arena; a per-slot block table maps logical row ``t`` to arena page
``bt[b, t // page_len]``. Dense decode attention gathers the whole logical
cache per step; here the grid walks (sequence, page) and the scalar-prefetch
block table drives the K/V BlockSpec index maps, so each grid step streams
exactly ONE page of K/V into VMEM — never a materialized
``[B, nb * page_len, ...]`` gather — and pages entirely past a sequence's
position are skipped by a ``pl.when`` guard (their index maps still clamp to
a valid page id, the pool's reserved scratch page for short sequences).

Grid: (B, nb) with the page dimension innermost, so each sequence's online
softmax (m / l / acc in VMEM scratch, f32) completes before its epilogue.
The oracle ``ref.paged_attention_ref`` mirrors the blocked computation
op-for-op; interpret mode is pinned **bit-for-bit in sub-f32 dtypes**
(bf16 — the ``q.dtype`` rounding barriers quantize away fusion noise,
exactly like the boundary kernel) and to a few f32 ulp otherwise: XLA may
rematerialize the interpreted kernel body with different FMA fusion than
the oracle's op-by-op eager execution, which f32 barriers cannot quantize
away (they are no-op casts).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, nb: int, plen: int, g: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    pos_b = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # page j holds logical rows [j*plen, (j+1)*plen); skip pages that start
    # past the current position (page 0 always runs: row 0 <= pos)
    @pl.when(j * plen <= pos_b)
    def _page():
        qf = q_ref[0].astype(jnp.float32)                    # [nq, hd]
        kf = jnp.repeat(k_ref[0].astype(jnp.float32), g, 1)  # [plen, nq, hd]
        vf = jnp.repeat(v_ref[0].astype(jnp.float32), g, 1)
        # explicit rounding barriers at the score and probability hand-offs
        # (same trick as the boundary kernel's GEMM chunks): the q-dtype
        # casts pin compiled, interpret, and oracle paths bit-for-bit by
        # quantizing away fusion/FMA rounding differences
        s = (jnp.einsum("nh,tnh->nt", qf, kf) * scale
             ).astype(q_ref.dtype).astype(jnp.float32)       # [nq, plen]
        t_abs = j * plen + jax.lax.broadcasted_iota(jnp.int32, (1, plen), 1)
        s = jnp.where(t_abs <= pos_b, s, NEG_INF)
        m_old = m_scr[...]                                   # [1, nq]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1)[None, :])
        p = jnp.exp(s - m_new[0][:, None]
                    ).astype(q_ref.dtype).astype(jnp.float32)  # [nq, plen]
        corr = jnp.exp(m_old - m_new
                       ).astype(q_ref.dtype).astype(jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = (l_scr[...] * corr).astype(q_ref.dtype).astype(
            jnp.float32) + jnp.sum(p, axis=-1)[None, :]
        acc_scr[...] = (acc_scr[...] * corr[0][:, None]).astype(
            q_ref.dtype).astype(jnp.float32) + jnp.einsum(
            "nt,tnh->nh", p, vf).astype(q_ref.dtype).astype(jnp.float32)

    @pl.when(j == nb - 1)
    def _epilogue():
        o_ref[0] = (acc_scr[...] / l_scr[0][:, None]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_table, positions, *,
                    interpret: bool = False):
    """Paged one-token GQA decode attention.

    q: [B, nq, hd] (rope already applied), ``k_pages``/``v_pages``:
    [n_pages, page_len, n_kv, hd] arenas with the current token's row
    already written, ``block_table``: [B, nb] int32 arena page ids,
    ``positions``: [B] int32 absolute positions. Every page id must be a
    valid arena index (the pool guarantees this — unallocated table entries
    point at the reserved scratch page). Returns the attention context
    [B, nq, hd] in ``q.dtype`` (pre-``wo``).
    """
    B, nq, hd = q.shape
    n_pages, plen, n_kv, hd2 = k_pages.shape
    assert hd == hd2 and nq % n_kv == 0, (q.shape, k_pages.shape)
    nb = block_table.shape[1]
    g = nq // n_kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, nq, hd), lambda b, j, bt, pos: (b, 0, 0)),
            pl.BlockSpec((1, plen, n_kv, hd),
                         lambda b, j, bt, pos: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, plen, n_kv, hd),
                         lambda b, j, bt, pos: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nq, hd), lambda b, j, bt, pos: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, nq), jnp.float32),      # running max
            pltpu.VMEM((1, nq), jnp.float32),      # running denominator
            pltpu.VMEM((nq, hd), jnp.float32),     # context accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nb=nb, plen=plen, g=g,
                          scale=1.0 / math.sqrt(hd)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nq, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), positions.astype(jnp.int32),
      q, k_pages, v_pages)
