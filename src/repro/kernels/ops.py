"""Jit'd public wrappers for the Pallas kernels: shape-padding, block-size
selection, and CPU (interpret-mode) dispatch so the same call sites work in
tests and on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bottleneck_quant as _bq
from repro.kernels import boundary_mixed as _bm
from repro.kernels import dequant_matmul as _dq
from repro.kernels import paged_attention as _pa
from repro.kernels import rglru_scan as _rs
from repro.kernels import ref

_ON_TPU = jax.default_backend() == "tpu"


def _pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest block <= preferred that divides dim, preferring MXU-aligned."""
    for b in (preferred, preferred // 2, preferred // 4, align):
        if b and dim % b == 0:
            return b
    for b in range(min(preferred, dim), 0, -1):
        if dim % b == 0:
            return b
    return dim


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bottleneck_quant_op(x, w, *, bits: int = 8, interpret: bool | None = None):
    """Fused down-proj + int8 quantize. x: [..., K], w: [K, N]."""
    interp = (not _ON_TPU) if interpret is None else interpret
    lead = x.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    K, N = w.shape
    x2 = x.reshape(M, K)
    bm = _pick_block(M, 128)
    bk = _pick_block(K, 512)
    if M % bm or K % bk or N % 128:
        codes, scales = ref.bottleneck_quant_ref(x2, w, bits)
    else:
        codes, scales = _bq.bottleneck_quant(x2, w, bits=bits, block_m=bm,
                                             block_k=bk, interpret=interp)
    return codes.reshape(*lead, N), scales.reshape(*lead, 1)


def _group_rows(mode_idx, n_modes: int, block_r: int):
    """Mode-uniform row-block layout for the fused boundary kernel.

    Rows are stably sorted by mode and each mode's run is padded up to a
    multiple of ``block_r``, so every ``block_r``-row block of the permuted
    layout carries exactly one mode. Returns (dest [B] int32 — each row's
    slot in the padded layout, starts [n_modes] int32 — each mode's padded
    offset, total padded row count P). P is static:
    ``(ceil(B / block_r) + n_modes) * block_r`` always suffices, because
    each mode group wastes at most ``block_r - 1`` pad rows.
    """
    B = mode_idx.shape[0]
    order = jnp.argsort(mode_idx)                       # stable in jax
    counts = jnp.zeros(n_modes, jnp.int32).at[mode_idx].add(1)
    padded = ((counts + block_r - 1) // block_r) * block_r
    starts = jnp.cumsum(padded) - padded                # exclusive cumsum
    cum = jnp.cumsum(counts) - counts
    sortedm = mode_idx[order]
    rank = jnp.arange(B, dtype=jnp.int32) - cum[sortedm]
    dest = jnp.zeros(B, jnp.int32).at[order].set(
        (starts[sortedm] + rank).astype(jnp.int32))
    P = (-(-B // block_r) + n_modes) * block_r
    return dest, starts, padded, P


def boundary_mixed_op(stacked, x, mode_idx, *, dtype=jnp.bfloat16,
                      interpret: bool | None = None):
    """Fused mixed-mode bottleneck boundary (dispatcher).

    Deliberately NOT jitted itself: every serving caller already invokes it
    inside a jitted step (where it traces straight through), and wrapping a
    jit here would change eager callers' op-by-op bf16 rounding against the
    pinned per-mode reference path.

    x: [B, S, d] boundary activations, ``mode_idx``: [B] int32 in [0, M]
    (0 = raw passthrough, m >= 1 = head m-1 of the ``stacked`` bank).
    Routes to the Pallas kernel on TPU (or when ``interpret=True`` — the
    CPU correctness path for tests); everything else — including
    non-128-aligned model/bank widths — takes the jnp reference, which is
    also the fast CPU serving path (interpret mode is a correctness tool,
    not a speed tool).
    """
    use_pallas = _ON_TPU if interpret is None else bool(interpret)
    interp = (not _ON_TPU) if interpret is None else bool(interpret)
    d = x.shape[-1]
    M, _, wmax = stacked["down_w"].shape
    if not use_pallas or d % 128 or wmax % 128:
        return ref.boundary_mixed_ref(stacked, x, mode_idx, dtype=dtype)

    B, S = x.shape[0], x.shape[1]
    block_r = 16 if jnp.dtype(x.dtype).itemsize == 2 else 8
    block_w = 128
    rmode = jnp.repeat(mode_idx.astype(jnp.int32), S)   # per-token mode
    dest, tables = group_layout(stacked, rmode, block_r, block_w)
    xp = jnp.zeros((tables["P"], d), x.dtype).at[dest].set(
        x.reshape(B * S, d))
    yp = _bm.boundary_mixed_grouped(
        xp, stacked["down_w"], stacked["up_w"], stacked["norm_scale"],
        tables["hid"], tables["nchunk"], tables["width"], tables["bits"],
        block_r=block_r, block_w=block_w, dtype=dtype, interpret=interp)
    return yp[dest].reshape(B, S, d)


def boundary_mixed_sharded(stacked, x, mode_idx, mesh, *,
                           dtype=jnp.bfloat16,
                           interpret: bool | None = None):
    """``boundary_mixed_op`` on a serving mesh, run per-shard inside a
    fully-manual ``shard_map`` region with every operand replicated.

    Replicated-in / replicated-out looks like a no-op, but it is the
    bit-identity fix: the reference path's batched gather-einsum lowers
    differently on CPU depending on the (sharded) batch extent, so letting
    GSPMD partition this op makes a dp-sharded step diverge from the
    unsharded engine at the last mantissa bits. Pinning the whole boundary
    to one replicated manual region makes every shard compute the same
    full-batch result with single-device lowering — the Pallas/CPU dispatch
    and unaligned fallbacks inside ``boundary_mixed_op`` run per-shard,
    untouched. A plain ``with_sharding_constraint`` does NOT achieve this
    (the partitioner still specializes the lowering)."""
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import shard_map

    fn = shard_map(
        lambda s, xx, mm: boundary_mixed_op(s, xx, mm, dtype=dtype,
                                            interpret=interpret),
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), stacked), P(), P()),
        out_specs=P())
    return fn(stacked, x, mode_idx)


def group_layout(stacked, rmode, block_r: int, block_w: int):
    """Row permutation + per-block tables for the grouped boundary kernel.

    ``rmode``: [rows] int32 mode per row. Returns (dest [rows] int32 — each
    row's slot in the mode-grouped padded layout, tables) where tables has
    the static padded row count ``P`` and per-row-block int32 arrays:
    ``hid`` (stacked-bank head), ``nchunk`` (width chunks; 0 = raw
    passthrough), ``width``, ``bits``. Blocks past the used span behave as
    raw rows and are never gathered back.
    """
    M = stacked["width"].shape[0]
    dest, starts, padded, P = _group_rows(rmode, M + 1, block_r)
    G = P // block_r
    bstart = jnp.arange(G, dtype=jnp.int32) * block_r
    used = bstart < jnp.sum(padded)
    bmode = jnp.clip(jnp.searchsorted(starts, bstart, side="right") - 1,
                     0, M)
    bmode = jnp.where(used, bmode, 0).astype(jnp.int32)
    hid_g = jnp.clip(bmode - 1, 0, M - 1).astype(jnp.int32)
    width_g = jnp.where(bmode >= 1, stacked["width"][hid_g], 0)
    bits_g = jnp.where(bmode >= 1, stacked["bits"][hid_g], 0)
    nchunk_g = (width_g + block_w - 1) // block_w
    return dest, {"P": P, "hid": hid_g,
                  "nchunk": nchunk_g.astype(jnp.int32),
                  "width": width_g.astype(jnp.int32),
                  "bits": bits_g.astype(jnp.int32)}


def head_layout(head_idx, n_heads: int, block_r: int):
    """Head-uniform row-block layout for the fused decode-tail kernel.

    Same machinery as ``group_layout`` but keyed by LM-head row instead of
    bottleneck mode: rows are stably sorted by head and padded so every
    ``block_r``-row block gathers exactly one head. Returns (dest [rows]
    int32, hid_g [P/block_r] int32, static padded row count P). Blocks past
    the used span read head 0 and are never gathered back.
    """
    dest, starts, padded, P = _group_rows(head_idx, n_heads, block_r)
    G = P // block_r
    bstart = jnp.arange(G, dtype=jnp.int32) * block_r
    used = bstart < jnp.sum(padded)
    hid_g = jnp.clip(jnp.searchsorted(starts, bstart, side="right") - 1,
                     0, n_heads - 1)
    hid_g = jnp.where(used, hid_g, 0).astype(jnp.int32)
    return dest, hid_g, P


def decode_tail_op(x, norm_scale, norm_bias, heads, head_idx=None, *,
                   norm_kind: str = "rmsnorm", tied: bool = False,
                   interpret: bool | None = None):
    """Fused decode tail: final norm -> LM-head gather -> argmax -> int32
    token, in ONE kernel (dispatcher). Together with ``boundary_mixed_op``
    this makes the device-resident serving tick exactly two kernels — the
    f32 logits never leave VMEM.

    Deliberately NOT jitted itself, for the same reason as the boundary op:
    serving callers trace it inside a jitted step, and eager callers keep
    the pinned op-by-op numerics of the legacy norm/lm_logits/argmax chain.

    x: [B, S, d] decoder output; ``heads``: [H, d, V] stacked LM heads (or
    the [1, V, d] embedding table when ``tied`` — transposed on the kernel
    path only); ``head_idx``: [B] int32 per-row head, None = head 0.
    Routes to the Pallas kernel on TPU (or ``interpret=True`` for tests);
    CPU and non-128-aligned d/V take :func:`ref.decode_tail_ref`, which is
    expression-identical to the legacy chain. Returns int32 tokens [B, S].
    """
    use_pallas = _ON_TPU if interpret is None else bool(interpret)
    interp = (not _ON_TPU) if interpret is None else bool(interpret)
    B, S, d = x.shape
    V = heads.shape[1] if tied else heads.shape[2]
    if not use_pallas or d % 128 or V % 128:
        return ref.decode_tail_ref(x, norm_scale, norm_bias, heads, head_idx,
                                   norm_kind=norm_kind, tied=tied)
    hv = jnp.swapaxes(heads, 1, 2) if tied else heads
    H = hv.shape[0]
    hidx = jnp.zeros(B, jnp.int32) if head_idx is None \
        else head_idx.astype(jnp.int32)
    rhid = jnp.repeat(hidx, S)                          # per-token head
    block_r = 16 if jnp.dtype(x.dtype).itemsize == 2 else 8
    dest, hid_g, P = head_layout(rhid, H, block_r)
    xp = jnp.zeros((P, d), x.dtype).at[dest].set(x.reshape(B * S, d))
    bias = norm_bias if norm_bias is not None \
        else jnp.zeros((d,), norm_scale.dtype)
    tokp = _bm.decode_tail_grouped(
        xp, hv, norm_scale, bias, hid_g, block_r=block_r,
        block_v=_pick_block(V, 512), norm_kind=norm_kind, interpret=interp)
    return tokp[dest, 0].reshape(B, S)


def paged_kernel_eligible(*, n_q: int, n_kv: int, hd: int,
                          page_len: int) -> bool:
    """Whether the serving decode path should route paged attention through
    the Pallas kernel. Only on a real TPU with MXU-aligned head and page
    shapes — on CPU the model layer's logical-gather jnp path is both the
    fast path and the one pinned bit-identical to dense decode (interpret
    mode is a correctness tool, not a speed tool)."""
    return _ON_TPU and hd % 128 == 0 and page_len % 8 == 0 \
        and n_q % n_kv == 0


def paged_attention_op(q, k_pages, v_pages, block_table, positions, *,
                       interpret: bool | None = None):
    """Paged decode attention (dispatcher). Deliberately NOT jitted itself —
    serving callers invoke it inside a jitted step, like the boundary op.

    q: [B, nq, hd] (rope applied), ``k_pages``/``v_pages``:
    [n_pages, page_len, n_kv, hd], ``block_table``: [B, nb] arena page ids,
    ``positions``: [B]. Routes to the Pallas kernel on TPU (or when
    ``interpret=True`` — the CPU correctness path for tests); misaligned
    shapes and plain CPU calls take the blocked jnp oracle. Returns the
    f32 attention context [B, nq, hd] (pre-``wo``)."""
    use_pallas = _ON_TPU if interpret is None else bool(interpret)
    interp = (not _ON_TPU) if interpret is None else bool(interpret)
    hd = q.shape[-1]
    plen = k_pages.shape[1]
    if not use_pallas or hd % 128 or plen % 8 or q.shape[1] % k_pages.shape[2]:
        return ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                       positions)
    return _pa.paged_attention(q, k_pages, v_pages, block_table, positions,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_matmul_op(codes, scales, w, *, interpret: bool | None = None):
    """Fused dequant + up-proj. codes: [..., N] int8 -> [..., D] bf16."""
    interp = (not _ON_TPU) if interpret is None else interpret
    lead = codes.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    N, D = w.shape
    c2 = codes.reshape(M, N)
    s2 = scales.reshape(M, 1)
    bm = _pick_block(M, 128)
    bd = _pick_block(D, 512)
    if M % bm or D % bd or N % 128:
        y = ref.dequant_matmul_ref(c2, s2, w)
    else:
        y = _dq.dequant_matmul(c2, s2, w, block_m=bm, block_d=bd,
                               interpret=interp)
    return y.reshape(*lead, D)


def rglru_scan_op(a, b, h0=None, *, interpret: bool | None = None):
    """Blocked linear recurrence h_t = a_t * h_{t-1} + b_t (dispatcher).

    Deliberately NOT jitted itself: the model layers call it inside jitted
    prefill/decode steps (where it traces straight through), and the CPU
    path must stay the plain ``lax.scan`` reference — bit-identical to the
    ``chunked_scan`` cell path it replaces — not the interpreted kernel.

    a, b: [B, S, D] f32; ``h0``: optional [B, D] initial carry. A non-zero
    ``h0`` is absorbed into the first step (``b_1 += a_1 * h0``) so the
    zero-carry Pallas kernel applies unchanged; the absorbed form is
    bit-identical because ``a_1*h0 + b_1`` is the same f32 expression
    either way. Routes to the Pallas kernel on TPU (or ``interpret=True``
    for tests); CPU and non-block-multiple S/D take the jnp reference.
    """
    use_pallas = _ON_TPU if interpret is None else bool(interpret)
    interp = (not _ON_TPU) if interpret is None else bool(interpret)
    B, S, D = a.shape
    # MXU-sane tiles only: sublane-multiple time blocks, lane-multiple
    # feature blocks — anything else takes the reference
    if not use_pallas or S % 8 or D % 128:
        return ref.rglru_scan_ref(a, b, h0)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))
    return _rs.rglru_scan(a, b, block_s=_pick_block(S, 256, align=8),
                          block_d=_pick_block(D, 512), interpret=interp)
