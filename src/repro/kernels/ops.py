"""Jit'd public wrappers for the Pallas kernels: shape-padding, block-size
selection, and CPU (interpret-mode) dispatch so the same call sites work in
tests and on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bottleneck_quant as _bq
from repro.kernels import dequant_matmul as _dq
from repro.kernels import rglru_scan as _rs
from repro.kernels import ref

_ON_TPU = jax.default_backend() == "tpu"


def _pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest block <= preferred that divides dim, preferring MXU-aligned."""
    for b in (preferred, preferred // 2, preferred // 4, align):
        if b and dim % b == 0:
            return b
    for b in range(min(preferred, dim), 0, -1):
        if dim % b == 0:
            return b
    return dim


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bottleneck_quant_op(x, w, *, bits: int = 8, interpret: bool | None = None):
    """Fused down-proj + int8 quantize. x: [..., K], w: [K, N]."""
    interp = (not _ON_TPU) if interpret is None else interpret
    lead = x.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    K, N = w.shape
    x2 = x.reshape(M, K)
    bm = _pick_block(M, 128)
    bk = _pick_block(K, 512)
    if M % bm or K % bk or N % 128:
        codes, scales = ref.bottleneck_quant_ref(x2, w, bits)
    else:
        codes, scales = _bq.bottleneck_quant(x2, w, bits=bits, block_m=bm,
                                             block_k=bk, interpret=interp)
    return codes.reshape(*lead, N), scales.reshape(*lead, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_matmul_op(codes, scales, w, *, interpret: bool | None = None):
    """Fused dequant + up-proj. codes: [..., N] int8 -> [..., D] bf16."""
    interp = (not _ON_TPU) if interpret is None else interpret
    lead = codes.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    N, D = w.shape
    c2 = codes.reshape(M, N)
    s2 = scales.reshape(M, 1)
    bm = _pick_block(M, 128)
    bd = _pick_block(D, 512)
    if M % bm or D % bd or N % 128:
        y = ref.dequant_matmul_ref(c2, s2, w)
    else:
        y = _dq.dequant_matmul(c2, s2, w, block_m=bm, block_d=bd,
                               interpret=interp)
    return y.reshape(*lead, D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan_op(a, b, *, interpret: bool | None = None):
    """Blocked linear recurrence. a, b: [B, S, D] f32."""
    interp = (not _ON_TPU) if interpret is None else interpret
    B, S, D = a.shape
    bs = _pick_block(S, 256, align=8)
    bd = _pick_block(D, 512)
    if S % bs or D % bd:
        return ref.rglru_scan_ref(a, b)
    return _rs.rglru_scan(a, b, block_s=bs, block_d=bd, interpret=interp)
