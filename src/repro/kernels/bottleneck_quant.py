"""Pallas TPU kernel: fused bottleneck down-projection + row-wise int8
quantization — the encoder-side transmit op the paper's mechanism inserts on
every query (layer A + wire format).

TPU adaptation: the GPU formulation would be a GEMM followed by a separate
quantize kernel; on TPU we tile the GEMM for the MXU (128-aligned blocks),
accumulate in an f32 VMEM scratch, and fuse the absmax/scale/round into the
epilogue of the final K-step so the full-precision activation NEVER leaves
VMEM — only int8 codes and one f32 scale per row are written to HBM, which is
exactly the wire payload.

Grid: (M/BM, K/BK) — K innermost so each row-block's accumulator completes
before its quantization epilogue. N (the bottleneck width, <= 2048 in all
assigned configs) fits one VMEM block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, codes_ref, scales_ref, acc_ref, *, n_k: int,
            qmax: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        z = acc_ref[...]                                   # [BM, N] f32
        absmax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
        scale = jnp.maximum(absmax, 1e-8) / qmax
        q = jnp.clip(jnp.round(z / scale), -qmax, qmax)
        codes_ref[...] = q.astype(jnp.int8)
        scales_ref[...] = scale


def bottleneck_quant(x, w, *, bits: int = 8, block_m: int = 128,
                     block_k: int = 512, interpret: bool = False):
    """x: [M, K], w: [K, N] -> (codes int8 [M, N], scales f32 [M, 1]).

    M % block_m == 0, K % block_k == 0 required (ops.py pads otherwise).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % block_m == 0 and K % block_k == 0, (M, K, block_m, block_k)
    n_k = K // block_k
    # floor at 1 to match quant.qmax and boundary_mixed: bits=1 is the
    # ternary {-1, 0, 1} wire code, not a division by zero
    qmax = max((1 << (bits - 1)) - 1, 1)

    grid = (M // block_m, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, k: (m, k)),
            pl.BlockSpec((block_k, N), lambda m, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, N), lambda m, k: (m, 0)),
            pl.BlockSpec((block_m, 1), lambda m, k: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, N), jnp.float32)],
        interpret=interpret,
    )(x, w)
