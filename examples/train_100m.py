"""End-to-end driver: train the full xlstm-125m assigned config (~110M
params) for a few hundred steps on the synthetic Markov LM stream, with the
split-cascade phases — the framework's training path at real (if small)
scale.

CPU note: the full 125M model at seq 256 takes ~2-5 s/step on this
container; default is a 20-step smoke. Pass --steps 300 for the full run.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--seq 256]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import split as SP
from repro.data import tokens
from repro.training import checkpoint
from repro.training import loop as L


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--mode", type=int, default=None,
                    help="split mode (None = monolithic)")
    ap.add_argument("--save", default="results/xlstm125m.npz")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    print(f"== xlstm-125m: {cfg.param_count()/1e6:.0f}M params, "
          f"{cfg.n_layers}L (mLSTM/sLSTM 1:1), seq {args.seq} ==")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"materialized {n/1e6:.1f}M params")

    src = tokens.MarkovTokenSource(cfg, alphabet=256)
    tcfg = TrainConfig(learning_rate=6e-4, warmup_steps=20,
                       total_steps=max(args.steps, 100))
    t0 = time.time()
    params, hist = L.train_loop(
        params, cfg, tcfg,
        lambda s: src.batch(args.batch, args.seq, s),
        steps=args.steps, mode=args.mode, log_every=5)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{toks} tokens in {dt:.0f}s = {toks/dt:.0f} tok/s "
          f"({6 * n * toks / dt / 1e9:.1f} GFLOP/s)")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if args.save:
        checkpoint.save(args.save, params, {"steps": args.steps})
        print(f"checkpoint -> {args.save}")


if __name__ == "__main__":
    main()
