"""The paper's end-to-end use case (Secs. V-VI): mmWave throughput prediction
with the adaptive split LSTM-Dense encoder-decoder on the (synthetic)
Lumos5G twin.

Runs the FULL Algorithm 1 cascade with the paper's architecture (2x128-cell
LSTM encoder, 32-cell bottleneck, time-distributed Dense decoder, T=20,
lr=1e-2, batch=256), then reproduces the analysis:
  - per-mode payload/accuracy table (the complexity-relevance tradeoff),
  - information-plane points for both phases (Fig. 9),
  - temporal conditional-MI redundancy ladder (Sec. VI),
and writes everything to results/throughput_prediction.json.

    PYTHONPATH=src python examples/throughput_prediction.py \
        [--steps-per-phase 300] [--samples 70000] [--reduced]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import TrainConfig
from repro.core import cascade as C
from repro.core.ib import info_plane
from repro.data import lumos5g
from repro.models import lstm as LSTM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps-per-phase", type=int, default=300)
    ap.add_argument("--samples", type=int, default=20_000)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny model for a fast smoke run")
    args = ap.parse_args()

    lcfg = get_reduced("lumos5g-lstm") if args.reduced \
        else get_config("lumos5g-lstm")
    print(f"== paper PoC: LSTM{list(lcfg.enc_cells)} + bottleneck "
          f"{lcfg.bottleneck_cells} on Lumos5G twin "
          f"(T={lcfg.seq_len}, {args.samples} samples) ==")

    dcfg = lumos5g.Lumos5GConfig(n_samples=args.samples,
                                 seq_len=lcfg.seq_len)
    data = lumos5g.generate(dcfg)
    train, test = lumos5g.train_test_split(data, dcfg)
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)

    it = lumos5g.batch_iterator(train, lcfg.batch_size)
    test_b = {"x": jnp.asarray(test["x"][:2048]),
              "y": jnp.asarray(test["y"][:2048])}

    def data_iter(step):
        b = next(it)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    def eval_fn(params, mode):
        loss, m = LSTM.loss_fn(params, test_b, lcfg, mode)
        return {"loss": loss, "acc": m["acc"]}

    tcfg = TrainConfig(learning_rate=lcfg.learning_rate, warmup_steps=20,
                       total_steps=2 * args.steps_per_phase,
                       weight_decay=0.0)
    t0 = time.time()
    params, hist = C.train_cascade(
        params, lambda p, b, m: LSTM.loss_fn(p, b, lcfg, m), data_iter,
        tcfg, n_modes=2, steps_per_phase=args.steps_per_phase,
        phase_mask_fn=lambda p, ph: LSTM.phase_mask(p, ph),
        eval_fn=eval_fn, log_every=50)

    # --- the complexity-relevance table -------------------------------------
    z_bytes = lcfg.enc_cells[-1] * 4
    zp_bytes = lcfg.bottleneck_cells + 2
    print("\nmode  code           bytes/query  val_loss  val_acc")
    for m, bytes_ in ((0, z_bytes), (1, zp_bytes)):
        e = hist["phases"][m]["eval"]
        code = "z  = H_T^(2)" if m == 0 else "z' = H_T^(3)"
        print(f"  {m}   {code:14s} {bytes_:8d}    {e['loss']:.4f}   "
              f"{e['acc']:.4f}")
    print(f"Ensure (Alg. 1): ordered={hist['ensure']['ordered']}")

    # --- IB analysis (Fig. 9 + Sec. VI) --------------------------------------
    xe = jnp.asarray(test["x"][:1500])
    y_tau = test["y"][:1500, -1]
    out_ib = {}
    for mode, layers in ((0, ["H1", "H2"]), (1, ["H1", "H2", "H3"])):
        _, acts = LSTM.forward(params, xe, lcfg, mode)
        for n in layers:
            h = np.asarray(acts[n])
            h_in = h[:, -4:, :] if n == "H1" else h[:, -1, :]
            pt = info_plane.layer_point(h_in, np.asarray(xe), y_tau,
                                        lcfg.n_classes)
            out_ib[f"mode{mode}_{n}"] = pt
    print("\ninformation plane (bits):")
    for k, v in out_ib.items():
        print(f"  {k}: I(X;H)={v['I_XH']:.2f}  I(H;Y)={v['I_HY']:.2f}")

    _, acts = LSTM.forward(params, xe, lcfg, 0)
    ladder = info_plane.temporal_redundancy(
        np.asarray(acts["H1"]), np.asarray(xe), max_condition=3)
    print(f"\nconditional-MI ladder I(X;H_T|H_(T-1..T-k)), k=1..3: "
          f"{['%.2f' % v for v in ladder]}")

    os.makedirs("results", exist_ok=True)
    with open("results/throughput_prediction.json", "w") as f:
        json.dump({"history": hist, "info_plane": out_ib,
                   "cond_mi_ladder": [float(v) for v in ladder],
                   "wall_s": time.time() - t0}, f, indent=1, default=float)
    print(f"\nwrote results/throughput_prediction.json "
          f"({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
