"""Expert-parallel MoE training end to end — the §Perf pair-B configuration
at CPU scale.

Spawns 8 host devices, builds the (2 data, 4 model) mesh, and trains the
reduced phi3.5-moe config twice for the same steps/seed: once with the
einsum MoE (GSPMD picks the collectives) and once with the explicit
shard_map expert-parallel all-to-all schedule (`--moe-ep` in the dry-run,
`moe_ep=True` here). Losses must track each other — the EP schedule is a
placement change, not a model change — while the compiled HLO shows
all-to-alls instead of expert-weight all-gathers.

    PYTHONPATH=src python examples/expert_parallel_moe.py [--steps 12]

NOTE: sets XLA_FLAGS before importing jax — run standalone, not from a
process that already initialized jax.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import re            # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_reduced                    # noqa: E402
from repro.configs.base import TrainConfig               # noqa: E402
from repro.core import split as SP                       # noqa: E402
from repro.data import tokens                            # noqa: E402
from repro.training import loop as L                     # noqa: E402
from repro.training import optimizer as opt              # noqa: E402


def run(cfg, mesh, *, moe_ep: bool, steps: int, batch: int, seq: int):
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2,
                       total_steps=max(steps, 10))
    step = jax.jit(L.make_train_step(cfg, tcfg, mesh=mesh,
                                     act_policy="batch", moe_ep=moe_ep))
    src = tokens.MarkovTokenSource(cfg, seed=3)
    opt_state = opt.init(params)
    losses = []
    with jax.set_mesh(mesh):
        lowered = step.lower(params, opt_state, {
            k: jnp.asarray(v) for k, v in src.batch(batch, seq, 0).items()})
        hlo = lowered.compile().as_text()
        for s in range(steps):
            b = {k: jnp.asarray(v) for k, v in src.batch(batch, seq, s).items()}
            params, opt_state, m = step(params, opt_state, b)
            losses.append(float(m["loss"]))
    n_a2a = len(re.findall(r"all-to-all", hlo))
    return losses, n_a2a


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_reduced("phi3.5-moe-42b-a6.6b")
    print(f"== reduced phi3.5-moe ({cfg.n_experts} experts, top-"
          f"{cfg.experts_per_tok}) on mesh {dict(mesh.shape)} ==")

    ref_losses, ref_a2a = run(cfg, mesh, moe_ep=False, steps=args.steps,
                              batch=args.batch, seq=args.seq)
    ep_losses, ep_a2a = run(cfg, mesh, moe_ep=True, steps=args.steps,
                            batch=args.batch, seq=args.seq)
    print(f"einsum MoE: loss {ref_losses[0]:.4f} -> {ref_losses[-1]:.4f} "
          f"(a2a ops in HLO: {ref_a2a})")
    print(f"EP MoE:     loss {ep_losses[0]:.4f} -> {ep_losses[-1]:.4f} "
          f"(a2a ops in HLO: {ep_a2a})")
    gap = max(abs(a - b) for a, b in zip(ref_losses, ep_losses))
    print(f"max per-step loss gap: {gap:.4f}")
    assert ep_a2a > 0, "EP path must lower to all-to-all"
    assert gap < 0.5, "EP and einsum training must track each other"
    assert ep_losses[-1] < ep_losses[0], "loss must decrease"
    print("OK — expert-parallel schedule trains identically")


if __name__ == "__main__":
    main()
