"""Continuous-batching split serving with per-user dynamic mode selection
(Fig. 3/5 at serving scale): requests from users with *different* mmWave
links stream into a slot-pooled engine; every decode tick each in-flight
request's orchestrator link state picks that user's bottleneck mode, so one
jitted decode step routes cell-edge users through the compressed code z'
while beam-center users keep the raw code z.

    PYTHONPATH=src python examples/split_serving.py [--arch qwen2.5-3b]
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import ChannelConfig, channel_fleet
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.serving import ContinuousBatchingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)

    pay = {m: BN.mode_payload_bytes(cfg, 1, 1, m)
           for m in range(cfg.split.n_modes)}
    print(f"== continuous split serving {args.arch}: per-token payload "
          + " ".join(f"mode{m}={b}B" for m, b in pay.items()) + " ==")

    profiles = [ModeProfile(m, pay[m], float(m)) for m in pay]
    orch = Orchestrator(profiles, AppRequirement(latency_budget_s=0.006),
                        ema=0.5, hysteresis=1.0)
    # a fleet of user links: log-spread means put some users at the cell
    # edge (z' territory) and some at beam center (raw z is affordable)
    chans = channel_fleet(
        args.requests,
        ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                      recovery_prob=0.15),
        seed=11, mean_spread=0.95)

    rng = np.random.default_rng(0)
    if cfg.frontend == "audio" and cfg.n_codebooks > 1:
        prompts = [rng.integers(1, cfg.vocab_size,
                                size=(cfg.n_codebooks, 4)).astype(np.int32)
                   for _ in range(args.requests)]
    else:
        prompts = [rng.integers(1, cfg.vocab_size, size=4).astype(np.int32)
                   for _ in range(args.requests)]
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=args.gen,
                    channel=chans[i], arrival_tick=2 * i)
            for i in range(args.requests)]

    eng = ContinuousBatchingEngine(params, cfg, n_slots=args.n_slots,
                                   cache_len=max(64, args.gen + 16),
                                   orchestrator=orch)
    done = eng.run(reqs)
    st = eng.stats()

    for s in sorted(done, key=lambda s: s.request.rid):
        mbps = s.request.channel.cfg.mean_mbps
        print(f"  req {s.request.rid:2d} uplink~{mbps:5.1f}Mbps "
              f"modes={s.mode_counts} wire={s.wire_bytes}B "
              f"xfer={1e3 * s.transfer_s:.1f}ms")
    dec_wire = sum(pay[m] * c for m, c in st["mode_counts"].items())
    raw = pay[0] * st["decode_tokens"]
    print(f"decode ticks with >=2 modes in the same batch: "
          f"{st['mixed_mode_ticks']}/{st['decode_ticks']}")
    print(f"decode wire bytes/token {dec_wire / max(st['decode_tokens'], 1):.1f} "
          f"(always-z would be {pay[0]}); saved "
          f"{100 * (1 - dec_wire / raw):.0f}% uplink")


if __name__ == "__main__":
    main()
