"""Split serving with dynamic mode selection (Fig. 3/5): a batched decoder
runs with its encoder half "on the UE" and decoder half "at the edge"; every
generated token's boundary activation crosses a simulated mmWave link, and
the orchestrator switches between the raw code z and the bottleneck code z'
as the channel fades and blocks.

    PYTHONPATH=src python examples/split_serving.py [--arch qwen2.5-3b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import Channel, ChannelConfig
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)

    pay = {m: BN.mode_payload_bytes(cfg, args.batch, 1, m) for m in (0, 1)}
    print(f"== split serving {args.arch}: boundary payload/token "
          f"z={pay[0]}B z'={pay[1]}B (x{pay[1]/pay[0]:.3f}) ==")

    profiles = [ModeProfile(0, pay[0], 1.0, 0.86),
                ModeProfile(1, pay[1], 1.3, 0.81)]
    orch = Orchestrator(profiles,
                        AppRequirement(latency_budget_s=0.006),
                        ema=0.5, hysteresis=1.0)
    ch = Channel(ChannelConfig(mean_mbps=20.0, std_mbps=8.0,
                               blockage_prob=0.08, recovery_prob=0.15,
                               seed=11))

    eng = ServingEngine(params, cfg, cache_len=max(64, args.tokens + 8),
                        batch=args.batch, orchestrator=orch)
    prompt = jnp.ones((args.batch, 4), jnp.int32) \
        if cfg.frontend != "audio" else \
        jnp.ones((args.batch, cfg.n_codebooks, 4), jnp.int32)
    logits = eng.prefill(prompt)
    first = jnp.argmax(logits, -1).astype(jnp.int32)

    caps = []
    def cap_fn():
        caps.append(ch.step())
        return caps[-1]

    out = eng.decode_tokens(first, args.tokens, capacity_bps_fn=cap_fn)
    timeline = "".join("." if c > 2e6 else "X" for c in caps)
    print(f"channel  (X=blocked): {timeline}")
    print(f"generated {out.shape[-1]} tokens x batch {args.batch}")
    print(f"wire bytes total: {eng.stats.wire_bytes} "
          f"(static-z would be {pay[0]*args.tokens})")
    print(f"mode usage: {eng.stats.mode_counts} "
          f"switches={orch.state.switches}")
    saved = 1 - eng.stats.wire_bytes / (pay[0] * args.tokens)
    print(f"uplink bytes saved vs always-z: {100*saved:.0f}%")


if __name__ == "__main__":
    main()
