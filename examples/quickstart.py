"""Quickstart: split any assigned architecture, train it with Algorithm 1's
cascade, and watch the orchestrator trade wire bytes for accuracy.

    PYTHONPATH=src python examples/quickstart.py [--arch stablelm-3b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced
from repro.configs.base import TrainConfig
from repro.core import bottleneck as BN
from repro.core import cascade as C
from repro.core import split as SP
from repro.data import tokens
from repro.models.transformer import lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"== {args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model}) ==")
    print(f"split at layer {cfg.split.split_at}; "
          f"bottleneck {cfg.split.d_bottleneck} @int{cfg.split.quant_bits}")
    for mode in range(cfg.split.n_modes):
        print(f"  mode {mode}: {BN.mode_payload_bytes(cfg, 1, 1, mode)} "
              f"bytes/token on the wire "
              f"(x{BN.compression_ratio(cfg, mode):.3f})")

    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    src = tokens.MarkovTokenSource(cfg, alphabet=32)

    def loss_fn(params, batch, mode):
        logits, aux, _ = SP.split_forward(params, batch["tokens"], cfg,
                                          mode, train=True,
                                          embeddings=batch.get("embeddings"))
        if cfg.frontend == "vision":
            logits = logits[:, -batch["labels"].shape[-1]:]
        return lm_loss(logits, batch["labels"]) + 0.01 * aux, {}

    def data_iter(step):
        return {k: jnp.asarray(v) for k, v in src.batch(8, 16, step).items()}

    def eval_fn(params, mode):
        loss, _ = loss_fn(params, data_iter(10_000), mode)
        return {"loss": loss}

    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                       total_steps=2 * args.steps, weight_decay=0.0)
    params, hist = C.train_cascade(
        params, loss_fn, data_iter, tcfg, n_modes=2,
        steps_per_phase=args.steps, eval_fn=eval_fn, log_every=20)

    print("\n== Algorithm 1 'Ensure' check (DPI ordering) ==")
    print(f"mode losses: {['%.3f' % l for l in hist['ensure']['losses']]} "
          f"ordered={hist['ensure']['ordered']}")


if __name__ == "__main__":
    main()
