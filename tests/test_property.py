"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quant
from repro.core.channel import Channel, ChannelConfig, tx_seconds
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.launch import roofline

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(2, 64),
       st.sampled_from([4, 8]), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_quant_roundtrip_error_bound(rows, d, bits, scale_mag):
    """|x - dq(q(x))| <= scale/2 elementwise (symmetric rounding bound)."""
    rng = np.random.default_rng(rows * d)
    x = jnp.asarray(scale_mag * rng.normal(size=(rows, d)), jnp.float32)
    q, s = quant.quantize(x, bits)
    err = jnp.abs(x - quant.dequantize(q, s, bits))
    assert bool(jnp.all(err <= s / 2 + 1e-6 * scale_mag))


@given(st.integers(1, 4), st.integers(2, 32))
@settings(**SETTINGS)
def test_quant_codes_in_range(rows, d):
    rng = np.random.default_rng(rows + d)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    for bits in (4, 8):
        q, _ = quant.quantize(x, bits)
        lim = quant.qmax(bits)
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= lim


@given(st.integers(2, 32), st.integers(1, 8))
@settings(**SETTINGS)
def test_ste_gradient_is_identity(d, rows):
    rng = np.random.default_rng(d)
    x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(quant.ste_quantize(x, 8) * 2.0))(x)
    np.testing.assert_allclose(np.asarray(g), 2.0)


@given(st.integers(1, 64), st.integers(8, 512))
@settings(**SETTINGS)
def test_payload_bytes_monotone(rows, d):
    """Fewer bits -> strictly fewer wire bytes; raw bf16 is the ceiling."""
    b4 = quant.payload_bytes((rows, d), 4)
    b8 = quant.payload_bytes((rows, d), 8)
    raw = quant.payload_bytes((rows, d), 0)
    assert b4 < b8 <= raw + rows * 2


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(1e4, 1e9), min_size=3, max_size=20),
       st.floats(0.001, 0.5))
@settings(**SETTINGS)
def test_orchestrator_choice_always_valid(capacities, budget):
    profiles = [ModeProfile(0, 100_000, 1.0), ModeProfile(1, 10_000, 1.2),
                ModeProfile(2, 1_000, 1.5)]
    orch = Orchestrator(profiles, AppRequirement(latency_budget_s=budget))
    for c in capacities:
        orch.observe_capacity(c)
        mode = orch.choose_mode()
        assert mode in (0, 1, 2)
        p = next(p for p in profiles if p.mode == mode)
        feasible_any = any(
            tx_seconds(q.payload_bytes, orch.state.capacity_ema) <= budget
            for q in profiles)
        if feasible_any:
            # hysteresis may hold a smaller-payload mode, never a larger
            # infeasible one
            assert (tx_seconds(p.payload_bytes, orch.state.capacity_ema)
                    <= budget
                    or p.payload_bytes == min(q.payload_bytes
                                              for q in profiles)
                    or p.mode == 2)


@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_channel_deterministic_and_positive(seed):
    cfg = ChannelConfig(seed=seed)
    t1 = Channel(cfg).trace(50)
    t2 = Channel(cfg).trace(50)
    np.testing.assert_array_equal(t1, t2)
    assert (t1 > 0).all()


# ---------------------------------------------------------------------------
# roofline parsing
# ---------------------------------------------------------------------------

@given(st.integers(1, 64), st.integers(1, 64), st.sampled_from(
    ["f32", "bf16", "s8"]))
@settings(**SETTINGS)
def test_shape_bytes_parser(m, n, dt):
    per = {"f32": 4, "bf16": 2, "s8": 1}[dt]
    s = f"{dt}[{m},{n}]{{1,0}}"
    assert roofline._shape_bytes(s) == m * n * per


@given(st.integers(1, 100), st.integers(1, 100))
@settings(**SETTINGS)
def test_roofline_dominant_term(flops_scale, bytes_scale):
    t = roofline.roofline_terms(flops_scale * 1e12, bytes_scale * 1e9,
                                0.0, 256)
    assert t["dominant"] in ("compute_s", "memory_s")
    assert t["bound_s"] == max(t["compute_s"], t["memory_s"],
                               t["collective_s"])


# ---------------------------------------------------------------------------
# sharding-spec fitting (the activation-policy machinery of §Perf)
# ---------------------------------------------------------------------------

try:
    _ABS_MESH = jax.sharding.AbstractMesh(
        (("pod", 2), ("data", 4), ("model", 8)))
except TypeError:   # older signature: (shape, axis_names)
    _ABS_MESH = jax.sharding.AbstractMesh((2, 4, 8),
                                          ("pod", "data", "model"))


@given(st.integers(1, 512), st.sampled_from(
    [("pod",), ("pod", "data"), ("pod", "data", "model"), ("model",)]))
@settings(**SETTINGS)
def test_fit_spec_always_divides(dim, axes):
    from jax.sharding import PartitionSpec as P
    from repro.models import sharding as SH
    spec = SH._fit_spec(P(axes), (dim,), _ABS_MESH)
    got = spec[0]
    if got is not None:
        assert dim % SH._axis_size(_ABS_MESH, got) == 0
    # trimming never invents axes
    if isinstance(got, tuple):
        assert set(got) <= set(axes)


@given(st.integers(1, 1024), st.sampled_from(["seq", "batch", "batch2d"]))
@settings(**SETTINGS)
def test_batch_pspec_always_valid(batch, policy):
    from repro.models import sharding as SH
    spec = SH.batch_pspec(_ABS_MESH, 2, batch, policy)
    axes = spec[0]
    if axes is not None:
        assert batch % SH._axis_size(_ABS_MESH, axes) == 0


@given(st.integers(2, 16), st.integers(1, 4), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_ep_capacity_positive_and_bounded(n_loc, k, d):
    """EP capacity formula: positive, and slack capacity keeps every slot."""
    from repro.models import moe_ep
    E = 4
    cap = max(int(8.0 * k * n_loc / E), 1)
    assert cap >= 1
    router_w = np.eye(d, E).astype(np.float32)
    xg = jnp.asarray(np.random.default_rng(0).normal(size=(n_loc, d)),
                     jnp.float32)
    gates, idx, slot, keep, aux = moe_ep._route_local(
        jnp.asarray(router_w), xg, min(k, E), cap, E)
    assert bool(jnp.all(keep))               # slack capacity drops nothing
    assert bool(jnp.all((slot >= 0) & (slot < cap)))
    assert float(jnp.max(jnp.abs(jnp.sum(gates, -1) - 1.0))) < 1e-5
