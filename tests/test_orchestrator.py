"""Orchestrator unit tests: cold start, hysteresis, min_acc filtering, and
per-request link isolation."""
import pytest

from repro.core.channel import tx_seconds
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)

PROFILES = [ModeProfile(0, 100_000, 1.0, 0.9),
            ModeProfile(1, 10_000, 1.2, 0.8),
            ModeProfile(2, 1_000, 1.5, 0.7)]


def make(**kw):
    kw.setdefault("requirement", AppRequirement(latency_budget_s=0.05))
    return Orchestrator([ModeProfile(p.mode, p.payload_bytes,
                                     p.expected_loss, p.expected_acc)
                         for p in PROFILES], **kw)


def test_cold_start_is_optimistic():
    """Before any capacity observation the orchestrator must NOT treat the
    link as zero-capacity (which silently pinned the smallest payload);
    it starts from the most relevant mode."""
    orch = make()
    assert orch.choose_mode() == 0
    # and the first real observation takes over immediately (EMA bootstraps
    # from the observation, not from 0.0)
    orch.observe_capacity(1e3)       # terrible link
    assert orch.state.capacity_ema == 1e3
    assert orch.choose_mode() == 2


def test_default_requirement_not_shared():
    a = make(requirement=None)
    b = make(requirement=None)
    a.req.latency_budget_s = 123.0
    assert b.req.latency_budget_s != 123.0
    # nor is a caller-provided requirement aliased
    req = AppRequirement(latency_budget_s=0.02)
    c = Orchestrator(PROFILES, req)
    req.latency_budget_s = 999.0
    assert c.req.latency_budget_s == 0.02


def test_hysteresis_no_flapping_on_boundary_oscillation():
    """A capacity trace oscillating around mode 0's feasibility boundary
    must not flap: with the hysteresis margin the orchestrator upgrades
    only when the better mode clears by a clear margin."""
    budget = 0.05
    # mode 0 needs ~100_000/0.046 ≈ 2.17e6 B/s to fit the budget (rtt 4ms)
    boundary = PROFILES[0].payload_bytes / (budget - 0.004)
    orch = make(ema=0.0, hysteresis=0.8)   # ema 0: track raw capacity
    orch.observe_capacity(boundary * 1.5)
    assert orch.choose_mode() == 0
    switches0 = orch.state.switches
    # oscillate +/-5% around the boundary: within the 20% hysteresis band
    for i in range(40):
        orch.observe_capacity(boundary * (1.05 if i % 2 == 0 else 0.95))
        orch.choose_mode()
    # at most one downgrade (to mode 1 when capacity dips below) and no
    # repeated up/down churn
    assert orch.state.switches - switches0 <= 1


def test_min_acc_filters_modes():
    orch = make(requirement=AppRequirement(latency_budget_s=0.05,
                                           min_acc=0.75))
    orch.observe_capacity(1e6)      # mode 0 infeasible; 1 and 2 feasible
    assert orch.choose_mode() == 1  # mode 2 violates the accuracy floor
    orch2 = make(requirement=AppRequirement(latency_budget_s=0.05,
                                            min_acc=0.95))
    orch2.observe_capacity(1e9)
    # no mode meets the floor: best-effort fallback, smallest payload
    assert orch2.choose_mode() == 2


def test_per_request_links_are_isolated():
    orch = make(hysteresis=1.0)
    orch.register("edge_user")
    orch.register("center_user")
    for _ in range(5):
        orch.observe_capacity(5e4, rid="edge_user")     # 50 kB/s
        orch.observe_capacity(1e8, rid="center_user")   # 100 MB/s
    assert orch.choose_mode(rid="center_user") == 0
    assert orch.choose_mode(rid="edge_user") == 2
    # the legacy shared link is untouched by per-request traffic
    assert orch.state.ticks == 0
    orch.release("edge_user")
    assert "edge_user" not in orch._links


def test_decoder_loss_feedback_reorders_modes():
    orch = make(ema=0.0, hysteresis=1.0)
    orch.observe_capacity(1e9)              # everything feasible
    assert orch.choose_mode() == 0
    # decoder reports mode 0 regressing hard (e.g. distribution shift)
    orch.observe_decoder_loss(0, 5.0)
    assert orch.choose_mode() == 1
