"""Attention correctness: GQA vs naive reference, blocked-online-softmax vs
dense, sliding window, and decode-vs-prefill consistency."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.models.layers import apply_rope


def _naive_reference(q, k, v, window=0):
    """Materialized GQA attention with causal (+window) mask."""
    B, S, nq, hd = q.shape
    n_kv = k.shape[2]
    g = nq // n_kv
    k_rep = jnp.repeat(k, g, axis=2)
    v_rep = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bsqh,btqh->bqst", q.astype(jnp.float32),
                        k_rep.astype(jnp.float32)) / math.sqrt(hd)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqst,btqh->bsqh", probs, v_rep.astype(jnp.float32))
    return out.reshape(B, S, nq * hd)


@pytest.mark.parametrize("n_kv,window", [(2, 0), (4, 0), (1, 8), (2, 16)])
def test_gqa_matches_naive(n_kv, window):
    key = jax.random.PRNGKey(0)
    B, S, nq, hd = 2, 32, 4, 16
    q = jax.random.normal(key, (B, S, nq, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, n_kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, n_kv, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ours = A._dense_attention(q, k, v, pos, hd, window)
    ref = _naive_reference(q, k, v, window)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,window", [(2048, 0), (2048, 512), (4096, 1024)])
def test_blocked_matches_dense(S, window):
    key = jax.random.PRNGKey(0)
    B, nq, n_kv, hd = 1, 4, 2, 16
    q = 0.3 * jax.random.normal(key, (B, S, nq, hd))
    k = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, S, n_kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, n_kv, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    dense = A._dense_attention(q, k, v, pos, hd, window)
    blocked = A._blocked_attention(q, k, v, pos, hd, window)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_prefill():
    """Decoding token-by-token reproduces the full-sequence forward."""
    key = jax.random.PRNGKey(0)
    B, S, nq, n_kv, hd = 2, 12, 4, 2, 16
    d = nq * hd
    p = A.attn_init(key, d, nq, n_kv, hd, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full = A.full_attention(p, x, pos, n_q=nq, n_kv=n_kv, hd=hd,
                            rope_theta=1e4)
    cache = A.init_cache(B, n_kv, hd, cache_len=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(p, x[:, t:t + 1], cache, jnp.int32(t),
                                      n_q=nq, n_kv=n_kv, hd=hd,
                                      rope_theta=1e4)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_decode_rolling_window_cache():
    """SWA decode with a rolling cache matches full-context SWA attention."""
    key = jax.random.PRNGKey(0)
    B, S, nq, n_kv, hd, W = 1, 24, 2, 1, 8, 8
    d = nq * hd
    p = A.attn_init(key, d, nq, n_kv, hd, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full = A.full_attention(p, x, pos, n_q=nq, n_kv=n_kv, hd=hd,
                            rope_theta=1e4, window=W)
    cache = A.init_cache(B, n_kv, hd, cache_len=W, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(p, x[:, t:t + 1], cache, jnp.int32(t),
                                      n_q=nq, n_kv=n_kv, hd=hd,
                                      rope_theta=1e4, window=W)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_rope_relative_shift_invariance():
    """RoPE dot products depend only on relative position."""
    key = jax.random.PRNGKey(0)
    hd = 32
    q = jax.random.normal(key, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 1e4)
        kr = apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
