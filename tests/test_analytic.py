"""Validate the analytic FLOP model against XLA cost_analysis on configs
where XLA counts correctly (single-layer stacks: scan trip count = 1, short
sequences: dense attention path, no inner loops)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig, SplitConfig
from repro.core import split as SP
from repro.launch import analytic
from repro.models import transformer as T


def _single_layer_cfg(arch):
    cfg = get_reduced(arch)
    return dataclasses.replace(
        cfg, n_layers=1, block_pattern=(cfg.block_pattern[0],),
        split=SplitConfig(split_at=1, d_bottleneck=0))


@pytest.mark.parametrize("arch", ["stablelm-3b", "qwen2.5-3b"])
def test_analytic_fwd_flops_vs_xla(arch):
    cfg = _single_layer_cfg(arch)
    B, S = 4, 128
    params = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def fwd(p, t):
        return T.forward(p, t, cfg)[0]

    cost = jax.jit(fwd).lower(params, toks).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):   # older JAX: one dict per device
        cost = cost[0]
    xla_flops = float(cost["flops"])
    sc = ShapeConfig("tiny", seq_len=S, global_batch=B, kind="prefill")
    ours = analytic.step_flops(cfg, sc)
    # within 35% (XLA counts a few extra elementwise/softmax flops; we count
    # only matmul-class work)
    assert 0.65 < ours / xla_flops < 1.35, (ours, xla_flops)


def test_train_multiplier_about_4x_forward():
    cfg = _single_layer_cfg("stablelm-3b")
    tr = analytic.step_flops(
        cfg, ShapeConfig("t", seq_len=128, global_batch=4, kind="train"))
    fw = analytic.step_flops(
        cfg, ShapeConfig("p", seq_len=128, global_batch=4, kind="prefill"))
    assert 3.0 < tr / fw < 4.2


def test_decode_flops_scale_with_context():
    cfg = get_reduced("granite-8b")
    f1 = analytic.step_flops(
        cfg, ShapeConfig("d", seq_len=1024, global_batch=8, kind="decode"))
    f2 = analytic.step_flops(
        cfg, ShapeConfig("d", seq_len=8192, global_batch=8, kind="decode"))
    assert f2 > f1                      # attention term grows with cache
    assert f2 < 8 * f1                  # but projections/mlp dominate


def test_swa_caps_decode_flops():
    import repro.configs as RC
    mix = RC.get_config("mixtral-8x7b")
    f_short = analytic.step_flops(
        mix, ShapeConfig("d", seq_len=4096, global_batch=1, kind="decode"))
    f_long = analytic.step_flops(
        mix, ShapeConfig("d", seq_len=524_288, global_batch=1, kind="decode"))
    # window 4096 caps the attention term: long context costs the same
    assert f_long == pytest.approx(f_short, rel=1e-6)


def test_moe_flops_use_active_params():
    phi = __import__("repro.configs", fromlist=["get_config"]).get_config(
        "phi3.5-moe-42b-a6.6b")
    sc = ShapeConfig("t", seq_len=4096, global_batch=8, kind="prefill")
    ours = analytic.step_flops(phi, sc)
    toks = sc.seq_len * sc.global_batch
    dense_bound = 2 * phi.param_count() * toks
    active_bound = 2 * phi.active_param_count() * toks
    assert ours < 0.5 * dense_bound     # NOT paying for all 16 experts
    assert ours > 0.8 * active_bound    # but at least the active share
