"""Pod-pipeline correctness (runs in a subprocess with 8 forced host devices
since the main test process must keep the single-device default)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import functools
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.core import split as S, pipeline as PL
from repro.launch.mesh import mesh_context
from repro.models import transformer as T

mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
cfg = get_reduced('stablelm-3b')
params = S.init_split_params(jax.random.PRNGKey(0), cfg)
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)

with mesh_context(mesh):
    # mode 0: pipeline == monolithic forward (bf16 tolerance)
    fn0 = jax.jit(functools.partial(PL.pipeline_forward, cfg=cfg, mesh=mesh,
                                    n_micro=4, mode=0))
    lg0, _ = fn0(params, tok)
    ref0, _ = T.forward(params, tok, cfg)
    err0 = float(jnp.max(jnp.abs(lg0 - ref0)))
    assert err0 < 0.15, f'mode0 err {err0}'

    # mode 1: pipeline == split bottleneck forward
    fn1 = jax.jit(functools.partial(PL.pipeline_forward, cfg=cfg, mesh=mesh,
                                    n_micro=4, mode=1))
    lg1, _ = fn1(params, tok)
    ref1, _, _ = S.split_forward(params, tok, cfg, mode=1)
    err1 = float(jnp.max(jnp.abs(lg1 - ref1)))
    assert err1 < 0.25, f'mode1 err {err1}'

    # gradients flow through the quantized wire (STE) to BOTH stages and
    # to the bottleneck head
    def loss(params):
        lg, aux = PL.pipeline_forward(params, tok, cfg, mesh=mesh,
                                      n_micro=4, mode=1, train=True)
        return T.lm_loss(lg, tok) + 0.01 * aux
    g = jax.jit(jax.grad(loss))(params)
    def l1(t):
        return sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                   for x in jax.tree.leaves(t))
    assert l1(g['layers']) > 0
    assert l1(g['bneck_modes'][0]['down']) > 0
    assert l1(g['bneck_modes'][0]['up']) > 0

    # beyond-paper: int8 BACKWARD wire (pipeline2) — grads still flow and
    # stay close to the float-backward grads (quantized, not broken)
    def loss_q(params):
        lg, aux = PL.pipeline_forward(params, tok, cfg, mesh=mesh,
                                      n_micro=4, mode=1, train=True,
                                      bwd_bits=8)
        return T.lm_loss(lg, tok) + 0.01 * aux
    gq = jax.jit(jax.grad(loss_q))(params)
    assert l1(gq['layers']) > 0
    ref_n, q_n = l1(g['layers']), l1(gq['layers'])
    assert abs(ref_n - q_n) / max(ref_n, 1e-9) < 0.2, (ref_n, q_n)

    # int8 payload on the wire: the compiled HLO's collective-permute moves
    # s8 codes, and mode1 moves fewer bytes than mode0
    from repro.launch import roofline as R
    h0 = fn0.lower(params, tok).compile().as_text()
    h1 = fn1.lower(params, tok).compile().as_text()
    c0 = R.parse_collectives(h0)['collective-permute']
    c1 = R.parse_collectives(h1)['collective-permute']
    assert c1['bytes'] < 0.35 * c0['bytes'], (c0, c1)
    assert 's8[' in h1
print('PIPELINE_OK')
"""


@pytest.mark.slow
def test_pipeline_two_pods():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
