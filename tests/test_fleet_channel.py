"""FleetChannel vs the scalar oracle classes.

The vectorized fleet must be *decision-identical* to N independent scalar
``Channel`` / ``TraceChannel`` / ``MobilityChannel`` objects: capacities,
cell membership, detach state, and handover events all match bit-for-bit,
whether lanes step together (``step_all``) or raggedly (per-lane ``step``).
Plus hypothesis property tests that the counter-based RNG never shares
state across UEs: a lane's realization depends only on its own key — not
on fleet size, not on stepping order.
"""
import numpy as np
import pytest

from repro.core.channel import (Channel, ChannelConfig, FleetChannel,
                                MobilityChannel, TraceChannel, channel_fleet,
                                city_grid_cells, is_mobile)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                          # container may lack hypothesis
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=25, deadline=None)

CFG = ChannelConfig(mean_mbps=80.0, std_mbps=30.0, blockage_prob=0.10,
                    recovery_prob=0.3, min_mbps=2.0)


def _scalar_traj(ch, n_ticks):
    return np.array([ch.step() for _ in range(n_ticks)])


# ---------------------------------------------------------------------------
# oracle identity
# ---------------------------------------------------------------------------

def test_fade_fleet_matches_channel_fleet_exactly():
    n, T = 9, 64
    fleet = FleetChannel(n, CFG, seed=7)
    scalars = channel_fleet(n, CFG, seed=7)
    got = np.stack([fleet.step_all() for _ in range(T)]).T
    want = np.stack([_scalar_traj(c, T) for c in scalars])
    assert np.array_equal(got, want)


def test_fade_lanes_match_scalars_under_ragged_stepping():
    n, T = 6, 48
    fleet = FleetChannel(n, CFG, seed=2)
    scalars = channel_fleet(n, CFG, seed=2)
    want = np.stack([_scalar_traj(c, T) for c in scalars])
    # interleave lanes in an adversarial order: lane i advances at a
    # different rate, exactly like engine slots admitted at different ticks
    cursors = np.zeros(n, int)
    rng = np.random.default_rng(0)
    got = np.zeros((n, T))
    while (cursors < T).any():
        i = int(rng.choice(np.flatnonzero(cursors < T)))
        got[i, cursors[i]] = fleet.lane(i).step()
        cursors[i] += 1
    assert np.array_equal(got, want)


def test_trace_fleet_matches_trace_channels():
    rng = np.random.default_rng(3)
    traces = np.abs(rng.normal(1e8, 3e7, size=(5, 12)))
    for cycle in (False, True):
        fleet = FleetChannel(5, traces_bps=traces, cycle=cycle)
        scalars = [TraceChannel(traces[i], cycle=cycle) for i in range(5)]
        got = np.stack([fleet.step_all() for _ in range(30)]).T
        want = np.stack([_scalar_traj(c, 30) for c in scalars])
        assert np.array_equal(got, want)


def test_mobility_fleet_matches_mobility_channels():
    n, T, n_cells = 6, 40, 3
    cells = city_grid_cells(n, T, n_cells, seed=5, dwell_ticks=5)
    caps = [4e8, 2e8, 1e8]
    fleet = FleetChannel(n, cells=cells, cell_caps_bps=caps,
                         detach_factor=0.1)
    scalars = [MobilityChannel(cells[i], caps, detach_factor=0.1)
               for i in range(n)]
    for i in range(n):
        fleet.lane(i).serving_cell = 0
        scalars[i].serving_cell = 0
    for t in range(T):
        got = [fleet.lane(i).step() for i in range(n)]
        want = [c.step() for c in scalars]
        assert got == want, f"capacity diverged at tick {t}"
        for i in range(n):
            assert fleet.lane(i).pending_handover == \
                scalars[i].pending_handover
            assert fleet.lane(i).detached == scalars[i].detached
            assert fleet.lane(i).current_cell == scalars[i].current_cell
            assert fleet.lane(i).last_cell == scalars[i].last_cell
        if t in (9, 23):                    # serving side re-homes mid-run
            for i in range(n):
                fleet.lane(i).ack_handover(scalars[i].last_cell)
                scalars[i].ack_handover(scalars[i].last_cell)
    for i in range(n):
        assert fleet.lane(i).handover_ticks == scalars[i].handover_ticks
        assert fleet.lane(i).handover_latencies == \
            scalars[i].handover_latencies


def test_city_replay_mode_traces_plus_cells():
    """traces_bps + cells (no scalar oracle): capacity comes from the
    trace, mobility only applies the detach throttle."""
    rng = np.random.default_rng(1)
    traces = np.abs(rng.normal(1e8, 1e7, size=(4, 20)))
    cells = city_grid_cells(4, 20, 2, seed=2, dwell_ticks=3)
    fleet = FleetChannel(4, traces_bps=traces, cells=cells,
                         detach_factor=0.5)
    for i in range(4):
        fleet.lane(i).serving_cell = int(cells[i, 0])
    got = np.stack([fleet.step_all() for _ in range(20)]).T
    detached = cells != cells[:, :1]       # serving stays the start cell
    want = np.where(detached, np.maximum(traces * 0.5, 1.0), traces)
    assert np.array_equal(got, want)
    assert is_mobile(fleet.lane(0))


def test_lane_peek_is_pure_and_matches_next_step():
    fleet = FleetChannel(4, CFG, seed=11)
    for i in range(4):
        p1, p2 = fleet.lane(i).peek(), fleet.lane(i).peek()
        assert p1 == p2                     # no state advance
        assert fleet.lane(i).step() == p1   # preview == delivery


def test_is_mobile_dispatch():
    assert not is_mobile(Channel())
    assert not is_mobile(TraceChannel([1.0]))
    assert is_mobile(MobilityChannel([0, 1], [1e8, 2e8]))
    fade = FleetChannel(2, CFG, seed=0)
    assert not is_mobile(fade.lane(0))
    mob = FleetChannel(2, cells=np.zeros((2, 4), int),
                       cell_caps_bps=[1e8])
    assert is_mobile(mob.lane(0))


def test_constructor_validation():
    with pytest.raises(ValueError):
        FleetChannel(0, CFG)
    with pytest.raises(ValueError):
        FleetChannel(2, traces_bps=np.ones((3, 4)))    # n mismatch
    with pytest.raises(ValueError):
        FleetChannel(2, cell_caps_bps=[1e8])           # caps without cells
    with pytest.raises(ValueError):
        FleetChannel(2, cells=np.ones((2, 3), int),
                     cell_caps_bps=[1e8])              # cell 1, one cap
    with pytest.raises(ValueError):
        FleetChannel(2, traces_bps=np.ones((2, 4)),
                     cells=np.zeros((2, 4), int), cell_caps_bps=[1e8])


# ---------------------------------------------------------------------------
# RNG independence properties (hypothesis-fuzzed when available, otherwise a
# deterministic seed sweep so the invariants are still exercised)
# ---------------------------------------------------------------------------

def _check_prefix_stable(seed, n, ticks):
    """UE i's realization must depend only on its own key: growing the
    fleet (same seed) never perturbs existing lanes' streams."""
    small = FleetChannel(n, CFG, seed=seed)
    large = FleetChannel(n + 5, CFG, seed=seed)
    a = np.stack([small.step_all() for _ in range(ticks)])
    b = np.stack([large.step_all() for _ in range(ticks)])
    assert np.array_equal(a, b[:, :n])


def _check_no_shared_state(seed, n, ticks):
    """Vectorized stepping never shares RNG state across UEs: every pair
    of lanes realizes a different stream, and each lane's stream is
    reproducible in isolation (stepping order independence)."""
    fleet = FleetChannel(n, CFG, seed=seed)
    caps = np.stack([fleet.step_all() for _ in range(ticks)]).T  # [n, T]
    for i in range(n):
        for j in range(i + 1, n):
            assert not np.array_equal(caps[i], caps[j]), \
                f"lanes {i} and {j} share a realization"
    # re-run ONLY lane n-1, alone, in its own fleet: identical stream
    solo = FleetChannel(n, CFG, seed=seed)
    alone = np.array([solo.lane(n - 1).step() for _ in range(ticks)])
    assert np.array_equal(alone, caps[n - 1])


def _check_deterministic_positive(seed, n, ticks):
    f1 = FleetChannel(n, CFG, seed=seed)
    f2 = FleetChannel(n, CFG, seed=seed)
    a = np.stack([f1.step_all() for _ in range(ticks)])
    b = np.stack([f2.step_all() for _ in range(ticks)])
    assert np.array_equal(a, b)
    assert (a > 0).all()


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 20), st.integers(2, 12), st.integers(4, 32))
    @settings(**SETTINGS)
    def test_fleet_streams_are_prefix_stable_in_fleet_size(seed, n, ticks):
        _check_prefix_stable(seed, n, ticks)

    @given(st.integers(0, 2 ** 20), st.integers(2, 10), st.integers(8, 48))
    @settings(**SETTINGS)
    def test_no_rng_state_shared_across_ues(seed, n, ticks):
        _check_no_shared_state(seed, n, ticks)

    @given(st.integers(0, 2 ** 16), st.integers(2, 8), st.integers(4, 24))
    @settings(**SETTINGS)
    def test_fleet_deterministic_and_positive(seed, n, ticks):
        _check_deterministic_positive(seed, n, ticks)
else:
    SEEDS = [0, 1, 7, 12345, 999983, 2 ** 20 - 1]

    @pytest.mark.parametrize("seed", SEEDS)
    def test_fleet_streams_are_prefix_stable_in_fleet_size(seed):
        _check_prefix_stable(seed, n=7, ticks=24)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_no_rng_state_shared_across_ues(seed):
        _check_no_shared_state(seed, n=6, ticks=32)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_fleet_deterministic_and_positive(seed):
        _check_deterministic_positive(seed, n=5, ticks=16)
