"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import TrainConfig
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import Channel, ChannelConfig
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.data import tokens
from repro.serving.engine import ServingEngine
from repro.training import loop as L


def test_tiny_transformer_training_improves():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    src = tokens.MarkovTokenSource(cfg, alphabet=32)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40)
    params, hist = L.train_loop(params, cfg, tcfg,
                                lambda s: src.batch(8, 16, s), steps=40,
                                log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_split_cascade_training_transformer():
    """Algorithm 1 on a reduced transformer: phase 2 trains the bottleneck
    to usable quality while the base stays frozen."""
    from repro.core import cascade as C
    cfg = get_reduced("stablelm-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    src = tokens.MarkovTokenSource(cfg, alphabet=16)

    def loss_fn(params, batch, mode):
        logits, aux, _ = SP.split_forward(params, batch["tokens"], cfg,
                                          mode, train=True)
        from repro.models.transformer import lm_loss
        loss = lm_loss(logits, batch["labels"])
        return loss + 0.01 * aux, {"acc": jnp.mean(
            jnp.argmax(logits, -1) == batch["labels"])}

    def data_iter(step):
        return {k: jnp.asarray(v) for k, v in src.batch(8, 16, step).items()}

    eval_b = data_iter(9999)

    def eval_fn(params, mode):
        loss, m = loss_fn(params, eval_b, mode)
        return {"loss": loss, "acc": m["acc"]}

    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=120,
                       weight_decay=0.0)
    params, hist = C.train_cascade(
        params, loss_fn, data_iter, tcfg, n_modes=2, steps_per_phase=60,
        eval_fn=eval_fn, verbose=False)
    p1, p2 = hist["phases"]
    assert p1["log"][-1]["loss"] < p1["log"][0]["loss"]
    assert p2["log"][-1]["loss"] < p2["log"][0]["loss"] + 0.05
    assert hist["ensure"]["losses"][1] >= hist["ensure"]["losses"][0] - 0.05


def test_orchestrator_switches_under_blockage():
    """When the simulated mmWave link drops into NLoS, the orchestrator must
    fall back to the compressed mode, and recover afterwards."""
    cfg = get_reduced("granite-8b")
    payload0 = BN.mode_payload_bytes(cfg, 4, 128, 0)    # a 128-token query
    payload1 = BN.mode_payload_bytes(cfg, 4, 128, 1)
    profiles = [ModeProfile(0, payload0, 1.0), ModeProfile(1, payload1, 1.3)]
    orch = Orchestrator(profiles, AppRequirement(latency_budget_s=0.02),
                        hysteresis=1.0)
    ch = Channel(ChannelConfig(mean_mbps=80.0, std_mbps=10.0,
                               blockage_prob=0.0, seed=1))
    modes = []
    for t in range(60):
        ch.blocked = 20 <= t < 40       # scripted blockage window
        orch.observe_capacity(ch.step())
        modes.append(orch.choose_mode())
    assert set(modes[5:20]) == {0}           # LoS: full code
    assert 1 in set(modes[20:40])            # blockage: compressed code
    assert modes[-1] == 0                    # recovery
    assert orch.state.switches >= 2


def test_split_serving_counts_wire_bytes():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    profiles = [ModeProfile(0, BN.mode_payload_bytes(cfg, 1, 1, 0), 1.0),
                ModeProfile(1, BN.mode_payload_bytes(cfg, 1, 1, 1), 1.2)]
    orch = Orchestrator(profiles, AppRequirement(latency_budget_s=1.0))
    eng = ServingEngine(params, cfg, cache_len=16, batch=2,
                        orchestrator=orch)
    eng.prefill(jnp.ones((2, 2), jnp.int32))
    eng.decode_tokens(jnp.ones((2, 1), jnp.int32), 6,
                      capacity_bps_fn=lambda: 1e9)
    assert eng.stats.tokens == 12          # 2 requests x 6 decode steps
    assert eng.stats.wire_bytes > 0
    assert sum(eng.stats.mode_counts.values()) == 6   # one decision per step
