"""Mesh-sharded serving: dp slot/page pools + mp heads, bit-identical.

The headline pin: a ``ContinuousBatchingEngine`` on a ``('dp','mp')``
serving mesh must produce **token-bit-identical** streams to the
single-device (``mesh=None``) engine — same tokens, same wire bytes,
same per-mode counts, same finished ticks — for the attention family and
one recurrent family, under both the host-driven and device-resident
loops, dense and paged pools. Data-parallel slot sharding carries a hard
bit-exactness guarantee (the boundary runs in a fully-replicated
shard_map region; see ``docs/sharding.md``). Tensor parallelism over
``mp`` reassociates reductions and is pinned to *schedule/accounting*
equality instead — numerically equivalent, not bit-exact.

Migration must be mesh-blind: a snapshot extracted from a sharded engine
is bit-identical to one from an unsharded engine, and a live migration
between two sharded replicas on *disjoint device subsets* resumes the
exact unmigrated stream.

Mesh tests skip unless >= 8 devices are visible — CI runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax import, so it cannot be applied from inside this file).
Validation tests run on any device count.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.core.channel import MobilityChannel
from repro.models import sharding
from repro.models.sharding import serving_mesh
from repro.serving import (ContinuousBatchingEngine, EdgeCluster,
                           PagedPool, Request, SlotPool,
                           default_orchestrator, extract_session)

NEED8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ARCHS = ["qwen2.5-3b", "recurrentgemma-2b"]


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_reduced(arch)
        out[arch] = (cfg, SP.init_split_params(jax.random.PRNGKey(0), cfg))
    return out


def _reqs(cfg, n=6, gen=12, seed=0, channel=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        (5 + i % 3,)).astype(np.int32),
                    max_new_tokens=gen,
                    channel=channel(i) if channel else None)
            for i in range(n)]


def _run(cfg, params, mesh, *, host_loop=False, paged=None, n=6):
    eng = ContinuousBatchingEngine(
        params, cfg, n_slots=4, cache_len=48,
        orchestrator=default_orchestrator(cfg), host_loop=host_loop,
        mesh=mesh, paged=paged)
    with eng:
        done = eng.run(_reqs(cfg, n=n))
    return {s.request.rid: (tuple(s.tokens), s.wire_bytes,
                            tuple(sorted(s.mode_counts.items())),
                            s.finished_tick) for s in done}


# ---------------------------------------------------------------------------
# sharded-vs-unsharded bit identity
# ---------------------------------------------------------------------------

@NEED8
@pytest.mark.parametrize("host_loop", [True, False],
                         ids=["host", "device"])
@pytest.mark.parametrize("arch", ARCHS)
def test_dp_sharded_stream_bit_identical(arch, host_loop, models):
    """Every dp factor of the slot pool decodes the exact mesh=None
    stream — tokens, wire bytes, mode counts, finished ticks."""
    cfg, params = models[arch]
    base = _run(cfg, params, None, host_loop=host_loop)
    for dp in (2, 4):
        got = _run(cfg, params, serving_mesh(dp, 1), host_loop=host_loop)
        assert got == base, (arch, host_loop, dp)


@NEED8
def test_dp8_full_mesh_bit_identical(models):
    """dp=8: one slot-shard per device (n_slots=4 < dp — the slot axis
    does not divide, the spec is dropped, and the run must STILL be
    bit-identical rather than crash or diverge)."""
    cfg, params = models["qwen2.5-3b"]
    base = _run(cfg, params, None)
    assert _run(cfg, params, serving_mesh(8, 1)) == base


@NEED8
def test_dp_mp_mesh_completes_same_schedule(models):
    """The full ('dp','mp') = (4,2) mesh: tensor parallelism over mp
    reassociates head/FFN reductions, so token bits may legitimately
    differ at greedy-argmax ties (bit-identity is the dp guarantee, not
    the mp one — see docs/sharding.md). What must hold: every request
    completes its full budget on the same tick schedule with identical
    wire-byte and per-mode accounting."""
    cfg, params = models["qwen2.5-3b"]
    base = _run(cfg, params, None)
    got = _run(cfg, params, serving_mesh(4, 2))
    assert set(got) == set(base)
    for rid in base:
        b_tok, b_wire, b_modes, b_tick = base[rid]
        g_tok, g_wire, g_modes, g_tick = got[rid]
        assert len(g_tok) == len(b_tok)
        assert (g_wire, g_modes, g_tick) == (b_wire, b_modes, b_tick)


@NEED8
@pytest.mark.parametrize("dp", [2, 8])
def test_paged_pool_sharded_bit_identical(dp, models):
    """Paged pools: the block-table arena shards over dp (page count
    padded to divide) and streams stay bit-identical to both the
    unsharded paged AND dense engines."""
    cfg, params = models["qwen2.5-3b"]
    dense = _run(cfg, params, None)
    base = _run(cfg, params, None, paged=True)
    assert base == dense
    assert _run(cfg, params, serving_mesh(dp, 1), paged=True) == base


# ---------------------------------------------------------------------------
# migration is mesh-blind
# ---------------------------------------------------------------------------

def _mobility(cross_at, *, n_ticks=64, cap=2e6):
    cells = [0] * cross_at + [1] * n_ticks
    return MobilityChannel(cells, [cap, cap], detach_factor=1.0)


@NEED8
@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_migration_round_trip(arch, models):
    """Live migration between two sharded replicas on DISJOINT device
    subsets decodes exactly what an unsharded single engine decodes."""
    cfg, params = models[arch]

    def reqs():
        rng = np.random.default_rng(3)
        return [Request(rid=0,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            (4,)).astype(np.int32),
                        max_new_tokens=12, channel=_mobility(5))]

    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=48,
                                   orchestrator=default_orchestrator(cfg))
    with eng:
        base = {s.request.rid: s for s in eng.run(reqs())}

    cluster = EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                          cache_len=48, placement="best-channel",
                          handover="migrate", dp=2)
    meshes = [e.mesh for e in cluster.replicas]
    assert all(m is not None for m in meshes)
    # replicas own disjoint device subsets of the same process
    devs = [set(d.id for d in m.devices.flat) for m in meshes]
    assert devs[0].isdisjoint(devs[1])
    got = {s.request.rid: s for s in cluster.run(reqs())}
    st = cluster.stats()
    cluster.close()

    assert st["migrations"] == 1
    assert got[0].tokens == base[0].tokens
    assert got[0].mode_counts == base[0].mode_counts
    assert got[0].wire_bytes == base[0].wire_bytes


@NEED8
def test_snapshot_wire_bits_mesh_invariant(models):
    """``extract_session`` from a sharded engine serializes the exact
    bytes the unsharded engine serializes: the snapshot wire format (and
    therefore resume behavior) is independent of device placement."""
    cfg, params = models["qwen2.5-3b"]

    def engine(mesh):
        # host loop: one tick per step, so the session is deterministically
        # live (and at the same position) when the snapshot is taken
        return ContinuousBatchingEngine(
            params, cfg, n_slots=2, cache_len=48,
            orchestrator=default_orchestrator(cfg), host_loop=True,
            mesh=mesh)

    def snap_after(mesh, n_steps=5):
        eng = engine(mesh)
        with eng:
            rng = np.random.default_rng(9)
            eng.submit(Request(
                rid=0,
                prompt=rng.integers(1, cfg.vocab_size, (4,)).astype(np.int32),
                max_new_tokens=20))
            for _ in range(n_steps):
                eng.step()
            return extract_session(eng, rid=0)

    a = snap_after(None)
    b = snap_after(serving_mesh(4, 1))
    assert a.position == b.position
    np.testing.assert_array_equal(a.cur_token, b.cur_token)
    assert len(a.wire) == len(b.wire)
    for ea, eb in zip(a.wire, b.wire):
        assert ea[0] == eb[0] == "raw"
        np.testing.assert_array_equal(ea[1], eb[1])


# ---------------------------------------------------------------------------
# pool placement + padding mechanics
# ---------------------------------------------------------------------------

@NEED8
def test_pool_states_carry_dp_sharding(models):
    """SlotPool leaves actually land sharded: slot axis -> 'dp' whenever
    it divides, and gathered migration rows stay host-addressable."""
    cfg, _ = models["qwen2.5-3b"]
    mesh = serving_mesh(4, 1)
    pool = SlotPool(cfg, n_slots=4, cache_len=16, mesh=mesh)
    specs = jax.tree.leaves(
        jax.tree.map(lambda a: a.sharding.spec, pool.states))
    assert any(len(s) > 1 and s[1] == "dp"
               for s in specs)                     # slot axis is axis 1
    rows = pool.read_rows([2, 0])
    for leaf in jax.tree.leaves(rows):
        np.asarray(leaf)                           # host-addressable


@NEED8
def test_paged_arena_padded_to_dp(models):
    """The paged arena's natural page count (n_pages+1, usually odd) is
    padded up to a dp-divisible count; the free list never hands out the
    padding pages."""
    cfg, _ = models["qwen2.5-3b"]
    mesh = serving_mesh(8, 1)
    pool = PagedPool(cfg, n_slots=4, cache_len=32, mesh=mesh)
    ref = PagedPool(cfg, n_slots=4, cache_len=32)
    arena_pages = jax.tree.leaves(pool.states)[0].shape[1]
    assert arena_pages % 8 == 0
    assert pool.n_pages == ref.n_pages            # allocatable pages equal
    assert len(pool._free_pages) == len(ref._free_pages)


# ---------------------------------------------------------------------------
# validation (no mesh needed — run on any device count)
# ---------------------------------------------------------------------------

def test_serving_mesh_validates_axes():
    with pytest.raises(ValueError):
        serving_mesh(0, 1)
    with pytest.raises(ValueError):
        serving_mesh(1, -2)


def test_serving_mesh_device_count_error_mentions_flag():
    with pytest.raises(ValueError, match="host_platform_device_count"):
        serving_mesh(4096, 1)


def test_cluster_rejects_oversubscribed_mesh(models):
    cfg, params = models["qwen2.5-3b"]
    with pytest.raises(ValueError, match="device"):
        EdgeCluster(params, cfg, n_replicas=2, n_slots=2, cache_len=32,
                    dp=4096)
