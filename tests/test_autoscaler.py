"""Autoscaler unit tests + elastic-cluster integration.

The pure controller: scale-up fires only on SUSTAINED pressure, decisions
are deterministic, cooldown and min/max clamps hold. The cluster side:
scale-down retires a replica without stranding its live sessions (they
drain out through the migration path and still finish), and scale-up
reuses the module-level compiled-step cache so adding a replica never
pays an XLA recompile.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.serving import (Autoscaler, AutoscalerConfig, EdgeCluster,
                           Request)
from repro.serving.batcher import _compiled_steps

ARCH = "qwen2.5-3b"


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced(ARCH)
    return cfg, SP.init_split_params(jax.random.PRNGKey(0), cfg)


def _prompt(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# pure controller logic
# ---------------------------------------------------------------------------

def test_scale_up_requires_sustained_pressure():
    a = Autoscaler(AutoscalerConfig(sustain_ticks=3, cooldown_ticks=5))
    # two hot ticks then cool: no decision (transient spike damped)
    assert a.observe(n_replicas=1, occupancy=0.95) == 0
    assert a.observe(n_replicas=1, occupancy=0.95) == 0
    assert a.observe(n_replicas=1, occupancy=0.1) == 0
    # the EMA cools slowly; keep feeding idle until pressure clears, then
    # three consecutive hot ticks fire exactly one scale-up
    for _ in range(10):
        a.observe(n_replicas=1, occupancy=0.0)
    a2 = Autoscaler(AutoscalerConfig(sustain_ticks=3, cooldown_ticks=5))
    got = [a2.observe(n_replicas=1, occupancy=0.95) for _ in range(3)]
    assert got == [0, 0, 1]
    assert a2.events[-1][1] == +1


def test_queue_and_miss_pressure_also_fire():
    for kw, reason in ((dict(occupancy=0.1, queue_per_slot=5.0), "queue"),
                       (dict(occupancy=0.1, miss_rate=0.5), "miss_rate")):
        a = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=2))
        got = [a.observe(n_replicas=1, **kw) for _ in range(2)]
        assert got == [0, 1]
        assert a.events[-1][2] == reason


def test_cooldown_suppresses_consecutive_decisions():
    a = Autoscaler(AutoscalerConfig(sustain_ticks=1, cooldown_ticks=4))
    got = [a.observe(n_replicas=1, occupancy=0.99) for _ in range(10)]
    # one decision, then >= cooldown_ticks of silence before the next
    ups = [i for i, d in enumerate(got) if d == 1]
    assert len(ups) >= 2
    assert ups[1] - ups[0] > 4


def test_scale_down_on_sustained_idle_and_min_clamp():
    a = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=0,
                                    min_replicas=2))
    got = [a.observe(n_replicas=3, occupancy=0.0) for _ in range(2)]
    assert got == [0, -1]
    # at min_replicas: never goes lower
    b = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=0,
                                    min_replicas=2))
    assert all(b.observe(n_replicas=2, occupancy=0.0) == 0
               for _ in range(10))


def test_max_clamp():
    a = Autoscaler(AutoscalerConfig(sustain_ticks=1, cooldown_ticks=0,
                                    max_replicas=2))
    assert all(a.observe(n_replicas=2, occupancy=0.99) == 0
               for _ in range(10))


def test_relaxation_requires_all_signals_quiet():
    a = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=0))
    # idle occupancy but a backlog: not a scale-down candidate
    got = [a.observe(n_replicas=2, occupancy=0.0, queue_per_slot=0.5)
           for _ in range(6)]
    assert all(d == 0 for d in got)


def test_decisions_deterministic():
    rng = np.random.default_rng(3)
    obs = [dict(n_replicas=2, occupancy=float(o), queue_per_slot=float(q),
                miss_rate=float(m))
           for o, q, m in zip(rng.uniform(0, 1, 64),
                              rng.uniform(0, 2, 64),
                              rng.uniform(0, 0.2, 64))]
    a = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=3))
    b = Autoscaler(AutoscalerConfig(sustain_ticks=2, cooldown_ticks=3))
    assert [a.observe(**o) for o in obs] == [b.observe(**o) for o in obs]
    assert a.events == b.events


def test_config_validation():
    with pytest.raises(ValueError):
        Autoscaler(AutoscalerConfig(min_replicas=0))
    with pytest.raises(ValueError):
        Autoscaler(AutoscalerConfig(min_replicas=3, max_replicas=2))


# ---------------------------------------------------------------------------
# elastic cluster integration
# ---------------------------------------------------------------------------

def test_scale_up_reuses_compiled_steps(model):
    cfg, params = model
    with EdgeCluster(params, cfg, n_replicas=1, n_slots=2,
                     cache_len=32) as cluster:
        cluster.warm(_prompt(cfg))
        info = _compiled_steps.cache_info()
        idx = cluster.scale_up()
        after = _compiled_steps.cache_info()
        # the new replica's engine construction must HIT the module-level
        # cache (same cfg/cache_len/mesh key): no new compile entry
        assert after.misses == info.misses
        assert after.hits > info.hits
        assert idx == 1 and cluster.n_live == 2
        # and it serves: run a request routed to the new replica
        done = cluster.run([Request(rid=0, prompt=_prompt(cfg),
                                    max_new_tokens=4)])
        assert len(done) == 1 and len(done[0].tokens) == 4


def test_scale_down_drains_via_migration_without_stranding(model):
    cfg, params = model
    with EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                     cache_len=64, max_window=2) as cluster:
        cluster.warm(_prompt(cfg))
        reqs = [Request(rid=i, prompt=_prompt(cfg, seed=i),
                        max_new_tokens=12) for i in range(4)]
        for r in reqs:
            cluster.submit(r)
        # let sessions start decoding on both replicas (window capped at 2
        # ticks so the 12-token budgets are still mid-flight here)
        for _ in range(2):
            cluster.step()
        assert any(cluster.replicas[1].active.values())
        retired = cluster.scale_down(1)
        assert retired == 1 and 1 in cluster.retired
        done = cluster.run([])               # drain to completion
        assert len(done) == 4                # nobody stranded
        assert cluster.replicas[1].active == {}
        assert cluster.migrations >= 1       # drained THROUGH migration
        migrated = [s for s in done
                    if any(m["from_replica"] == 1 for m in s.migrations)]
        assert migrated, "retired replica's sessions must have moved"
        for s in done:
            assert len(s.tokens) == 12
        st = cluster.stats()
        c = st["conservation"]
        assert c["submitted"] == c["finished"] == 4
        assert c["in_flight"] == 0


def test_retired_replica_gets_no_new_work(model):
    cfg, params = model
    with EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                     cache_len=32) as cluster:
        cluster.scale_down(0)
        for i in range(4):
            cluster.submit(Request(rid=i, prompt=_prompt(cfg, seed=i),
                                   max_new_tokens=3))
        assert cluster._load(cluster.replicas[0]) == 0
        assert cluster._load(cluster.replicas[1]) == 4
        done = cluster.run([])
        assert len(done) == 4


def test_scale_up_revives_drained_retired_replica(model):
    cfg, params = model
    with EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                     cache_len=32) as cluster:
        assert cluster.scale_down(1) == 1
        # empty retired replica revives in place of building a third engine
        assert cluster.scale_up() == 1
        assert cluster.retired == set()
        assert len(cluster.replicas) == 2


def test_cluster_autoscales_under_load(model):
    """End-to-end determinism: a seeded fleet through an autoscaled
    cluster produces identical scale events and token streams run-to-run,
    and the autoscaler actually grows the cluster under backlog."""
    cfg, params = model

    def _run():
        auto = Autoscaler(AutoscalerConfig(
            max_replicas=3, sustain_ticks=2, cooldown_ticks=4,
            high_occupancy=0.7))
        cluster = EdgeCluster(params, cfg, n_replicas=1, n_slots=2,
                              cache_len=32, autoscaler=auto,
                              max_pending=64)
        with cluster:
            cluster.warm(_prompt(cfg))
            reqs = [Request(rid=i, prompt=_prompt(cfg, seed=i),
                            max_new_tokens=6, arrival_tick=i // 4)
                    for i in range(12)]
            done = cluster.run_paced(reqs)
            return (sorted((s.request.rid, tuple(s.tokens)) for s in done),
                    list(cluster.scale_events), cluster.stats())

    t1, ev1, st1 = _run()
    t2, ev2, st2 = _run()
    assert t1 == t2
    assert ev1 == ev2
    assert st1["scale_ups"] >= 1
    assert len(t1) == 12
    assert st1["conservation"]["in_flight"] == 0
