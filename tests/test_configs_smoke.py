"""Per-architecture smoke tests: a REDUCED variant of each assigned family
runs one forward + one train step + one decode step on CPU with correct
shapes and no NaNs (the FULL configs are exercised via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import TrainConfig
from repro.core import split as SP
from repro.data.tokens import make_batch
from repro.models import transformer as T
from repro.training import loop as L
from repro.training import optimizer as opt


def _batch(cfg, B=2, S=16, kind="train"):
    b = make_batch(cfg, B, S, kind)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch["tokens"], cfg, train=False,
                            embeddings=batch.get("embeddings"))
    # vision archs prepend the (stubbed) patch-embedding prefix
    S_out = batch["tokens"].shape[-1] + (
        cfg.n_vision_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[-2] == S_out
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(L.make_train_step(cfg, tcfg))
    state = opt.init(params)
    batch = _batch(cfg)
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(1), cfg)
    B = 2
    states = T.init_decode_state(cfg, B, cache_len=32)
    tok = (jnp.zeros((B, cfg.n_codebooks, 1), jnp.int32)
           if cfg.frontend == "audio" else jnp.zeros((B, 1), jnp.int32))
    logits, new_states = T.decode_step(params, tok, states, jnp.int32(3), cfg)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))
    # state actually written
    changed = any(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(states), jax.tree.leaves(new_states))
        if a.dtype != jnp.bool_)
    assert changed


def test_full_configs_match_assignment():
    spec = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L_, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L_, d, h, kv, ff, v), arch


def test_moe_configs():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    mix = get_config("mixtral-8x7b")
    assert (phi.n_experts, phi.experts_per_tok) == (16, 2)
    assert (mix.n_experts, mix.experts_per_tok) == (8, 2)
    assert mix.sliding_window == 4096
    # active-param accounting: phi ~6.6B active of ~42B
    assert 5e9 < phi.active_param_count() < 8e9
    assert 38e9 < phi.param_count() < 46e9
