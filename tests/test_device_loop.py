"""Device-resident decode loop vs the legacy host loop.

The continuous-batching engine's default tick keeps tokens and positions on
device (argmax + feedback + position increment fused into the jitted step,
pool state donated) and materializes each tick's token values one tick
late, overlapping the host sync with the next tick's device compute.
``host_loop=True`` preserves the pre-device-loop engine verbatim; these
tests pin the two loops token-identical — same decoded streams, same mode
decisions, same wire accounting, same tick counts — across every decode
state family (attention KV, Griffin rglru + rolling window, xLSTM).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import ChannelConfig, channel_fleet
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.serving import ContinuousBatchingEngine, Request

ARCHS = ["qwen2.5-3b", "recurrentgemma-2b", "xlstm-125m"]


def _requests(cfg, n, *, seed=3, gen_lo=2, gen_hi=8):
    chans = channel_fleet(
        n, ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                         recovery_prob=0.15),
        seed=11, mean_spread=0.95)
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=4).astype(np.int32),
                    max_new_tokens=int(rng.integers(gen_lo, gen_hi)),
                    channel=chans[i], arrival_tick=i // 2)
            for i in range(n)]


def _orch(cfg):
    return Orchestrator(
        [ModeProfile(m, BN.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=0.006), ema=0.5, hysteresis=1.0)


def _run(params, cfg, host_loop: bool, *, fused_tail: bool = True):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, cache_len=32,
                                   orchestrator=_orch(cfg),
                                   host_loop=host_loop,
                                   fused_tail=fused_tail)
    done = eng.run(_requests(cfg, 10))
    st = eng.stats()
    assert eng.pool.n_free == eng.pool.n_slots
    return done, st


@pytest.mark.parametrize("arch", ARCHS)
def test_device_loop_token_identical_to_host_loop(arch):
    """Same requests, same channels: the device-resident loop must decode
    the exact token stream the host loop decodes, per request — and make
    the same per-tick mode decisions with the same wire/transfer
    accounting (retirement is budget-driven, so the one-tick-lagged value
    sync may not change any lifecycle decision)."""
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    host_done, host_st = _run(params, cfg, host_loop=True)
    dev_done, dev_st = _run(params, cfg, host_loop=False)

    host = {s.request.rid: s for s in host_done}
    dev = {s.request.rid: s for s in dev_done}
    assert host.keys() == dev.keys() and len(host) == 10
    for rid in host:
        assert host[rid].tokens == dev[rid].tokens, rid
        assert host[rid].mode_counts == dev[rid].mode_counts, rid
        assert host[rid].wire_bytes == dev[rid].wire_bytes, rid
        assert host[rid].admitted_tick == dev[rid].admitted_tick, rid
        assert host[rid].finished_tick == dev[rid].finished_tick, rid
    for k in ["decode_ticks", "mixed_mode_ticks", "wire_bytes",
              "prefill_calls", "mode_counts", "generated_tokens",
              "mode_switches", "deadline_misses"]:
        assert host_st[k] == dev_st[k], k


@pytest.mark.parametrize("arch", ARCHS)
def test_megakernel_loop_token_identical_to_legacy_window_loop(arch):
    """The fused decode tail (``fused_tail=True``: norm + LM-head gather +
    argmax + token feedback inside the scan body, one tail kernel per tick
    on TPU) must decode the exact streams the pre-megakernel window loop
    (``fused_tail=False``: full-vocab logits returned, argmax in the scan
    body) decodes — same tokens, modes, wire accounting, tick lifecycle —
    across attention, rglru and xLSTM decode-state families."""
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    legacy_done, legacy_st = _run(params, cfg, host_loop=False,
                                  fused_tail=False)
    fused_done, fused_st = _run(params, cfg, host_loop=False,
                                fused_tail=True)

    legacy = {s.request.rid: s for s in legacy_done}
    fused = {s.request.rid: s for s in fused_done}
    assert legacy.keys() == fused.keys() and len(legacy) == 10
    for rid in legacy:
        assert legacy[rid].tokens == fused[rid].tokens, rid
        assert legacy[rid].mode_counts == fused[rid].mode_counts, rid
        assert legacy[rid].wire_bytes == fused[rid].wire_bytes, rid
        assert legacy[rid].admitted_tick == fused[rid].admitted_tick, rid
        assert legacy[rid].finished_tick == fused[rid].finished_tick, rid
    for k in ["decode_ticks", "mixed_mode_ticks", "wire_bytes",
              "prefill_calls", "mode_counts", "generated_tokens",
              "mode_switches", "deadline_misses"]:
        assert legacy_st[k] == fused_st[k], k


def test_device_loop_budget_one_and_tick_exhaustion():
    """Edge cases of the lagged pipeline: budget-1 sessions complete inside
    their own prefill (never entering the decode pipeline), and a
    tick-budget-exhausted ``run`` still materializes the final dispatched
    tick's tokens instead of dropping them."""
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   orchestrator=_orch(cfg))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                               size=3).astype(np.int32),
                    max_new_tokens=1) for i in range(3)]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(s.tokens) == 1 for s in done)

    eng2 = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=64,
                                    orchestrator=_orch(cfg), max_window=4)
    reqs2 = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                                size=3).astype(np.int32),
                     max_new_tokens=20) for i in range(2)]
    for r in reqs2:
        eng2.submit(r)
    for _ in range(3):                   # 3 steps = 3 windows of 4 ticks
        eng2.step()
    # every dispatched tick's tokens must be visible after the flush —
    # sessions must still be mid-flight, or these assertions are vacuous
    eng2._materialize_inflight()
    assert len(eng2.active) == 2
    for s in eng2.active.values():
        assert len(s.tokens) == 1 + 3 * 4   # prefill + 3 four-tick windows
