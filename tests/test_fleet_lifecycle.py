"""End-to-end lifecycle fuzz of the elastic cluster under fleet load.

The invariants, for ANY random arrival/mobility script:

* every submitted request terminates EXACTLY once — it finishes, or it is
  rejected (queue back-pressure / SLO gate / over-capacity); never both,
  never neither, never twice;
* slot and page free-lists never leak — after a full drain every pool is
  back to all-free;
* ``stats()["conservation"]`` balances: submitted == finished + every
  rejection class, with zero in-flight work left.

Fuzzed with hypothesis when available; otherwise a deterministic seed
sweep exercises the same invariant checker. The 1k-UE case pins the
ISSUE's population-scale requirement with tiny model shapes.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.core.channel import FleetChannel, city_grid_cells
from repro.serving import (Autoscaler, AutoscalerConfig, EdgeCluster,
                           FleetLoadConfig, SLOAdmission,
                           SLOAdmissionConfig, fleet_requests)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ARCH = "qwen2.5-3b"


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced(ARCH)
    return cfg, SP.init_split_params(jax.random.PRNGKey(0), cfg)


def _fleet(n, seed, n_cells=0):
    rng = np.random.default_rng(seed)
    traces = np.abs(rng.normal(2e6, 8e5, size=(n, 128))) + 1e5
    if n_cells:
        cells = city_grid_cells(n, 128, n_cells, seed=seed + 1,
                                dwell_ticks=6)
        return FleetChannel(n, traces_bps=traces, cells=cells,
                            cell_caps_bps=None, cycle=True,
                            detach_factor=0.5)
    return FleetChannel(n, traces_bps=traces, cycle=True)


def _check_lifecycle(model, *, seed, n_ues, arrival, handover,
                     n_replicas, n_slots, mobility, admission,
                     autoscale, gen=4, max_pending=None):
    """Run a scripted fleet through an elastic cluster and assert every
    lifecycle invariant. Returns the stats dict for extra assertions."""
    cfg, params = model
    fleet = _fleet(n_ues, seed, n_cells=n_replicas if mobility else 0)
    load = FleetLoadConfig(arrival=arrival, mean_interarrival_ticks=1.0,
                           prompt_len=4, max_new_tokens=gen,
                           vocab=cfg.vocab_size, slo_ticks=64, seed=seed)
    reqs = fleet_requests(fleet, load)
    gate = SLOAdmission(64, SLOAdmissionConfig(park_max_ticks=16)) \
        if admission else None
    auto = Autoscaler(AutoscalerConfig(
        max_replicas=n_replicas + 2, sustain_ticks=2,
        cooldown_ticks=4)) if autoscale else None
    cluster = EdgeCluster(
        params, cfg, n_replicas=n_replicas, n_slots=n_slots,
        cache_len=32, handover=handover, admission=gate, autoscaler=auto,
        placement="best-channel" if mobility else "least-loaded",
        max_pending=max_pending if max_pending is not None
        else max(n_ues // 4, 8))
    with cluster:
        cluster.warm(reqs[0].prompt)
        done = cluster.run_paced(reqs)
        st = cluster.stats()
    c = st["conservation"]
    # fully drained: nothing in flight anywhere
    assert c["in_flight"] == 0, c
    assert c["slo_parked"] == 0 and c["parked_moves"] == 0, c
    # exactly-once termination: the terminal counters partition submitted
    terminals = (c["finished"] + c["queue_rejected_router"]
                 + c["queue_rejected_engine"] + c["over_capacity"]
                 + c["slo_rejected"])
    assert c["submitted"] == terminals, c
    assert c["submitted"] == n_ues
    # no rid finishes twice (drop-and-replay chains fold to one session)
    rids = [s.request.rid for s in done]
    assert len(rids) == len(set(rids))
    # every finished session really produced its tokens
    for s in done:
        assert 1 <= len(s.tokens) <= gen
    # free-lists never leak: every pool back to all-free after the drain
    for eng in cluster.replicas:
        assert eng.pool.n_free == eng.pool.n_slots
        if eng.paged:
            assert int(eng.pool.pages_in_use) == 0
    return st


# ---------------------------------------------------------------------------
# deterministic scenario matrix (runs with or without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arrival", ["poisson", "heavy-tail", "burst"])
def test_lifecycle_static_fleet(model, arrival):
    st = _check_lifecycle(model, seed=11, n_ues=24, arrival=arrival,
                          handover="migrate", n_replicas=2, n_slots=2,
                          mobility=False, admission=False, autoscale=False)
    assert st["requests_finished"] > 0


@pytest.mark.parametrize("handover", ["migrate", "stay", "drop"])
def test_lifecycle_mobile_fleet(model, handover):
    st = _check_lifecycle(model, seed=5, n_ues=16, arrival="poisson",
                          handover=handover, n_replicas=2, n_slots=2,
                          mobility=True, admission=False, autoscale=False,
                          gen=6)
    assert st["handovers"] >= 0


def test_lifecycle_with_admission_and_autoscaler(model):
    st = _check_lifecycle(model, seed=7, n_ues=48, arrival="burst",
                          handover="migrate", n_replicas=1, n_slots=2,
                          mobility=False, admission=True, autoscale=True)
    # burst load against one 2-slot replica must exercise the gate or
    # the scaler (park/reject or grow) — not sail through untouched
    assert st["scale_ups"] + st["slo_rejected"] + st["requests_rejected"] > 0


def test_lifecycle_tight_queue_backpressure(model):
    """A deliberately tiny queue forces router/engine rejections — the
    conservation law must balance THROUGH the back-pressure path."""
    st = _check_lifecycle(model, seed=13, n_ues=32, arrival="burst",
                          handover="migrate", n_replicas=1, n_slots=2,
                          mobility=False, admission=False, autoscale=False,
                          max_pending=2)
    assert st["requests_rejected"] > 0


def test_lifecycle_1k_ues(model):
    """Population scale (ISSUE acceptance): >= 1k UEs, tiny shapes, full
    conservation + leak check."""
    st = _check_lifecycle(model, seed=3, n_ues=1000, arrival="heavy-tail",
                          handover="migrate", n_replicas=2, n_slots=16,
                          mobility=False, admission=True, autoscale=True,
                          gen=3, max_pending=256)
    assert st["requests_finished"] >= 500   # the bulk of the fleet served


@pytest.mark.slow
def test_lifecycle_2k_ue_smoke(model):
    """CI's dedicated slow job: 2k mobile UEs with admission + autoscaling
    + handover migration all on — the whole elastic stack at once."""
    st = _check_lifecycle(model, seed=17, n_ues=2000, arrival="heavy-tail",
                          handover="migrate", n_replicas=2, n_slots=16,
                          mobility=True, admission=True, autoscale=True,
                          gen=3, max_pending=512)
    assert st["requests_finished"] >= 1000


# ---------------------------------------------------------------------------
# hypothesis fuzz (skipped when hypothesis is unavailable; the matrix
# above still covers every policy arm deterministically)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2 ** 16),
           arrival=st.sampled_from(["poisson", "heavy-tail", "burst"]),
           handover=st.sampled_from(["migrate", "stay", "drop"]),
           mobility=st.booleans(),
           admission=st.booleans(),
           n_ues=st.integers(8, 32))
    @settings(max_examples=8, deadline=None)
    def test_lifecycle_fuzz(model, seed, arrival, handover, mobility,
                            admission, n_ues):
        _check_lifecycle(model, seed=seed, n_ues=n_ues, arrival=arrival,
                         handover=handover, n_replicas=2, n_slots=2,
                         mobility=mobility, admission=admission,
                         autoscale=False)
