"""The paper's technique: split model exactness, Algorithm 1 phase masks,
cascade training, and the DPI/Ensure ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.configs.base import TrainConfig
from repro.core import bottleneck as BN
from repro.core import cascade as C
from repro.core import split as SP
from repro.data import lumos5g
from repro.models import lstm as LSTM
from repro.models import transformer as T


def test_split_mode0_equals_full_forward():
    cfg = get_reduced("granite-8b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size)
    full, _ = T.forward(params, tok, cfg)
    split, _, info = SP.split_forward(params, tok, cfg, mode=0)
    np.testing.assert_allclose(np.asarray(split), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    assert info["payload_bytes"] == 2 * 16 * cfg.d_model * 2


def test_split_mode1_compresses_payload():
    cfg = get_reduced("granite-8b")
    assert BN.compression_ratio(cfg, 1) < 0.3
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size)
    logits, _, info1 = SP.split_forward(params, tok, cfg, mode=1)
    _, _, info0 = SP.split_forward(params, tok, cfg, mode=0)
    assert info1["payload_bytes"] < 0.3 * info0["payload_bytes"]
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_split_decode_matches_monolithic_mode0():
    cfg = get_reduced("mixtral-8x7b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    B = 2
    s1 = T.init_decode_state(cfg, B, 32)
    s2 = T.init_decode_state(cfg, B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(4):
        l_ref, s1 = T.decode_step(params, tok, s1, jnp.int32(t), cfg)
        l_split, s2, _ = SP.split_decode_step(params, tok, s2, jnp.int32(t),
                                              cfg, mode=0)
        np.testing.assert_allclose(np.asarray(l_split), np.asarray(l_ref),
                                   rtol=1e-4, atol=1e-4)
        tok = jnp.argmax(l_ref, -1).astype(jnp.int32)


def test_phase_mask_freezes_base_in_phase2():
    cfg = get_reduced("stablelm-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    m1 = C.transformer_phase_mask(params, 1)
    m2 = C.transformer_phase_mask(params, 2)
    assert all(jax.tree.leaves(m1["layers"]))
    assert not any(jax.tree.leaves(m2["layers"]))
    assert not any(jax.tree.leaves(m1["bneck_modes"]))
    assert all(jax.tree.leaves(m2["bneck_modes"][0]))


def test_cascade_on_paper_lstm_poc():
    """Run Algorithm 1 end-to-end on the (reduced) paper model with the
    synthetic Lumos5G twin; phase 2 must NOT move frozen weights and the
    Ensure ordering must hold."""
    lcfg = get_reduced("lumos5g-lstm")
    dcfg = lumos5g.Lumos5GConfig(n_samples=3000, seq_len=lcfg.seq_len,
                                 seed=0)
    data = lumos5g.generate(dcfg)
    train, test = lumos5g.train_test_split(data, dcfg)
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)

    def loss_fn(params, batch, mode):
        return LSTM.loss_fn(params, batch, lcfg, mode)

    it = lumos5g.batch_iterator(train, 128)
    batches = [next(it) for _ in range(160)]

    def data_iter(step):
        b = batches[step % len(batches)]
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    test_b = {"x": jnp.asarray(test["x"][:512]),
              "y": jnp.asarray(test["y"][:512])}

    def eval_fn(params, mode):
        loss, m = LSTM.loss_fn(params, test_b, lcfg, mode)
        return {"loss": loss, "acc": m["acc"]}

    enc_before = None
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=160,
                       weight_decay=0.0)

    def mask_fn(params, phase):
        return LSTM.phase_mask(params, phase)

    params, hist = C.train_cascade(
        params, loss_fn, data_iter, tcfg, n_modes=2, steps_per_phase=80,
        phase_mask_fn=mask_fn, eval_fn=eval_fn, verbose=False)

    # mode 0 learned something (better than chance = -log(1/3) ~ 1.0986)
    assert hist["phases"][0]["eval"]["loss"] < 1.05
    # Ensure: mode 1 (bottleneck) at most as good as mode 0
    assert hist["ensure"]["losses"][1] >= hist["ensure"]["losses"][0] - 0.02
    # both modes beat chance accuracy
    assert hist["ensure"]["accs"][1] > 0.40


def test_cascade_phase2_frozen_weights_unchanged():
    lcfg = get_reduced("lumos5g-lstm")
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)
    from repro.training import optimizer as opt
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=10)
    step = C.make_train_step(
        lambda p, b, m: LSTM.loss_fn(p, b, lcfg, m), tcfg)
    state = opt.init(params)
    batch = {"x": jnp.ones((8, lcfg.seq_len, lcfg.n_features)),
             "y": jnp.zeros((8, lcfg.seq_len), jnp.int32)}
    mask = LSTM.phase_mask(params, 2)
    p2, _, _ = step(params, state, batch, mask, mode=1)
    # encoder + decoder identical; bottleneck/adapter moved
    for a, b in zip(jax.tree.leaves(params["enc"]), jax.tree.leaves(p2["enc"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params["bneck"]),
                        jax.tree.leaves(p2["bneck"])))
    assert moved
