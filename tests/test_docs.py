"""The docs link-checker (tools/check_docs.py, run by the CI docs job) must
pass on the repo's own markdown and actually catch rot."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_docs)


def test_repo_markdown_has_no_broken_links():
    errors = check_docs.check_repo(REPO)
    assert errors == [], "\n".join(errors)


def test_github_slugs():
    s = check_docs.github_slug
    assert s("Split serving") == "split-serving"
    assert s("`payload_bytes` rounding semantics") == \
        "payload_bytes-rounding-semantics"
    assert s("Encoder → bottleneck → decoder!") == "encoder--bottleneck--decoder"


def test_checker_catches_broken_links_and_anchors(tmp_path):
    (tmp_path / "a.md").write_text(
        "# Title\n\n## Real Heading\n\n[ok](b.md) [ok2](#real-heading)\n"
        "[bad file](missing.md) [bad anchor](b.md#nope)\n"
        "```\n[not a link in code](also_missing.md)\n```\n"
        "~~~\n[nor in tilde fences](tilde_missing.md)\n~~~\n")
    (tmp_path / "b.md").write_text("# B\n")
    # a mid-line ``` in prose must NOT pair with a later real fence and
    # swallow the broken link between them
    (tmp_path / "c.md").write_text(
        "# C\n\nwrap examples in ``` fences\n\n[swallowed?](gone.md)\n\n"
        "```\ncode\n```\n")
    # indented fences (valid inside list items) are still code, not links
    (tmp_path / "ind.md").write_text(
        "# I\n\n- item:\n  ```\n  [in code](ind_missing.md)\n  ```\n")
    errors = check_docs.check_repo(tmp_path)
    assert len(errors) == 3
    assert any("missing.md" in e for e in errors)
    assert any("#nope" in e for e in errors)
    assert any("gone.md" in e for e in errors)
    assert not any("ind_missing" in e for e in errors)


def test_duplicate_headings_get_numbered_anchors(tmp_path):
    (tmp_path / "d.md").write_text(
        "# Same\n\n# Same\n\n[first](#same) [second](#same-1)\n")
    assert check_docs.check_repo(tmp_path) == []
