"""Edge-cluster serving: router, live migration, handover policies.

The core pin: a session that live-migrates between replicas mid-generation
(``SlotPool.read_rows`` snapshot -> backhaul -> ``inject_session``) must
decode the EXACT token stream an unmigrated single-engine run decodes —
for every decode-state family (attention KV, Griffin rglru + rolling
window, xLSTM), including a handover that lands mid-window under the
device-resident loop, and with identical wire/mode accounting. Quantized
snapshots trade that bit-exactness for backhaul bytes; the raw-vs-quantized
test measures both sides.

The ``MobilityChannel`` in these tests uses ``detach_factor=1.0`` (equal
capacity in and out of cell) so both runs observe the *identical* capacity
sequence — migration must be state-exact, not merely close. The policy
A/B tests then turn degradation on to check stay-and-degrade really
degrades and migrate really rescues.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.core.channel import MobilityChannel
from repro.models import transformer as T
from repro.serving import (ContinuousBatchingEngine, EdgeCluster,
                           Request, RequestQueue, SlotPool,
                           default_orchestrator, extract_session,
                           inject_session)

ARCHS = ["qwen2.5-3b", "recurrentgemma-2b", "xlstm-125m"]


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_reduced(arch)
        out[arch] = (cfg, SP.init_split_params(jax.random.PRNGKey(0), cfg))
    return out


def _prompt(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


def _mobility(cross_at, *, n_ticks=64, n_cells=2, cap=2e6, detach=1.0):
    cells = [0] * cross_at + [1 % n_cells] * n_ticks
    return MobilityChannel(cells, [cap] * n_cells, detach_factor=detach)


# ---------------------------------------------------------------------------
# read_rows / write_rows round-trip (independent of migration)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_read_write_rows_round_trip(arch, models):
    """``write_rows(read_rows(s), s)`` must be an identity for every state
    layout: the homogeneous stacked ``[L, B, ...]`` attention KV (cache
    positions included), and the heterogeneous per-layer tuples of
    rglru/xlstm."""
    cfg, _ = models[arch]
    pool = SlotPool(cfg, n_slots=4, cache_len=16)
    # fill the pool with a recognizable non-zero pattern
    key = jax.random.PRNGKey(1)
    leaves, treedef = jax.tree.flatten(pool.states)
    filled = []
    for i, leaf in enumerate(leaves):
        r = jax.random.normal(jax.random.fold_in(key, i), leaf.shape)
        filled.append((r * 100).astype(leaf.dtype)
                      if np.issubdtype(leaf.dtype, np.integer)
                      else r.astype(leaf.dtype))
    pool.states = jax.tree.unflatten(treedef, filled)
    before = jax.tree.map(np.asarray, pool.states)

    rows = pool.read_rows([2, 0])
    # the gathered batch has batch=2 on the slot axis, other dims intact
    axis = 1 if cfg.homogeneous else 0
    for leaf in jax.tree.leaves(rows):
        assert leaf.shape[axis] == 2
    # writing the rows back where they came from changes nothing
    pool.write_rows(rows, [2, 0], [0, 0])
    after = jax.tree.map(np.asarray, pool.states)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)

    # cross-copy: slot 2's rows land bit-exactly in slots 1 and 3
    pool.write_rows(pool.read_rows([2, 2]), [1, 3], [0, 0])
    for leaf in jax.tree.leaves(jax.tree.map(np.asarray, pool.states)):
        row = np.moveaxis(leaf, axis, 0)
        np.testing.assert_array_equal(row[1], row[2])
        np.testing.assert_array_equal(row[3], row[2])


def test_read_rows_matches_write_rows_positions(models):
    """Positions are host-side state: read_rows returns only device rows,
    and the pool's position bookkeeping survives a write_rows round trip."""
    cfg, _ = models["qwen2.5-3b"]
    pool = SlotPool(cfg, n_slots=2, cache_len=16)
    pool.positions[0] = 7
    rows = pool.read_rows([0])
    pool.write_rows(rows, [1], [7])
    assert pool.positions[1] == 7 and pool.positions[0] == 7


# ---------------------------------------------------------------------------
# migrated token streams are bit-identical
# ---------------------------------------------------------------------------

def _run_single(params, cfg, reqs, **kw):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=48,
                                   orchestrator=default_orchestrator(cfg),
                                   **kw)
    done = eng.run(reqs)
    eng.close()
    return {s.request.rid: s for s in done}


def _run_cluster(params, cfg, reqs, **kw):
    kw.setdefault("n_replicas", 2)
    kw.setdefault("n_slots", 2)
    kw.setdefault("cache_len", 48)
    # best-channel admits every session in its home cell, so the only
    # migrations these tests see are the scripted crossings (least-loaded
    # may place a UE off-cell, which a migrating cluster then corrects —
    # covered separately by test_off_cell_placement_corrected)
    kw.setdefault("placement", "best-channel")
    cluster = EdgeCluster(params, cfg, **kw)
    done = cluster.run(reqs)
    st = cluster.stats()
    cluster.close()
    return {s.request.rid: s for s in done}, st


@pytest.mark.parametrize("arch", ARCHS)
def test_migrated_stream_bit_identical(arch, models):
    """One session crosses cells mid-generation and live-migrates; another
    never moves. Both must decode exactly what a single unmigrated engine
    decodes — tokens, modes, wire bytes."""
    cfg, params = models[arch]

    def reqs():
        return [
            Request(rid=0, prompt=_prompt(cfg, seed=3), max_new_tokens=12,
                    channel=_mobility(5)),
            Request(rid=1, prompt=_prompt(cfg, seed=4), max_new_tokens=9,
                    channel=_mobility(60)),       # never actually crosses
        ]

    base = _run_single(params, cfg, reqs())
    got, st = _run_cluster(params, cfg, reqs(), handover="migrate")
    assert st["migrations"] == 1 and st["requests_finished"] == 2
    for rid in base:
        assert got[rid].tokens == base[rid].tokens, (arch, rid)
        assert got[rid].mode_counts == base[rid].mode_counts, (arch, rid)
        assert got[rid].wire_bytes == base[rid].wire_bytes, (arch, rid)
    assert len(got[0].migrations) == 1
    m = got[0].migrations[0]
    assert m["kind"] == "migrate" and m["bytes"] > 0
    assert m["from_replica"] == 0 and m["to_replica"] == 1
    assert got[0].handover_ticks and not got[1].handover_ticks
    # the migration's backhaul latency is charged on top of the baseline's
    # identical uplink accounting
    assert got[0].transfer_s > base[0].transfer_s


def test_mid_window_handover_device_loop(models):
    """Device-resident loop with wide windows: the crossing happens INSIDE
    a dispatched multi-tick window (engine tick has advanced past it when
    the cluster polls), extraction lands the in-flight window, and the
    stream still matches the unmigrated run bit for bit."""
    cfg, params = models["qwen2.5-3b"]

    def reqs():
        # budget 20 with max_window 8: windows of 8 ticks; crossing at
        # channel tick 5 falls mid-window (admission steps the channel
        # once, so crossing sits 4 decode ticks into the first window)
        return [Request(rid=0, prompt=_prompt(cfg, seed=7),
                        max_new_tokens=20, channel=_mobility(5))]

    base = _run_single(params, cfg, reqs(), max_window=8)
    got, st = _run_cluster(params, cfg, reqs(), handover="migrate",
                           max_window=8)
    assert st["migrations"] == 1
    assert got[0].tokens == base[0].tokens
    # the handover was detected strictly after the crossing tick — the
    # window had already been dispatched (that is the latency being paid)
    assert st["mean_handover_latency_ticks"] > 0


def test_host_loop_migration_identical(models):
    """The same pin under host_loop=True (write_rows injection path)."""
    cfg, params = models["qwen2.5-3b"]

    def reqs():
        return [Request(rid=0, prompt=_prompt(cfg, seed=5),
                        max_new_tokens=10, channel=_mobility(4))]

    base = _run_single(params, cfg, reqs(), host_loop=True)
    got, st = _run_cluster(params, cfg, reqs(), handover="migrate",
                           host_loop=True)
    assert st["migrations"] == 1
    assert got[0].tokens == base[0].tokens


def test_raw_vs_quantized_snapshot(models):
    """Raw snapshots are bit-exact; int8 snapshots must ship strictly
    fewer backhaul bytes and still complete the session (their stream may
    legitimately diverge after the lossy re-injection)."""
    cfg, params = models["qwen2.5-3b"]

    def reqs():
        return [Request(rid=0, prompt=_prompt(cfg, seed=9),
                        max_new_tokens=14, channel=_mobility(5))]

    base = _run_single(params, cfg, reqs())
    raw, st_raw = _run_cluster(params, cfg, reqs(), handover="migrate",
                               snapshot_bits=0)
    q8, st_q8 = _run_cluster(params, cfg, reqs(), handover="migrate",
                             snapshot_bits=8)
    assert st_raw["migrations"] == st_q8["migrations"] == 1
    assert raw[0].tokens == base[0].tokens          # raw: bit-identical
    assert len(q8[0].tokens) == len(base[0].tokens)  # q8: completes fully
    assert 0 < st_q8["migration_bytes"] < st_raw["migration_bytes"]
    assert q8[0].migrations[0]["bits"] == 8


def test_extract_inject_direct(models):
    """The migration primitives standalone: extract detaches the session
    and its link state; inject refuses when the target pool is full, then
    lands when a slot frees."""
    cfg, params = models["qwen2.5-3b"]
    src = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=48,
                                   orchestrator=default_orchestrator(cfg),
                                   max_window=2)
    dst = ContinuousBatchingEngine(params, cfg, n_slots=1, cache_len=48,
                                   orchestrator=default_orchestrator(cfg))
    blocker = Request(rid=99, prompt=_prompt(cfg, seed=1), max_new_tokens=30,
                      channel=_mobility(60))
    mover = Request(rid=0, prompt=_prompt(cfg, seed=2), max_new_tokens=12,
                    channel=_mobility(60))
    dst.submit(blocker)
    src.submit(mover)
    for _ in range(3):
        src.step()
        dst.step()
    with pytest.raises(KeyError):
        extract_session(src, rid=12345)
    snap = extract_session(src, rid=0)
    assert not src.active and src.pool.n_free == src.pool.n_slots
    assert snap.link is not None and snap.position == snap.session.pos
    assert not inject_session(dst, snap)            # pool still occupied
    dst.run()                                       # blocker finishes
    assert inject_session(dst, snap)
    done = dst.run()
    assert any(s.request.rid == 0 and len(s.tokens) == 12 for s in done)
    src.close(), dst.close()


# ---------------------------------------------------------------------------
# router placement
# ---------------------------------------------------------------------------

def test_round_robin_placement(models):
    cfg, params = models["qwen2.5-3b"]
    cluster = EdgeCluster(params, cfg, n_replicas=3, n_slots=2,
                          cache_len=32, placement="round-robin")
    reqs = [Request(rid=i, prompt=_prompt(cfg), max_new_tokens=2)
            for i in range(6)]
    assert [cluster.place(r) for r in reqs] == [0, 1, 2, 0, 1, 2]
    cluster.close()


def test_least_loaded_placement(models):
    cfg, params = models["qwen2.5-3b"]
    cluster = EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                          cache_len=32, placement="least-loaded")
    for i in range(4):
        cluster.submit(Request(rid=i, prompt=_prompt(cfg),
                               max_new_tokens=4))
    # alternating homes: each submit lands on the emptier replica
    assert sorted(cluster._home.values()) == [0, 0, 1, 1]
    cluster.run()
    cluster.close()


def test_submit_rejects_unfronted_cells(models):
    """A mobility script naming a cell no replica fronts would alias onto
    some replica under the modulo map and could misread a real crossing as
    'crossed back home' (silently disabling migration) — so it must raise
    at submit time."""
    cfg, params = models["qwen2.5-3b"]
    cluster = EdgeCluster(params, cfg, n_replicas=2, n_slots=2,
                          cache_len=32)
    ch = MobilityChannel([0, 0, 2], [1e6] * 3)      # cell 2, 2 replicas
    with pytest.raises(ValueError, match="cell 2"):
        cluster.submit(Request(rid=0, prompt=_prompt(cfg),
                               max_new_tokens=4, channel=ch))
    cluster.close()


def test_best_channel_placement_follows_cell(models):
    cfg, params = models["qwen2.5-3b"]
    cluster = EdgeCluster(params, cfg, n_replicas=3, n_slots=2,
                          cache_len=32, placement="best-channel")
    ch = MobilityChannel([2, 2, 2, 0], [1e6] * 3)
    req = Request(rid=0, prompt=_prompt(cfg), max_new_tokens=2, channel=ch)
    assert cluster.place(req) == 2                  # the UE's current cell
    plain = Request(rid=1, prompt=_prompt(cfg), max_new_tokens=2)
    assert cluster.place(plain) in (0, 1, 2)        # least-loaded fallback
    cluster.close()


# ---------------------------------------------------------------------------
# handover policies
# ---------------------------------------------------------------------------

def _degrading_reqs(cfg, n=2, gen=14):
    # detach_factor small enough that even the cheapest mode misses the
    # per-token budget while served from the wrong cell
    return [Request(rid=i, prompt=_prompt(cfg, seed=20 + i),
                    max_new_tokens=gen,
                    channel=_mobility(4, cap=2e7, detach=0.001))
            for i in range(n)]


def test_stay_degrades_migrate_rescues(models):
    cfg, params = models["qwen2.5-3b"]
    _, st_stay = _run_cluster(params, cfg, _degrading_reqs(cfg),
                              handover="stay", max_window=4,
                              latency_budget_s=0.005)
    _, st_mig = _run_cluster(params, cfg, _degrading_reqs(cfg),
                             handover="migrate", max_window=4,
                             latency_budget_s=0.005)
    assert st_stay["handovers_ignored"] == st_stay["handovers"] > 0
    assert st_mig["migrations"] > 0
    assert st_mig["deadline_miss_rate"] < st_stay["deadline_miss_rate"]


def test_off_cell_placement_corrected(models):
    """round-robin can admit a UE onto a replica that never fronted its
    cell; a migrating cluster must detect the standing detachment (no
    crossing event ever fires) and correct it instead of serving the whole
    session at detach_factor."""
    cfg, params = models["qwen2.5-3b"]
    # two UEs, both physically in cell 1 forever; round-robin puts rid 0
    # on replica 0 (off-cell) and rid 1 on replica 1 (in-cell)
    reqs = [Request(rid=i, prompt=_prompt(cfg, seed=50 + i),
                    max_new_tokens=10,
                    channel=MobilityChannel([1] * 64, [2e6, 2e6],
                                            detach_factor=0.001))
            for i in range(2)]
    got, st = _run_cluster(params, cfg, reqs, handover="migrate",
                           placement="round-robin", max_window=4)
    assert st["requests_finished"] == 2
    assert st["migrations"] == 1            # only the off-cell UE moves
    assert st["handovers"] == 0             # no crossing event ever fired
    assert len(got[0].migrations) == 1 and not got[1].migrations
    assert not reqs[0].channel.detached     # re-homed, now serving in-cell


def test_drop_and_replay_completes(models):
    cfg, params = models["qwen2.5-3b"]
    base = _run_single(params, cfg,
                       [Request(rid=0, prompt=_prompt(cfg, seed=30),
                                max_new_tokens=12, channel=_mobility(5))])
    got, st = _run_cluster(params, cfg,
                           [Request(rid=0, prompt=_prompt(cfg, seed=30),
                                    max_new_tokens=12,
                                    channel=_mobility(5))],
                           handover="drop", cache_len=64)
    assert st["replays"] == 1 and st["migrations"] == 0
    sess = got[0]
    # replay regenerates the decoder state by prefilling prompt+emitted:
    # greedy decode completes the full budget and the replayed context
    # costs a second (longer) prompt upload
    assert len(sess.tokens) == 12
    assert sess.tokens == base[0].tokens   # same modes: prefill==loop
    assert any(m["kind"] == "replay" for m in sess.migrations)
    assert sess.prefill_wire_bytes > base[0].prefill_wire_bytes


def test_cluster_session_result_fields(models):
    """Session.result() carries migrations/handover_ticks — empty for
    single-engine serving, populated under the cluster."""
    cfg, params = models["qwen2.5-3b"]
    base = _run_single(params, cfg,
                       [Request(rid=0, prompt=_prompt(cfg),
                                max_new_tokens=4)])
    r = base[0].result()
    assert r["migrations"] == [] and r["handover_ticks"] == []
    got, _ = _run_cluster(params, cfg,
                          [Request(rid=0, prompt=_prompt(cfg),
                                   max_new_tokens=10,
                                   channel=_mobility(4))],
                          handover="migrate")
    r = got[0].result()
    assert len(r["migrations"]) == 1 and r["handover_ticks"]


def test_cluster_stats_shape(models):
    cfg, params = models["qwen2.5-3b"]
    _, st = _run_cluster(params, cfg, _degrading_reqs(cfg, n=3, gen=6),
                         handover="migrate")
    assert st["n_replicas"] == 2 and len(st["per_replica"]) == 2
    for rep in st["per_replica"]:
        assert 0.0 <= rep["occupancy"] <= 1.0
    assert st["requests_finished"] == 3
    assert st["migration_bytes"] >= 0


# ---------------------------------------------------------------------------
# satellites: per-engine pipeline, deque queue
# ---------------------------------------------------------------------------

def test_request_queue_deque_semantics():
    q = RequestQueue(max_pending=2)
    r = [Request(rid=i, prompt=np.zeros(2, np.int32)) for i in range(3)]
    assert q.submit(r[0]) and q.submit(r[1])
    assert not q.submit(r[2])                       # back-pressure
    assert q.rejected == 1 and q.submitted == 2 and len(q) == 2
    assert q.peek() is r[0]                         # FIFO head, no pop
    assert q.pop() is r[0] and q.pop() is r[1] and q.pop() is None
    assert len(q) == 0 and q.peek() is None
    assert q.submit(r[2]) and len(q) == 1           # reusable after drain


def test_per_engine_pipeline_isolated_and_closeable(models):
    """Two device-loop engines must each own a pipeline worker (the old
    module-global single worker serialized all engines in the process),
    and close() must be idempotent and leave the engine reusable."""
    cfg, params = models["qwen2.5-3b"]
    a = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                 orchestrator=default_orchestrator(cfg))
    b = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                 orchestrator=default_orchestrator(cfg))
    ra = [Request(rid=i, prompt=_prompt(cfg, seed=40), max_new_tokens=6)
          for i in range(2)]
    rb = [Request(rid=i, prompt=_prompt(cfg, seed=40), max_new_tokens=6)
          for i in range(2)]
    for r1, r2 in zip(ra, rb):
        a.submit(r1), b.submit(r2)
    while a.step() | b.step():                      # interleave the loops
        pass
    a._materialize_inflight(), b._materialize_inflight()
    a._sync_device_state(), b._sync_device_state()
    assert a._exec is not b._exec and a._exec is not None
    toks_a = {s.request.rid: s.tokens for s in a.finished}
    toks_b = {s.request.rid: s.tokens for s in b.finished}
    assert toks_a == toks_b                         # identical workloads
    a.close(), a.close()                            # idempotent
    assert a._exec is None
    # reusable after close: a new worker spawns lazily
    done = a.run([Request(rid=9, prompt=_prompt(cfg), max_new_tokens=3)])
    assert any(s.request.rid == 9 for s in done)
    a.close(), b.close()


def test_engine_context_manager(models):
    cfg, params = models["qwen2.5-3b"]
    with ContinuousBatchingEngine(
            params, cfg, n_slots=2, cache_len=32,
            orchestrator=default_orchestrator(cfg)) as eng:
        done = eng.run([Request(rid=0, prompt=_prompt(cfg),
                                max_new_tokens=4)])
        assert len(done) == 1
    assert eng._exec is None
