"""Optimizer, checkpoint, data pipeline, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import TrainConfig
from repro.core import split as SP
from repro.data import lumos5g, tokens
from repro.serving.engine import ServingEngine, make_serve_step
from repro.training import checkpoint, optimizer as opt
from repro.models import transformer as T


def test_adamw_descends_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.apply_updates(params, g, state, tcfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_mask_freezes_leaves():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10)
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    grads = {"a": jnp.ones(3), "b": jnp.ones(3)}
    state = opt.init(params)
    mask = {"a": True, "b": False}
    p2, state2, _ = opt.apply_updates(params, grads, state, tcfg, mask)
    assert not np.allclose(np.asarray(p2["a"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p2["b"]), 1.0)
    np.testing.assert_array_equal(np.asarray(state2.m["b"]), 0.0)


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt.lr_schedule(tcfg, s)) for s in
           (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]            # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]          # decay
    assert lrs[4] >= 0.1 * 0.99                # floor at 10%


def test_grad_clip():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-3)


def test_checkpoint_roundtrip_mixed_tree():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((3,), jnp.bfloat16)},
        "tup": (jnp.zeros((2,), jnp.int32), jnp.ones((1,), jnp.float32)),
    }
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        checkpoint.save(path, tree, metadata={"step": 7})
        out = checkpoint.restore(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        assert checkpoint.load_metadata(path)["step"] == 7


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        checkpoint.save(path, tree)
        with pytest.raises(ValueError):
            checkpoint.restore(path, {"a": jnp.ones((3, 3))})


def test_lumos5g_schema_and_correlation():
    cfg = lumos5g.Lumos5GConfig(n_samples=4000, seq_len=10)
    d = lumos5g.generate(cfg)
    assert d["x"].shape == (4000, 10, 11)
    assert d["y"].shape == (4000, 10)
    assert set(np.unique(d["y"])) <= {0, 1, 2}
    # classes roughly balanced (terciles)
    counts = np.bincount(d["y"].ravel())
    assert counts.min() > 0.25 * counts.sum() / 3
    # NR signal strength (feature 7: nr_rsrp) correlates with throughput
    r = np.corrcoef(d["x"][:, 0, 7], d["tput"][:, 0])[0, 1]
    assert r > 0.4
    # temporal autocorrelation exists (it's a time series, not iid noise)
    r_t = np.corrcoef(d["tput"][:, 0], d["tput"][:, 5])[0, 1]
    assert r_t > 0.3


def test_markov_token_source_learnable_structure():
    cfg = get_reduced("stablelm-3b")
    src = tokens.MarkovTokenSource(cfg, alphabet=16)
    b = src.batch(4, 32)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    assert b["tokens"].max() < 16
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_serving_engine_deterministic_prefill_decode():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, cache_len=16, batch=1)
    prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
    logits = eng.prefill(prompt)
    out1 = eng.decode_tokens(jnp.argmax(logits, -1).astype(jnp.int32), 5)
    eng.reset()
    eng.prefill(prompt)
    out2 = eng.decode_tokens(jnp.argmax(logits, -1).astype(jnp.int32), 5)
    np.testing.assert_array_equal(out1, out2)
    assert eng.stats.tokens == 5
