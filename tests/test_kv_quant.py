"""int8 KV cache: decode with the quantized cache tracks the exact decode
(single device, reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["stablelm-3b", "mixtral-8x7b"])
def test_kv8_decode_tracks_exact(arch):
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    B, steps, cache = 2, 10, 32
    st_f = T.init_decode_state(cfg, B, cache)
    st_q = T.init_decode_state(cfg, B, cache, kv_bits=8)
    # the quantized state must actually be smaller (int8 codes + scales)
    sizes_f = sum(x.nbytes for x in jax.tree.leaves(st_f))
    sizes_q = sum(x.nbytes for x in jax.tree.leaves(st_q))
    assert sizes_q < 0.75 * sizes_f, (sizes_q, sizes_f)

    step = jax.jit(lambda p, t, s, c: T.decode_step(p, t, s, c, cfg))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    maxdiff = 0.0
    agree = 0
    for i in range(steps):
        lf, st_f = step(params, tok, st_f, jnp.int32(i))
        lq, st_q = step(params, tok, st_q, jnp.int32(i))
        rel = float(jnp.linalg.norm((lf - lq).astype(jnp.float32))
                    / max(float(jnp.linalg.norm(lf.astype(jnp.float32))),
                          1e-9))
        maxdiff = max(maxdiff, rel)
        agree += int(jnp.sum(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
        tok = jnp.argmax(lf, axis=-1).astype(jnp.int32).reshape(tok.shape)
    # int8 KV: ~1% relative logits for dense; MoE routing is discontinuous,
    # so quantization noise can flip expert choices on untrained weights —
    # greedy-token agreement is the meaningful invariant there
    tol = 0.25 if cfg.is_moe else 0.05
    assert maxdiff < tol, maxdiff
    assert agree >= 0.9 * steps * B          # greedy tokens agree
