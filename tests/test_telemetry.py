"""Serving telemetry: registry oracles, trace round-trip, and the
no-behavior-change contract.

The telemetry subsystem (``repro.serving.telemetry``) must be purely
additive: attaching a ``Telemetry`` to an engine may not change a single
decoded token bit, on either the host loop or the device-resident
windowed loop, for any decode-state family. These tests pin that, plus
the registry's percentile math against a ``np.quantile`` oracle, the
Chrome-trace JSON round-trip Perfetto relies on, the device telemetry
block's wire accounting against the host's, and the cluster timeline's
per-replica lanes with admission/migration/autoscale events.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import (ChannelConfig, MobilityChannel,
                                channel_fleet)
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.serving import (Autoscaler, AutoscalerConfig,
                           ContinuousBatchingEngine, EdgeCluster,
                           MetricsRegistry, Request, SLOAdmission,
                           SLOAdmissionConfig, Telemetry, TraceRecorder)
from repro.serving.telemetry import Histogram

ARCHS = ["qwen2.5-3b", "recurrentgemma-2b", "xlstm-125m"]


# ---------------------------------------------------------------------------
# registry / histogram oracles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_match_numpy_oracle():
    """A log-bucketed quantile is the upper edge of the rank's bucket, so
    it must bracket the exact sample quantile from above within one
    bucket ratio."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-5.0, sigma=1.5, size=5000)
    h = Histogram("t", lo=1e-6, hi=100.0, n_buckets=96)
    for s in samples:
        h.observe(s)
    ratio = (100.0 / 1e-6) ** (1 / 95)        # adjacent-edge ratio ~1.21x
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.quantile(q)
        assert exact <= est <= exact * ratio * 1.0001, (q, exact, est)
    assert h.count == 5000
    assert h.summary()["max"] == pytest.approx(samples.max())
    assert h.summary()["mean"] == pytest.approx(samples.mean(), rel=1e-9)


def test_histogram_weighted_observe_and_overflow():
    h = Histogram("t", lo=1e-3, hi=1.0, n_buckets=16)
    h.observe(0.01, n=7)
    h.observe(50.0)                            # past hi -> overflow bucket
    assert h.count == 8
    assert h.quantile(0.5) >= 0.01
    assert h.quantile(1.0) == 50.0             # overflow reports true max
    h.reset()
    assert h.count == 0 and h.quantile(0.5) == 0.0


def test_registry_snapshot_prometheus_and_reset():
    reg = MetricsRegistry()
    reg.inc("a.events", 3)
    reg.set("a.depth", 2.5)
    reg.observe("a.lat_s", 0.02, n=4)
    snap = reg.snapshot()
    assert snap["a.events"] == 3 and snap["a.depth"] == 2.5
    assert snap["a.lat_s"]["count"] == 4
    prom = reg.prometheus()
    assert "# TYPE a_events counter" in prom
    assert "# TYPE a_lat_s histogram" in prom
    assert 'a_lat_s_bucket{le="+Inf"} 4' in prom
    lat = reg.latency_summary("a.lat_s", "missing")
    assert set(lat) == {"a.lat_s"}
    assert lat["a.lat_s"]["p50"] >= 20.0       # ms
    with pytest.raises(TypeError):
        reg.inc("a.depth")                     # kind mismatch must be loud
    reg.ingest("st", {"x": 1, "nested": {"y": 2.0}, "skip": [1, 2]})
    assert reg.snapshot()["st.nested.y"] == 2.0
    reg.reset()
    snap = reg.snapshot()
    assert snap["a.events"] == 0 and snap["a.lat_s"]["count"] == 0


# ---------------------------------------------------------------------------
# trace recorder round-trip
# ---------------------------------------------------------------------------

def test_trace_chrome_json_round_trip(tmp_path):
    tr = TraceRecorder(capacity=64)
    tr.set_lane(0, "cluster")
    tr.set_lane(1, "replica0")
    tr.instant("admit", lane=0, cat="admission", rid=1)
    with tr.span("window", lane=1, cat="window", ticks=4):
        pass
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["pid"]: m["args"]["name"] for m in meta} == {
        0: "cluster", 1: "replica0"}
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["name"] == "admit" and inst["pid"] == 0
    assert inst["cat"] == "admission" and inst["args"]["rid"] == 1
    span = next(e for e in evs if e["ph"] == "X")
    assert span["pid"] == 1 and span["dur"] >= 0
    assert span["args"]["ticks"] == 4
    ts = [e["ts"] for e in evs if e["ph"] != "M"]
    assert all(t >= 0 for t in ts)


def test_trace_ring_buffer_drops_oldest():
    tr = TraceRecorder(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 8 and tr.dropped == 12
    assert tr.events()[0]["name"] == "e12"     # oldest evicted first


def test_telemetry_lane_views_share_registry_and_trace():
    tel = Telemetry(lane=0, lane_name="cluster")
    view = tel.for_lane(2, "replica1")
    view.inc("x", 5)
    view.instant("ev")
    assert tel.registry.snapshot()["x"] == 5
    assert tel.trace.events()[0]["pid"] == 2
    assert tel.trace._lanes[2] == "replica1"


# ---------------------------------------------------------------------------
# engine instrumentation: zero behavior change
# ---------------------------------------------------------------------------

def _requests(cfg, n, *, seed=3):
    chans = channel_fleet(
        n, ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                         recovery_prob=0.15),
        seed=11, mean_spread=0.95)
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=4).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 8)),
                    channel=chans[i], arrival_tick=i // 2)
            for i in range(n)]


def _orch(cfg):
    return Orchestrator(
        [ModeProfile(m, BN.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=0.006), ema=0.5, hysteresis=1.0)


def _run(params, cfg, *, host_loop, telemetry):
    tel = Telemetry() if telemetry else None
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, cache_len=32,
                                   orchestrator=_orch(cfg),
                                   host_loop=host_loop, telemetry=tel)
    done = eng.run(_requests(cfg, 10))
    st = eng.stats()
    assert eng.pool.n_free == eng.pool.n_slots
    return done, st, tel, eng


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("host_loop", [False, True])
def test_telemetry_changes_no_token_bits(arch, host_loop):
    """The no-behavior-change contract: the instrumented engine decodes
    the exact streams the plain engine decodes — tokens, modes, wire,
    lifecycle ticks — on both the host loop and the device windowed
    loop (where telemetry recompiles the scan with an extra int32
    output)."""
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    plain_done, plain_st, _, _ = _run(params, cfg, host_loop=host_loop,
                                      telemetry=False)
    tel_done, tel_st, tel, eng = _run(params, cfg, host_loop=host_loop,
                                      telemetry=True)

    plain = {s.request.rid: s for s in plain_done}
    instr = {s.request.rid: s for s in tel_done}
    assert plain.keys() == instr.keys() and len(plain) == 10
    for rid in plain:
        assert plain[rid].tokens == instr[rid].tokens, rid
        assert plain[rid].mode_counts == instr[rid].mode_counts, rid
        assert plain[rid].wire_bytes == instr[rid].wire_bytes, rid
        assert plain[rid].admitted_tick == instr[rid].admitted_tick, rid
        assert plain[rid].finished_tick == instr[rid].finished_tick, rid
    # stats() parity — mean_ttft_s is wall-clock and run-dependent
    for k in plain_st:
        if k == "mean_ttft_s":
            continue
        assert plain_st[k] == tel_st[k], k

    # the registry saw real work
    snap = tel.registry.snapshot()
    assert snap["engine.ttft_s"]["count"] == 10
    assert snap["engine.decode_wire_bytes"] == tel_st["decode_wire_bytes"]
    if not host_loop:
        # device telemetry block vs host accounting: the int32 row
        # summed over the scan must reproduce the host's decode wire
        # bytes and per-mode tick histogram exactly
        assert eng.device_tel["wire_bytes"] == tel_st["decode_wire_bytes"]
        assert eng.device_tel["slot_ticks"] == sum(
            len(s.tokens) - 1 for s in tel_done)
        assert int(eng.device_tel["mode_ticks"].sum()) \
            == eng.device_tel["slot_ticks"]


def test_reset_counters_clears_registry():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    tel = Telemetry()
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   orchestrator=_orch(cfg), telemetry=tel)
    eng.warm(np.array([1, 2, 3], np.int32))    # ends in reset_counters
    snap = tel.registry.snapshot()
    assert snap["engine.ttft_s"]["count"] == 0
    assert eng.device_tel["wire_bytes"] == 0


# ---------------------------------------------------------------------------
# SLO admission structured events
# ---------------------------------------------------------------------------

def test_slo_admission_records_decisions_with_margin():
    gate = SLOAdmission(64, SLOAdmissionConfig(latency_budget_s=0.05,
                                               hopeless_factor=4.0,
                                               park_queue_per_slot=2.0))
    assert gate.decide(slo_ticks=100, predicted_wait_ticks=10,
                       service_ticks=20, queue_per_slot=0.5,
                       rid=7) == "admit"
    assert gate.decide(slo_ticks=25, predicted_wait_ticks=10,
                       service_ticks=20, rid=8) == "reject"
    assert gate.decide(slo_ticks=None, predicted_wait_ticks=0,
                       service_ticks=1, queue_per_slot=9.0,
                       rid=9) == "park"
    assert gate.decide(slo_ticks=100, predicted_wait_ticks=0,
                       service_ticks=1, capacity_bps=1.0,
                       rid=10) == "reject"
    evs = list(gate.events)
    assert [e["reason"] for e in evs] == ["ok", "deadline", "backlog",
                                          "link_hopeless"]
    assert evs[0] == {"rid": 7, "verdict": "admit", "reason": "ok",
                      "margin_ticks": 70, "predicted_wait_ticks": 10,
                      "service_ticks": 20, "queue_per_slot": 0.5}
    assert evs[1]["margin_ticks"] == -5
    assert evs[2]["margin_ticks"] is None
    tel = Telemetry()
    gate.telemetry = tel
    gate.decide(slo_ticks=100, predicted_wait_ticks=1, service_ticks=1,
                rid=11)
    ev = tel.trace.events()[-1]
    assert ev["name"] == "slo_admission" and ev["cat"] == "admission"
    assert ev["args"]["rid"] == 11 and ev["args"]["margin_ticks"] == 98


# ---------------------------------------------------------------------------
# cluster timeline: lanes + admission/migration/autoscale events
# ---------------------------------------------------------------------------

def _mobility(cross_at, n, cap=2e6):
    return MobilityChannel([0] * cross_at + [1] * n, [cap, cap],
                           detach_factor=1.0)


def test_cluster_trace_has_lanes_and_lifecycle_events(tmp_path):
    """One exported cluster trace must carry per-replica lanes plus the
    control-plane story: SLO admission verdicts, migration send/inject
    and autoscale decisions, all loadable as Chrome trace JSON."""
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    gen = 10
    tel = Telemetry()
    cluster = EdgeCluster(
        params, cfg, n_replicas=2, n_slots=2, cache_len=48,
        placement="best-channel", handover="migrate",
        admission=SLOAdmission(64, SLOAdmissionConfig()),
        autoscaler=Autoscaler(AutoscalerConfig(
            min_replicas=1, max_replicas=4, high_occupancy=0.5,
            sustain_ticks=1, cooldown_ticks=2)),
        telemetry=tel)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=4).astype(np.int32),
                    max_new_tokens=gen,
                    channel=_mobility(5 if i == 0 else gen + 60,
                                      gen + 60),
                    slo_ticks=400)
            for i in range(4)]
    cluster.run(reqs)
    st = cluster.stats()
    cluster.close()

    names = {e["name"] for e in tel.trace.events()}
    assert "slo_admission" in names
    if st["migrations"]:
        assert {"migrate_send", "migrate_inject"} & names
    lanes = {e["pid"] for e in tel.trace.events()}
    assert 0 in lanes and len(lanes) >= 2      # cluster + >=1 replica lane
    assert tel.trace._lanes[0] == "cluster"
    assert tel.trace._lanes[1] == "replica0"
    # registry mirrors the cluster stats() totals
    snap = tel.registry.snapshot()
    assert snap["cluster.migrations"] == st["migrations"]
    assert "cluster.stats.requests_finished" in snap
    # and the whole timeline survives a JSON round-trip
    path = tel.trace.export(str(tmp_path / "cluster_trace.json"))
    doc = json.load(open(path))
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])
