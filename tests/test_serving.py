"""Continuous-batching split-serving: mixed-mode decode correctness, slot
recycling, and per-request wire-byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import bottleneck as BN
from repro.core import quant
from repro.core import split as SP
from repro.core.channel import ChannelConfig, channel_fleet
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.models import transformer as T
from repro.serving import ContinuousBatchingEngine, Request, RequestQueue


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_mixed_step_matches_per_mode_reference(setup):
    """One jitted mixed-mode step == the per-mode split step, per slot."""
    cfg, params = setup
    B = 3
    states = T.init_decode_state(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    stacked = BN.bank_stack(params["bneck_modes"], cfg.split)
    pos = jnp.full((B,), 5, jnp.int32)
    for m in range(cfg.split.n_modes):
        ref, _, _ = SP.split_decode_step(params, tok, states, jnp.int32(5),
                                         cfg, mode=m)
        mix, _ = SP.split_decode_step_mixed(
            params, stacked, tok, states, pos, cfg,
            jnp.full((B,), m, jnp.int32))
        np.testing.assert_allclose(np.asarray(ref), np.asarray(mix),
                                   atol=1e-5, rtol=1e-5)


def test_ragged_positions_match_aligned_decode(setup):
    """Per-slot position vectors must reproduce scalar-position decode."""
    cfg, params = setup
    B = 2
    states = T.init_decode_state(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    ref, _ = T.decode_step(params, tok, states, jnp.int32(7), cfg)
    rag, _ = T.decode_step(params, tok, states,
                           jnp.full((B,), 7, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(rag),
                               atol=1e-5, rtol=1e-5)


def _run_engine(cfg, params, n_requests, *, n_slots=3, gen_lo=4, gen_hi=9):
    orch = Orchestrator(
        [ModeProfile(m, BN.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=0.006), ema=0.5, hysteresis=1.0)
    chans = channel_fleet(
        n_requests,
        ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                      recovery_prob=0.15),
        seed=11, mean_spread=0.95)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=3).astype(np.int32),
                    max_new_tokens=int(rng.integers(gen_lo, gen_hi)),
                    channel=chans[i], arrival_tick=i // 2)
            for i in range(n_requests)]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                   cache_len=32, orchestrator=orch)
    done = eng.run(reqs)
    return eng, done


def test_continuous_batching_mixed_modes_and_accounting(setup):
    """A few dozen requests through a small slot pool: every request
    finishes, slots recycle, at least one decode tick runs >= 2 distinct
    modes, and per-request wire bytes reconcile exactly against
    ``quant.payload_bytes``-derived mode payloads."""
    cfg, params = setup
    eng, done = _run_engine(cfg, params, 24)
    assert len(done) == 24
    assert eng.pool.n_free == eng.pool.n_slots      # all slots recycled
    st = eng.stats()
    assert st["mixed_mode_ticks"] > 0               # genuinely mixed batches
    assert len(st["mode_counts"]) >= 2

    w = BN.mode_widths(cfg.split)[0]
    for s in done:
        assert len(s.tokens) == s.request.max_new_tokens
        # decode accounting: sum over tokens of that token's mode payload
        dec = sum(BN.mode_payload_bytes(cfg, 1, 1, m) * c
                  for m, c in s.mode_counts.items())
        assert s.wire_bytes == s.prefill_wire_bytes + dec
        # the first token came from the prefill, not a decode tick
        assert sum(s.mode_counts.values()) == len(s.tokens) - 1
        # and the mode payload table itself is the packed wire format
        assert BN.mode_payload_bytes(cfg, 1, 1, 1) == \
            quant.payload_bytes((1, 1, w[0]), w[1])
        assert s.transfer_s > 0


def test_queue_admission_backpressure():
    q = RequestQueue(max_pending=2)
    r = lambda i: Request(rid=i, prompt=np.ones(2, np.int32))
    assert q.submit(r(0)) and q.submit(r(1))
    assert not q.submit(r(2))                       # full -> rejected
    assert q.rejected == 1
    q.pop()
    assert q.submit(r(3))                           # slot freed


def test_payload_bytes_packed_rows():
    """int4 with an odd last dim must round each row UP to whole bytes."""
    # 3 rows x 5 int4 codes: ceil(5*4/8)=3 code bytes + 2 scale bytes per row
    assert quant.payload_bytes((3, 5), 4) == 3 * (3 + 2)
    # int8 unaffected
    assert quant.payload_bytes((3, 5), 8) == 3 * (5 + 2)
    # raw bf16
    assert quant.payload_bytes((3, 5), 0) == 30


def test_quantize_bits1_finite_and_consistent():
    """qmax(1) must floor at 1 (ternary code), matching boundary_mixed's
    floor — a zero qmax made the scale infinite and the dequant NaN."""
    assert quant.qmax(1) == 1
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 7)),
                    jnp.float32)
    q, s = quant.quantize(x, 1)
    assert np.isfinite(np.asarray(s)).all()
    d = quant.dequantize(q, s, 1)
    assert np.isfinite(np.asarray(d)).all()
    assert set(np.unique(np.asarray(q))) <= {-1, 0, 1}
    # the mixed-path wire (boundary_mixed) uses the same qm for bits=1:
    # max(1 << (max(bits,1)-1) - 1, 1) == quant.qmax(1)
    assert max((1 << (max(1, 1) - 1)) - 1, 1) == quant.qmax(1)


# -- batched full-sequence admission ------------------------------------------

def test_admission_is_one_batched_prefill_with_greedy_parity(setup):
    """Admitting a 64-token prompt must issue ONE jitted prefill call (not
    64 sequential batch-1 decode steps), with greedy decode matching the
    per-token-prefill baseline in mode 0."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, 64).astype(np.int32)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=128)
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=8)])
    st = eng.stats()
    assert st["prefill_calls"] == 1
    assert st["prefill_tokens"] == 64

    # loop baseline: token-at-a-time admission + greedy decode (mode 0 is
    # the raw boundary, so the monolithic path is the reference)
    states = T.init_decode_state(cfg, 1, 128)
    lg = None
    for t in range(64):
        lg, states = T.decode_step(params, jnp.asarray(prompt[None, t:t + 1]),
                                   states, jnp.int32(t), cfg)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    ref, pos = [int(tok[0, 0])], 64      # the prefill argmax IS token 1
    for _ in range(7):
        lg, states = T.decode_step(params, tok, states, jnp.int32(pos), cfg)
        pos += 1
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        ref.append(int(tok[0, 0]))
    assert done[0].tokens == ref
    assert done[0].ttft_s > 0


def test_multi_request_admission_single_call(setup):
    """All requests admitted in one tick and one length bucket prefill in
    ONE batched call."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        5 + i).astype(np.int32),
                    max_new_tokens=4)
            for i in range(3)]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=4, cache_len=64)
    done = eng.run(reqs)
    assert len(done) == 3
    assert eng.stats()["prefill_calls"] == 1      # one bucket, one dispatch


def test_over_capacity_rejected_and_truncated(setup):
    """DENSE full-attention pools must never wrap the rolling cache over
    the prompt: an unfittable prompt is rejected (counted), and a
    generation budget that would overflow the cache is truncated.
    ``paged=False`` pins the legacy per-slot rule — the paged pool (the
    qwen default) replaces it with arena-wide page-budget admission, which
    ``tests/test_paged.py`` covers."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    too_long = Request(rid=0, prompt=rng.integers(
        1, cfg.vocab_size, 20).astype(np.int32), max_new_tokens=4)
    overflow = Request(rid=1, prompt=rng.integers(
        1, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=20)
    exact_fit = Request(rid=2, prompt=rng.integers(
        1, cfg.vocab_size, 16).astype(np.int32), max_new_tokens=3)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=16,
                                   paged=False)
    done = eng.run([too_long, overflow, exact_fit])
    st = eng.stats()
    assert st["requests_over_capacity"] == 1
    assert st["requests_truncated"] == 2
    by_rid = {s.request.rid: s for s in done}
    assert set(by_rid) == {1, 2}
    # truncated to exactly what fits: the prefill argmax costs no cache
    # write, so cache_len - prompt_len + 1 tokens are deliverable
    assert len(by_rid[1].tokens) == 16 - 8 + 1
    # budget-1 decode ticks ran; the last write was at position pos-1 ==
    # cache_len-1, so nothing ever wrapped the cache
    assert by_rid[1].pos == 16
    # a prompt that exactly fills the cache is servable for one token
    # (the prefill argmax), not rejected
    assert len(by_rid[2].tokens) == 1
    # the original request is NOT mutated by the session-level clip
    assert by_rid[1].request.max_new_tokens == 20


def test_wire_byte_split_prefill_vs_decode(setup):
    """stats() must report prompt-proportional prefill bytes separately
    from per-generated-token decode bytes."""
    cfg, params = setup
    eng, done = _run_engine(cfg, params, 8)
    st = eng.stats()
    assert st["prefill_wire_bytes"] == sum(s.prefill_wire_bytes
                                           for s in done)
    assert st["decode_wire_bytes"] == sum(s.wire_bytes - s.prefill_wire_bytes
                                          for s in done)
    dec_toks = sum(len(s.tokens) - 1 for s in done)   # first token: prefill
    assert st["decode_wire_bytes_per_token"] == \
        st["decode_wire_bytes"] / dec_toks
    assert st["generated_tokens"] == sum(len(s.tokens) for s in done)
    assert "wire_bytes_per_token" not in st      # the skewed figure is gone
