"""Information-theory estimator correctness (the paper's analysis layer)."""
import numpy as np
import pytest

from repro.core.ib import binning, gcmi, info_plane, kde

RNG = np.random.default_rng(0)


def test_gcmi_known_gaussian():
    """For bivariate Gaussians with correlation r, I = -0.5 log2(1-r^2)."""
    n = 20_000
    for r in (0.3, 0.6, 0.9):
        x = RNG.normal(size=(n, 1))
        y = r * x + np.sqrt(1 - r * r) * RNG.normal(size=(n, 1))
        est = gcmi.gcmi_cc(x, y)
        true = -0.5 * np.log2(1 - r * r)
        assert abs(est - true) < 0.08, (r, est, true)


def test_gcmi_independent_near_zero():
    x = RNG.normal(size=(5000, 3))
    y = RNG.normal(size=(5000, 3))
    assert gcmi.gcmi_cc(x, y) < 0.05


def test_gcmi_invariance_under_monotone_transform():
    """MI is invariant to strictly monotone per-dim transforms (paper Eq. 1);
    the copula rank transform realizes this exactly."""
    n = 8000
    x = RNG.normal(size=(n, 2))
    y = x @ RNG.normal(size=(2, 2)) + 0.5 * RNG.normal(size=(n, 2))
    base = gcmi.gcmi_cc(x, y)
    warped = gcmi.gcmi_cc(np.exp(x), np.tanh(y) if False else y ** 3)
    assert abs(base - warped) < 0.05


def test_conditional_mi_ladder_decreases():
    """Conditioning on variables that carry the same information drives the
    conditional MI down — the paper's temporal-redundancy diagnostic."""
    n = 6000
    x = RNG.normal(size=(n, 4))
    h_prev = x @ RNG.normal(size=(4, 3)) + 0.2 * RNG.normal(size=(n, 3))
    h_last = h_prev @ RNG.normal(size=(3, 3)) + 0.2 * RNG.normal(size=(n, 3))
    unconditioned = gcmi.gcmi_cc(x, h_last)
    conditioned = gcmi.gccmi_ccc(x, h_last, h_prev)
    assert conditioned < 0.5 * unconditioned


def test_dpi_ordering():
    """Data-processing inequality: X -> Z -> Z' implies I(X;Z') <= I(X;Z).
    This is the paper's core argument for why the added bottleneck layer can
    only lose information."""
    n = 8000
    x = RNG.normal(size=(n, 4))
    z = np.tanh(x @ RNG.normal(size=(4, 4))) + 0.1 * RNG.normal(size=(n, 4))
    zp = np.tanh(z @ RNG.normal(size=(4, 2))) + 0.1 * RNG.normal(size=(n, 2))
    assert gcmi.gcmi_cc(x, zp) <= gcmi.gcmi_cc(x, z) + 0.05


def test_kde_mi_bounds():
    n = 3000
    t = RNG.normal(size=(n, 3))
    y = (t[:, 0] > 0).astype(int)
    i_ty = kde.mi_ty(t, y, 2)
    assert 0.5 < i_ty <= 1.0 + 0.05          # binary label: at most 1 bit
    i_tx = kde.mi_tx(t, noise_var=0.1)
    assert i_tx > 0


def test_kde_noise_var_monotone():
    """More noise -> less information about T (compression knob)."""
    t = RNG.normal(size=(2000, 2))
    vals = [kde.mi_tx(t, noise_var=v) for v in (0.01, 0.1, 1.0)]
    assert vals[0] > vals[1] > vals[2]


def test_binning_estimates():
    n = 4000
    t = RNG.normal(size=(n, 2))
    y = (t[:, 0] + 0.1 * RNG.normal(size=n) > 0).astype(int)
    i_ty = binning.bin_mi_ty(t, y, 2, n_bins=20)
    assert 0.6 < i_ty <= 1.0
    assert binning.bin_mi_tx(t, n_bins=20) > 5.0   # near log2(n) for distinct


def test_info_plane_pipeline():
    n = 1500
    x = RNG.normal(size=(n, 6))
    h = np.tanh(x @ RNG.normal(size=(6, 8)))
    y = (x[:, 0] > 0).astype(int)
    pt = info_plane.layer_point(h, x, y, 2)
    assert pt["I_XH"] > 1.0
    assert 0 < pt["I_HY"] <= 1.05


def test_temporal_redundancy_ladder():
    """Conditioning on previous temporal states must remove most of the
    information H_T carries about X (the redundancy the paper quantifies);
    the ladder is weakly decreasing up to estimator noise."""
    n, T, D, C_ = 6000, 5, 2, 3
    x = RNG.normal(size=(n, T, D))
    # redundant temporal states (the paper's saturated-LSTM regime): every
    # h_t carries the same underlying signal s(X) plus per-step noise, so
    # conditioning on previous states removes most of h_T's information and
    # conditioning on MORE states keeps removing (noise averaging)
    s = np.tanh(x.reshape(n, -1) @ RNG.normal(size=(T * D, C_)))
    h = s[:, None, :] + 0.3 * RNG.normal(size=(n, T, C_))
    unconditioned = gcmi.gcmi_cc(
        info_plane._reduce(x), info_plane._reduce(h[:, -1]))
    ladder = info_plane.temporal_redundancy(h, x, max_condition=3)
    assert all(v >= 0 for v in ladder)
    assert ladder[0] < 0.7 * unconditioned     # h_{T-1} explains most of h_T
    assert ladder[-1] <= ladder[0] + 0.05      # weakly decreasing ladder
