"""In-flight dynamic mode switching: controller policy (dwell, escalation,
vectorized selection parity) and the correctness pin that a mid-stream mode
switch leaves decode state identical to a fixed-mode run of the same
per-token mode sequence — for every decode-state family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import (Channel, ChannelConfig, TraceChannel,
                                channel_fleet, tx_seconds)
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.models import transformer as T
from repro.serving import (ContinuousBatchingEngine, ControllerConfig,
                           ModeController, Request)

ATOL = 3e-4

# attention (GQA KV cache), Griffin (RG-LRU + rolling local-attn window),
# and xLSTM (mLSTM + sLSTM) cover every decode-state family
ARCHS = ["qwen2.5-3b", "recurrentgemma-2b", "xlstm-125m"]

PROFILES = [ModeProfile(0, 100_000, 1.0, 0.9),
            ModeProfile(1, 10_000, 1.2, 0.8),
            ModeProfile(2, 1_000, 1.5, 0.7)]


def make_orch(**kw):
    kw.setdefault("requirement", AppRequirement(latency_budget_s=0.05))
    return Orchestrator([ModeProfile(p.mode, p.payload_bytes,
                                     p.expected_loss, p.expected_acc)
                         for p in PROFILES], **kw)


# -- vectorized selection ------------------------------------------------------

def test_choose_modes_matches_scalar_path():
    """``choose_modes(rids, caps)`` must be decision-for-decision identical
    to the scalar observe_capacity + choose_mode loop, including EMA
    bootstrap, cold start, min_acc filtering, hysteresis, and per-link
    switch counting."""
    scalar, vector = make_orch(), make_orch()
    rids = ["a", "b", "c"]
    strict = AppRequirement(latency_budget_s=0.05, min_acc=0.85)
    for o in (scalar, vector):
        o.register("a")
        o.register("b", strict)
        o.register("c")
    rng = np.random.default_rng(0)
    for t in range(40):
        # spread over the feasibility boundaries of all three profiles,
        # with occasional missing observations
        caps = [None if rng.random() < 0.15
                else float(10 ** rng.uniform(3.5, 7.5)) for _ in rids]
        want = []
        for r, c in zip(rids, caps):
            if c is not None:
                scalar.observe_capacity(c, rid=r)
            want.append(scalar.choose_mode(rid=r))
        got = vector.choose_modes(rids, caps)
        assert got.tolist() == want, f"tick {t}: {got.tolist()} != {want}"
    for r in rids:
        ls, lv = scalar.register(r), vector.register(r)
        assert lv.mode == ls.mode
        assert lv.switches == ls.switches
        assert lv.ticks == ls.ticks
        np.testing.assert_allclose(lv.capacity_ema, ls.capacity_ema)


def test_choose_modes_hold_keeps_current_mode():
    orch = make_orch(ema=0.0, hysteresis=1.0)
    orch.register("u")
    assert orch.choose_modes(["u"], [1e9]).tolist() == [0]
    # capacity collapses, but the hold mask (the controller's dwell) wins
    assert orch.choose_modes(["u"], [1e3], hold=[True]).tolist() == [0]
    assert orch.register("u").switches == 0
    # EMA tracked through the held tick: released, it switches immediately
    assert orch.choose_modes(["u"], [1e3]).tolist() == [2]


# -- controller policy ---------------------------------------------------------

def test_controller_dwell_prevents_flapping():
    """A capacity trace oscillating across mode 0's feasibility boundary
    flaps the bare per-tick policy every tick; the controller's dwell time
    bounds switches to at most one per dwell window."""
    boundary = PROFILES[0].payload_bytes / (0.05 - 0.004)
    n, dwell = 40, 8
    osc = [boundary * (1.05 if t % 2 else 0.95) for t in range(n)]

    bare = make_orch(ema=0.0, hysteresis=1.0)
    bare.register("u")
    for c in osc:
        bare.observe_capacity(c, rid="u")
        bare.choose_mode(rid="u")
    assert bare.register("u").switches > n // 2      # the failure mode

    orch = make_orch(ema=0.0, hysteresis=1.0)
    ctl = ModeController(orch, ControllerConfig(dwell_ticks=dwell,
                                                escalate_util=10.0))
    ctl.admit("u", None, osc[0], tick=0)
    for t, c in enumerate(osc[1:], start=1):
        ctl.step_modes(["u"], [c], t)
    assert ctl.control("u").switches <= n // dwell + 1
    assert ctl.control("u").switches < bare.register("u").switches


def test_deadline_escalation_overrides_dwell():
    """When predicted transfer time blows the latency budget, the session
    must drop to the cheapest mode IMMEDIATELY — dwell exists to damp
    flapping, not to ride a collapsing link into deadline misses."""
    orch = make_orch(ema=0.0, hysteresis=1.0)
    ctl = ModeController(orch, ControllerConfig(dwell_ticks=1000,
                                                util_ema=0.0))
    assert ctl.admit("u", None, 1e9, tick=0) == 0     # good link: raw mode
    modes = ctl.step_modes(["u"], [1e3], 1)           # link collapses
    assert modes.tolist() == [2]                      # cheapest, now
    c = ctl.control("u")
    assert c.escalations == 1
    assert c.trace == [(0, 0, 0), (1, 0, 2)]
    # and the orchestrator's link state agrees (hysteresis next tick uses it)
    assert orch.register("u").mode == 2


def test_no_escalation_on_cold_start_links():
    """A session with no channel (no capacity ever observed) must stay on
    the optimistic cold-start mode — the phantom 0.0 capacity EMA must not
    feed the deadline tracker and force-drop it to the cheapest mode."""
    orch = make_orch()
    ctl = ModeController(orch, ControllerConfig(util_ema=0.0))
    assert ctl.admit("u", None, None, tick=0) == 0
    for t in range(1, 5):
        assert ctl.step_modes(["u"], [None], t).tolist() == [0]
    c = ctl.control("u")
    assert c.escalations == 0 and c.switches == 0
    # first real observation brings the tracker online without phantom
    # history: a healthy link keeps the mode
    assert ctl.step_modes(["u"], [1e9], 5).tolist() == [0]
    assert ctl.control("u").escalations == 0


def test_controller_lifecycle_detaches():
    orch = make_orch()
    ctl = ModeController(orch)
    ctl.admit("u", None, 1e8, tick=0)
    assert ctl.n_attached == 1
    rec = ctl.finish("u")
    assert rec is not None and rec.mode == 0
    assert ctl.n_attached == 0
    assert "u" not in orch._links


# -- switch-vs-fixed decode-state equivalence ---------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_midstream_switch_matches_fixed_mode_sequence(arch):
    """Decoding with modes switching mid-stream (the mixed step, as the
    engine runs it when the controller re-selects) must produce the same
    logits at every step AND the same final decode state as running the
    identical per-token mode sequence through the per-mode scalar step —
    i.e. switching is stateless: nothing about a past mode lingers in the
    caches/carries beyond the tokens it produced."""
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    stacked = BN.bank_stack(params["bneck_modes"], cfg.split)
    B, cache_len = 2, 32
    mode_seq = [0, 0, 1, 1, 0, 1]        # two upswitches, one downswitch
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        size=(len(mode_seq), B, 1)).astype(np.int32)

    st_mix = T.init_decode_state(cfg, B, cache_len)
    st_ref = T.init_decode_state(cfg, B, cache_len)
    for t, m in enumerate(mode_seq):
        tok = jnp.asarray(toks[t])
        lg_mix, st_mix = SP.split_decode_step_mixed(
            params, stacked, tok, st_mix, jnp.full((B,), t, jnp.int32),
            cfg, jnp.full((B,), m, jnp.int32))
        lg_ref, st_ref, _ = SP.split_decode_step(
            params, tok, st_ref, jnp.int32(t), cfg, mode=m)
        np.testing.assert_allclose(
            np.asarray(lg_mix), np.asarray(lg_ref), atol=ATOL, rtol=ATOL,
            err_msg=f"{arch}: logits diverge at step {t} (mode {m})")
    for a, b in zip(jax.tree.leaves(st_mix), jax.tree.leaves(st_ref)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=ATOL, rtol=ATOL,
            err_msg=f"{arch}: decode state diverges after switches")


# -- engine-level adaptive vs frozen ------------------------------------------

def test_engine_adaptive_beats_frozen_on_fade():
    """On identical fading channels, the adaptive controller must spend no
    more wire bytes/token than admission-frozen modes, at an
    equal-or-better deadline-miss rate, and record the mid-stream switch."""
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    pay = {m: BN.mode_payload_bytes(cfg, 1, 1, m)
           for m in range(cfg.split.n_modes)}
    budget = 0.006
    hi = 4.0 * max(pay.values()) / (budget - 0.004)
    lo = 1.3 * min(pay.values()) / (budget - 0.004)
    fade = np.concatenate([np.full(4, hi), np.linspace(hi, lo, 6),
                           np.full(24, lo)])
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(2)]

    def run(adaptive: bool):
        orch = Orchestrator(
            [ModeProfile(m, pay[m], float(m)) for m in pay],
            AppRequirement(latency_budget_s=budget), ema=0.5, hysteresis=0.9)
        kw = ({"controller": ModeController(orch,
                                            ControllerConfig(dwell_ticks=2))}
              if adaptive else {"orchestrator": orch, "freeze_modes": True})
        eng = ContinuousBatchingEngine(params, cfg, n_slots=2,
                                       cache_len=64, **kw)
        done = eng.run([Request(rid=i, prompt=prompts[i], max_new_tokens=16,
                                channel=TraceChannel(fade))
                        for i in range(2)])
        assert len(done) == 2
        return eng.stats(), done

    ast, adone = run(adaptive=True)
    fst, fdone = run(adaptive=False)
    assert ast["mode_policy"] == "adaptive"
    assert fst["mode_policy"] == "frozen"
    # frozen sessions admitted on the good link lock in the raw mode
    assert all(s.admission_mode == 0 and len(s.mode_trace) == 1
               for s in fdone)
    assert fst["mode_switches"] == 0
    # the controller switched mid-stream and the trace recorded it
    assert ast["mode_switches"] >= 1
    assert any(len(s.mode_trace) > 1 for s in adone)
    assert ast["decode_wire_bytes_per_token"] \
        < fst["decode_wire_bytes_per_token"]
    assert ast["deadline_miss_rate"] <= fst["deadline_miss_rate"]
    # per-session ledgers reconcile under time-varying modes
    for s in adone:
        dec = sum(BN.mode_payload_bytes(cfg, 1, 1, m) * c
                  for m, c in s.mode_counts.items())
        assert s.wire_bytes == s.prefill_wire_bytes + dec


def test_engine_rejects_conflicting_policy_config():
    cfg = get_reduced("qwen2.5-3b")
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    orch = make_orch()
    ctl = ModeController(orch)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(params, cfg, controller=ctl,
                                 freeze_modes=True)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(params, cfg, controller=ctl,
                                 orchestrator=make_orch())


# -- channel hygiene -----------------------------------------------------------

def test_channel_default_config_not_shared():
    a, b = Channel(), Channel()
    assert a.cfg is not b.cfg
    a.cfg.mean_mbps = 1.0
    assert b.cfg.mean_mbps != 1.0


def test_channel_fleet_configs_isolated():
    base = ChannelConfig(mean_mbps=100.0)
    fleet = channel_fleet(3, base, seed=5)
    assert len({id(c.cfg) for c in fleet}) == 3
    assert all(c.cfg is not base for c in fleet)
    fleet[0].cfg.mean_mbps = -1.0
    assert base.mean_mbps == 100.0               # caller's cfg untouched
    assert fleet[1].cfg.mean_mbps > 0            # members isolated
    # distinct sub-seeds: members realize different traces
    t0, t1 = fleet[1].trace(8), fleet[2].trace(8)
    assert not np.allclose(t0, t1)


def test_channel_trace_advances_live_state():
    """``trace`` is documented to ADVANCE the live channel (it drives
    ``step``): interleaving trace and step continues one realization."""
    cfg = ChannelConfig(seed=3)
    a, b = Channel(cfg), Channel(cfg)
    first = a.trace(5)
    np.testing.assert_allclose(first, [b.step() for _ in range(5)])
    assert a.t == pytest.approx(5 * cfg.tick_seconds)
    # continuing after trace == continuing after the equivalent steps
    np.testing.assert_allclose(a.step(), b.step())


def test_trace_channel_replays_and_holds():
    tc = TraceChannel([10.0, 20.0, 30.0])
    assert [tc.step() for _ in range(5)] == [10.0, 20.0, 30.0, 30.0, 30.0]
    cyc = TraceChannel([1.0, 2.0], cycle=True)
    assert [cyc.step() for _ in range(4)] == [1.0, 2.0, 1.0, 2.0]
    with pytest.raises(ValueError):
        TraceChannel([])


def test_tx_seconds_matches_vectorized_rtt():
    """The scalar and vectorized feasibility paths must share one RTT."""
    from repro.core.channel import RTT_SECONDS
    assert tx_seconds(0, 1e9) == pytest.approx(RTT_SECONDS)
