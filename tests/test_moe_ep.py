"""Expert-parallel MoE (shard_map all-to-all schedule) vs the einsum oracle.

Runs in a subprocess with 8 forced host devices (same pattern as
test_pipeline_pods.py) so the main pytest process keeps 1 device.
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
jax.config.update("jax_default_matmul_precision", "highest")
from repro.launch.mesh import mesh_context
from repro.models import moe, moe_ep

mesh = jax.make_mesh((2, 4), ('data', 'model'))
d, dff, E, k = 32, 64, 4, 2
p = moe.moe_init(jax.random.PRNGKey(0), d, dff, E, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, d), jnp.float32)

# support guard
assert moe_ep.moe_supports_ep(E, mesh, 8, 16)
assert not moe_ep.moe_supports_ep(3, mesh, 8, 16)      # E % model != 0
assert moe_ep.moe_supports_ep(E, mesh, 6, 16)          # batch % dp == 0 ok
assert not moe_ep.moe_supports_ep(E, mesh, 5, 16)      # batch % dp != 0
assert not moe_ep.moe_supports_ep(E, mesh, 8, 6)       # seq % model != 0
assert not moe_ep.moe_supports_ep(E, None, 8, 16)

# forward equivalence at slack capacity (no dropped tokens)
y_ref, aux_ref = moe.moe_apply(p, x, k=k, capacity_factor=8.0)
with mesh_context(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ep.moe_apply_ep(
        p, x, k=k, capacity_factor=8.0, mesh=mesh))(p, x)
err = float(jnp.max(jnp.abs(y_ref - y_ep)))
assert err < 1e-5, f'fwd err {err}'
# aux is a mean of per-group load-balance terms; EP groups tokens per chip
# (B/dp x S/m) while the oracle groups per batch row — same estimator,
# different grouping, so compare loosely
assert abs(float(aux_ref) - float(aux_ep)) < 0.1

# gradient equivalence on the token path (both a2a transposes + the
# scatter-add transpose); aux is excluded — its grouping differs (above)
def loss(fn):
    def f(p, x):
        y, _ = fn(p, x)
        return jnp.sum(y ** 2)
    return f
with mesh_context(mesh):
    g_ep = jax.jit(jax.grad(loss(lambda p, x: moe_ep.moe_apply_ep(
        p, x, k=k, capacity_factor=8.0, mesh=mesh))))(p, x)
g_ref = jax.grad(loss(lambda p, x: moe.moe_apply(
    p, x, k=k, capacity_factor=8.0)))(p, x)
gerr = jax.tree.reduce(max, jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ep, g_ref))
assert gerr < 1e-3, f'grad err {gerr}'

# tight capacity: WHICH tokens drop differs (EP groups per chip, the
# oracle per batch row) but the drop volume must be comparable and the
# output finite
y_ref, _ = moe.moe_apply(p, x, k=k, capacity_factor=1.0)
with mesh_context(mesh):
    y_ep, _ = jax.jit(lambda p, x: moe_ep.moe_apply_ep(
        p, x, k=k, capacity_factor=1.0, mesh=mesh))(p, x)
assert bool(jnp.all(jnp.isfinite(y_ep)))
def zero_rows(y):
    return int(jnp.sum(jnp.all(jnp.abs(y) < 1e-9, axis=-1)))
n_tok = x.shape[0] * x.shape[1]
assert abs(zero_rows(y_ep) - zero_rows(y_ref)) <= n_tok // 4, \
    (zero_rows(y_ep), zero_rows(y_ref))
print('EP-MoE OK')
"""


def test_moe_ep_matches_einsum_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "EP-MoE OK" in r.stdout
