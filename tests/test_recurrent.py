"""RG-LRU / xLSTM recurrence correctness: decode steps reproduce the
full-sequence pass; chunked-remat scans are exact."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import rglru as R
from repro.models import xlstm as X
from repro.models.scan_utils import chunked_scan


def test_chunked_scan_matches_plain():
    def cell(c, x):
        c = 0.9 * c + x
        return c, c * 2.0
    xs = jax.random.normal(jax.random.PRNGKey(0), (64, 4))
    c0 = jnp.zeros((4,))
    c_ref, ys_ref = jax.lax.scan(cell, c0, xs)
    c_chk, ys_chk = chunked_scan(cell, c0, xs, chunk=16)
    np.testing.assert_allclose(np.asarray(ys_chk), np.asarray(ys_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c_chk), np.asarray(c_ref),
                               rtol=1e-6)


def test_chunked_scan_grad_matches():
    def cell(c, x):
        c = jnp.tanh(0.5 * c + x)
        return c, c
    xs = jax.random.normal(jax.random.PRNGKey(1), (32, 3))
    c0 = jnp.zeros((3,))
    f_plain = lambda xs: jnp.sum(jax.lax.scan(cell, c0, xs)[1])
    f_chunk = lambda xs: jnp.sum(chunked_scan(cell, c0, xs, chunk=8)[1])
    g1 = jax.grad(f_plain)(xs)
    g2 = jax.grad(f_chunk)(xs)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-5)


def test_rglru_step_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, d, dr = 2, 12, 16, 24
    p = R.rglru_init(key, d, dr, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    full = R.rglru_full(p, x)
    state = R.rglru_state_init(B, dr)
    outs = []
    for t in range(S):
        o, state = R.rglru_step(p, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_rglru_assoc_scan_matches_sequential():
    key = jax.random.PRNGKey(3)
    B, S, d, dr = 1, 32, 8, 8
    p = R.rglru_init(key, d, dr, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    seq = R.rglru_full(p, x, use_assoc_scan=False)
    assoc = R.rglru_full(p, x, use_assoc_scan=True)
    np.testing.assert_allclose(np.asarray(assoc), np.asarray(seq),
                               rtol=1e-4, atol=1e-5)


def test_rglru_forgets_with_small_a():
    """With a ~ 0 (Λ very negative) the recurrence passes inputs through
    nearly memorylessly; with a ~ 1 it integrates."""
    key = jax.random.PRNGKey(0)
    B, S, dr = 1, 8, 4
    p = R.rglru_init(key, dr, dr, dtype=jnp.float32)
    x = jnp.ones((B, S, dr), jnp.float32)
    p_forget = dict(p, lam=jnp.full((dr,), -20.0))
    u = jnp.ones((B, S, dr))
    a_f, _ = R._gates(p_forget, u)
    assert float(jnp.max(a_f)) < 1e-6
    p_keep = dict(p, lam=jnp.full((dr,), 20.0))
    a_k, _ = R._gates(p_keep, u)
    assert float(jnp.min(a_k)) > 0.99


def test_mlstm_step_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, d, H = 2, 10, 16, 4
    p = X.mlstm_init(key, d, H, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    full = X.mlstm_full(p, x, H)
    state = X.mlstm_state_init(B, d, H)
    outs = []
    for t in range(S):
        o, state = X.mlstm_step(p, x[:, t:t + 1], state, H)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_slstm_step_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, d, H = 2, 10, 16, 4
    p = X.slstm_init(key, d, H, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(key, (B, S, d), jnp.float32)
    full = X.slstm_full(p, x, H)
    state = X.slstm_state_init(B, d)
    outs = []
    for t in range(S):
        o, state = X.slstm_step(p, x[:, t:t + 1], state, H)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_stabilizer_no_overflow():
    """Large forget/input preactivations must not produce inf/nan (the m
    stabilizer is the xLSTM paper's key numerical device)."""
    key = jax.random.PRNGKey(0)
    B, S, d, H = 1, 16, 8, 2
    p = X.mlstm_init(key, d, H, dtype=jnp.float32)
    x = 100.0 * jax.random.normal(key, (B, S, d), jnp.float32)
    y = X.mlstm_full(p, x, H)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# scan-op wiring parity: the ops.rglru_scan_op paths (eval default) must be
# bit-identical to the legacy chunked_scan cell paths they replaced
# ---------------------------------------------------------------------------

def test_rglru_full_scan_op_matches_legacy():
    """rglru_full: train=True (legacy chunked_scan, differentiable) and the
    default eval path (ops.rglru_scan_op) must agree BIT FOR BIT."""
    p = R.rglru_init(jax.random.PRNGKey(2), 64, 96)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 17, 64), jnp.bfloat16)
    y_legacy = R.rglru_full(p, x, train=True)
    y_op = R.rglru_full(p, x, train=False)
    np.testing.assert_array_equal(np.asarray(y_legacy, np.float32),
                                  np.asarray(y_op, np.float32))


def test_rglru_prefill_scan_op_matches_legacy_lengths():
    """rglru_prefill through the scan op vs the legacy chunked_scan path:
    outputs AND carried state (h + conv history) bit-identical, including
    non-block-multiple lengths and a non-zero carried h0."""
    d, dr, B, S = 64, 96, 4, 13
    p = R.rglru_init(jax.random.PRNGKey(4), d, dr)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, d), jnp.bfloat16)
    st = {"h": jax.random.normal(jax.random.PRNGKey(6), (B, dr), jnp.float32),
          "conv": jax.random.normal(jax.random.PRNGKey(7), (B, 3, dr),
                                    jnp.float32)}
    for lengths in (None, jnp.asarray([13, 7, 3, 1], jnp.int32)):
        y0, s0 = R.rglru_prefill(p, x, st, lengths=lengths,
                                 use_scan_op=False)
        y1, s1 = R.rglru_prefill(p, x, st, lengths=lengths,
                                 use_scan_op=True)
        np.testing.assert_array_equal(np.asarray(y0, np.float32),
                                      np.asarray(y1, np.float32))
        for k in s0:
            np.testing.assert_array_equal(np.asarray(s0[k]),
                                          np.asarray(s1[k]))


def test_mlstm_full_scan_op_matches_legacy():
    """mlstm_full: the decomposed recurrence (m-scan -> parallel gates ->
    normalizer via ops.rglru_scan_op -> C-only chunked_scan) must be
    bit-identical to scanning the fused cell."""
    H, d = 4, 64
    p = X.mlstm_init(jax.random.PRNGKey(8), d, H)
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 19, d), jnp.bfloat16)
    y_legacy = X.mlstm_full(p, x, H, train=True)
    y_op = X.mlstm_full(p, x, H, train=False)
    np.testing.assert_array_equal(np.asarray(y_legacy, np.float32),
                                  np.asarray(y_op, np.float32))


def test_mlstm_prefill_scan_op_matches_legacy_lengths():
    """mlstm_prefill decomposed vs fused-cell path: outputs and the full
    final state (C, n, m) bit-identical for ragged lengths, and chained
    from a REAL mid-stream state (finite m, non-zero n/C)."""
    H, d, B, S = 4, 64, 3, 11
    p = X.mlstm_init(jax.random.PRNGKey(10), d, H)
    x = jax.random.normal(jax.random.PRNGKey(11), (B, S, d), jnp.bfloat16)
    st = X.mlstm_state_init(B, d, H)
    for lengths in (None, jnp.asarray([11, 5, 2], jnp.int32)):
        y0, s0 = X.mlstm_prefill(p, x, st, H, lengths=lengths,
                                 use_scan_op=False)
        y1, s1 = X.mlstm_prefill(p, x, st, H, lengths=lengths,
                                 use_scan_op=True)
        np.testing.assert_array_equal(np.asarray(y0, np.float32),
                                      np.asarray(y1, np.float32))
        for k in s0:
            np.testing.assert_array_equal(np.asarray(s0[k]),
                                          np.asarray(s1[k]))
    # continue from the state the first prefill left behind
    _, mid0 = X.mlstm_prefill(p, x, st, H, use_scan_op=False)
    _, mid1 = X.mlstm_prefill(p, x, st, H, use_scan_op=True)
    y0, e0 = X.mlstm_prefill(p, x, mid0, H,
                             lengths=jnp.asarray([4, 11, 8], jnp.int32),
                             use_scan_op=False)
    y1, e1 = X.mlstm_prefill(p, x, mid1, H,
                             lengths=jnp.asarray([4, 11, 8], jnp.int32),
                             use_scan_op=True)
    np.testing.assert_array_equal(np.asarray(y0, np.float32),
                                  np.asarray(y1, np.float32))
    for k in e0:
        np.testing.assert_array_equal(np.asarray(e0[k]), np.asarray(e1[k]))
