"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles
across shape/dtype sweeps (per-kernel allclose, per the deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bottleneck_quant import bottleneck_quant
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.rglru_scan import rglru_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("M,K,N", [
    (128, 512, 128), (256, 1024, 256), (384, 512, 128), (128, 2048, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_quant_sweep(M, K, N, dtype):
    x = (0.5 * jax.random.normal(KEY, (M, K))).astype(dtype)
    w = (0.02 * jax.random.normal(jax.random.PRNGKey(1), (K, N))).astype(dtype)
    codes, scales = bottleneck_quant(x, w, block_m=128, block_k=512,
                                     interpret=True)
    c_ref, s_ref = ref.bottleneck_quant_ref(x, w)
    # int8 codes may differ by 1 ulp where round() ties differ across orders
    diff = np.abs(codes.astype(np.int32) - np.asarray(c_ref, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(scales), np.asarray(s_ref),
                               rtol=1e-3)


@pytest.mark.parametrize("M,N,D", [
    (128, 128, 512), (256, 256, 1024), (128, 512, 512),
])
def test_dequant_matmul_sweep(M, N, D):
    x = jax.random.normal(KEY, (M, N))
    codes, scales = ref.bottleneck_quant_ref(x, jnp.eye(N))
    w = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (N, D))
    y = dequant_matmul(codes, scales, w, block_m=128, block_d=min(D, 512),
                       interpret=True)
    y_ref = ref.dequant_matmul_ref(codes, scales, w)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("B,S,D,bs,bd", [
    (1, 512, 128, 256, 128), (2, 1024, 256, 256, 128), (2, 512, 512, 128, 256),
])
def test_rglru_scan_sweep(B, S, D, bs, bd):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D)))
    b = jax.random.normal(jax.random.PRNGKey(3), (B, S, D))
    h = rglru_scan(a, b, block_s=bs, block_d=bd, interpret=True)
    h_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_carry_across_time_blocks():
    """The VMEM carry must persist across sequential grid steps: compare a
    2-block run to the oracle on a signal where state matters."""
    B, S, D = 1, 512, 128
    a = jnp.full((B, S, D), 0.999)          # long memory
    b = jnp.zeros((B, S, D)).at[:, 0, :].set(1.0)
    h = rglru_scan(a, b, block_s=256, block_d=128, interpret=True)
    h_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5)
    # state visibly decays across the block boundary
    assert float(h[0, 257, 0]) == pytest.approx(0.999 ** 257, rel=1e-3)


@pytest.mark.parametrize("bits", [8, 4, 1])
def test_bottleneck_quant_agrees_with_quant_module(bits):
    """The fused kernel (and its oracle) must produce the SAME wire format
    as ``repro.core.quant`` for every calibrated bit width — including the
    bits=1 ternary code, which divided by a zero qmax before the floor fix
    (inf scales -> NaN payloads)."""
    from repro.core import quant
    x = jax.random.normal(KEY, (128, 512))
    w = 0.02 * jax.random.normal(jax.random.PRNGKey(7), (512, 128))
    z = x @ w
    q_codes, q_scales = quant.quantize(z, bits)
    k_codes, k_scales = bottleneck_quant(x, w, bits=bits, block_m=128,
                                         block_k=512, interpret=True)
    r_codes, r_scales = ref.bottleneck_quant_ref(x, w, bits)
    for codes, scales in [(k_codes, k_scales), (r_codes, r_scales)]:
        assert np.isfinite(np.asarray(scales)).all()
        np.testing.assert_allclose(np.asarray(scales),
                                   np.asarray(q_scales), rtol=1e-5)
        diff = np.abs(np.asarray(codes, np.int32)
                      - np.asarray(q_codes, np.int32))
        assert diff.max() <= 1           # round() ties may break either way
        assert (diff > 0).mean() < 0.01
        assert np.abs(np.asarray(codes)).max() <= quant.qmax(bits)


# ---------------------------------------------------------------------------
# fused mixed-mode boundary kernel (kernels/boundary_mixed.py)
# ---------------------------------------------------------------------------

from repro.kernels.boundary_mixed import boundary_mixed_grouped  # noqa: E402


def _stacked_bank(widths_bits, d=128, seed=0, dtype=jnp.bfloat16):
    """A synthetic stacked mode bank (same pytree as bottleneck.bank_stack
    produces) with the given [(width, bits)] heads."""
    wmax = max(w for w, _ in widths_bits)
    keys = jax.random.split(jax.random.PRNGKey(seed), 2 * len(widths_bits))
    downs, ups = [], []
    for i, (w, _) in enumerate(widths_bits):
        dw = 0.05 * jax.random.normal(keys[2 * i], (d, w))
        uw = 0.05 * jax.random.normal(keys[2 * i + 1], (w, d))
        downs.append(jnp.pad(dw, ((0, 0), (0, wmax - w))).astype(dtype))
        ups.append(jnp.pad(uw, ((0, wmax - w), (0, 0))).astype(dtype))
    return {
        "down_w": jnp.stack(downs),
        "up_w": jnp.stack(ups),
        "norm_scale": jnp.ones((len(widths_bits), d), dtype),
        "width": jnp.asarray([w for w, _ in widths_bits], jnp.int32),
        "bits": jnp.asarray([b for _, b in widths_bits], jnp.int32),
    }


# widths cover full-wmax, narrow (fewer chunks than wmax), and a
# non-chunk-aligned width (masked last chunk); bits cover int8 / int4 /
# ternary / unquantized
HET_BANK = [(128, 8), (256, 4), (200, 1), (384, 0)]


def _grouped_parity(stacked, x, modes):
    """Run the Pallas kernel (interpret) and the blocked jnp oracle on the
    SAME mode-grouped layout and return both plus the serving reference."""
    B, S, d = x.shape
    block_r = 16 if jnp.dtype(x.dtype).itemsize == 2 else 8
    rmode = jnp.repeat(jnp.asarray(modes, jnp.int32), S)
    dest, tb = ops.group_layout(stacked, rmode, block_r, 128)
    xp = jnp.zeros((tb["P"], d), x.dtype).at[dest].set(x.reshape(B * S, d))
    yk = boundary_mixed_grouped(
        xp, stacked["down_w"], stacked["up_w"], stacked["norm_scale"],
        tb["hid"], tb["nchunk"], tb["width"], tb["bits"],
        block_r=block_r, block_w=128, interpret=True)
    yo = ref.boundary_mixed_grouped_ref(
        xp, stacked["down_w"], stacked["up_w"], stacked["norm_scale"],
        np.asarray(tb["hid"]), np.asarray(tb["nchunk"]),
        np.asarray(tb["width"]), np.asarray(tb["bits"]),
        block_r=block_r, block_w=128)
    return yk, yo


@pytest.mark.parametrize("mode", [0, 1, 2, 3, 4])
def test_boundary_kernel_bitwise_every_calibrated_mode(mode):
    """Uniform-mode batches: the Pallas kernel must match the blocked jnp
    oracle BIT FOR BIT for every calibrated mode — bits 8, 4, the ternary
    bits=1 code, the unquantized bits=0 wire, and the raw mode-0
    passthrough."""
    stacked = _stacked_bank(HET_BANK)
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 1, 128)
                          ).astype(jnp.bfloat16)
    modes = jnp.full((8,), mode, jnp.int32)
    yk, yo = _grouped_parity(stacked, x, modes)
    np.testing.assert_array_equal(np.asarray(yk, np.float32),
                                  np.asarray(yo, np.float32))
    # and the dispatcher output must agree with the serving jnp reference
    y_op = ops.boundary_mixed_op(stacked, x, modes, interpret=True)
    y_ref = ref.boundary_mixed_ref(stacked, x, modes)
    np.testing.assert_allclose(np.asarray(y_op, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("B", [1, 8, 32])
def test_boundary_kernel_heterogeneous_pool_sizes(B):
    """Mixed-mode pools (every slot on its own head) at pool sizes 1/8/32:
    bit-for-bit vs the blocked oracle, tight agreement vs the serving
    reference, and exact passthrough for raw-mode rows."""
    stacked = _stacked_bank(HET_BANK)
    rng = np.random.default_rng(B)
    x = jnp.asarray(rng.normal(size=(B, 1, 128)), jnp.bfloat16)
    modes = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    yk, yo = _grouped_parity(stacked, x, modes)
    np.testing.assert_array_equal(np.asarray(yk, np.float32),
                                  np.asarray(yo, np.float32))
    y_op = np.asarray(ops.boundary_mixed_op(stacked, x, modes,
                                            interpret=True), np.float32)
    y_ref = np.asarray(ref.boundary_mixed_ref(stacked, x, modes), np.float32)
    np.testing.assert_allclose(y_op, y_ref, atol=2e-2, rtol=2e-2)
    raw = np.asarray(modes) == 0
    np.testing.assert_array_equal(y_op[raw], np.asarray(x, np.float32)[raw])


def test_boundary_kernel_prefill_rows():
    """[B, S, d] prefill-shaped inputs (S > 1): every token row of a batch
    row rides that row's mode; parity must hold with per-token grouping."""
    stacked = _stacked_bank(HET_BANK)
    rng = np.random.default_rng(5)
    B, S = 5, 3
    x = jnp.asarray(rng.normal(size=(B, S, 128)), jnp.bfloat16)
    modes = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    yk, yo = _grouped_parity(stacked, x, modes)
    np.testing.assert_array_equal(np.asarray(yk, np.float32),
                                  np.asarray(yo, np.float32))
    y_op = np.asarray(ops.boundary_mixed_op(stacked, x, modes,
                                            interpret=True), np.float32)
    y_ref = np.asarray(ref.boundary_mixed_ref(stacked, x, modes), np.float32)
    np.testing.assert_allclose(y_op, y_ref, atol=2e-2, rtol=2e-2)


def test_boundary_dispatcher_unaligned_widths_fall_back():
    """A bank whose widest head is not 128-aligned cannot tile the kernel;
    the dispatcher must route to the jnp reference and agree EXACTLY."""
    stacked = _stacked_bank([(32, 8), (48, 4), (24, 1)])   # wmax = 48
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(6, 1, 128)), jnp.bfloat16)
    modes = jnp.asarray(rng.integers(0, 4, 6), jnp.int32)
    y_op = ops.boundary_mixed_op(stacked, x, modes, interpret=True)
    y_ref = ref.boundary_mixed_ref(stacked, x, modes)
    np.testing.assert_array_equal(np.asarray(y_op, np.float32),
                                  np.asarray(y_ref, np.float32))


# ---------------------------------------------------------------------------
# fused decode-tail megakernel (kernels/boundary_mixed.decode_tail_grouped)
# ---------------------------------------------------------------------------

from repro.kernels.boundary_mixed import decode_tail_grouped  # noqa: E402


def _tail_inputs(B, d=128, V=512, H=1, seed=0, norm_kind="rmsnorm"):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, 1, d)).astype(jnp.bfloat16)
    scale = (0.1 * jax.random.normal(ks[1], (d,)) + 1.0).astype(jnp.bfloat16)
    bias = (0.1 * jax.random.normal(ks[2], (d,))).astype(jnp.bfloat16) \
        if norm_kind == "layernorm" else None
    heads = jax.random.normal(ks[3], (H, d, V)).astype(jnp.bfloat16)
    return x, scale, bias, heads


@pytest.mark.parametrize("norm_kind", ["rmsnorm", "layernorm"])
@pytest.mark.parametrize("B", [1, 8, 32])
def test_decode_tail_kernel_bitwise_pool_sizes(B, norm_kind):
    """The tail megakernel must match its blocked jnp oracle BIT FOR BIT on
    the same head-grouped layout, at pool sizes 1/8/32 and for both norm
    families the serving archs use (rmsnorm / xLSTM layernorm)."""
    H = 3
    x, scale, bias, heads = _tail_inputs(B, H=H, seed=B, norm_kind=norm_kind)
    hidx = jax.random.randint(jax.random.PRNGKey(B + 7), (B,), 0, H)
    block_r = 16
    dest, hid_g, P = ops.head_layout(hidx.astype(jnp.int32), H, block_r)
    xp = jnp.zeros((P, x.shape[-1]), x.dtype).at[dest].set(x[:, 0])
    bias_arr = bias if bias is not None \
        else jnp.zeros((x.shape[-1],), scale.dtype)
    tk = decode_tail_grouped(xp, heads, scale, bias_arr, hid_g,
                             block_r=block_r, block_v=128,
                             norm_kind=norm_kind, interpret=True)
    to = ref.decode_tail_grouped_ref(np.asarray(xp), heads, scale, bias_arr,
                                     np.asarray(hid_g), block_r=block_r,
                                     block_v=128, norm_kind=norm_kind)
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(to))
    # dispatcher tokens == serving reference tokens (argmax is exact: the
    # kernel computes the same f32 logits chunk-by-chunk)
    t_op = ops.decode_tail_op(x, scale, bias, heads, hidx,
                              norm_kind=norm_kind, interpret=True)
    t_ref = ref.decode_tail_ref(x, scale, bias, heads, hidx,
                                norm_kind=norm_kind)
    np.testing.assert_array_equal(np.asarray(t_op), np.asarray(t_ref))


def test_decode_tail_matches_legacy_chain():
    """The op's CPU path must reproduce the legacy
    norm_apply -> lm_logits -> argmax chain EXACTLY (expression identity,
    not allclose) for both the untied matmul head and the tied embedding
    einsum — this is what lets serving swap the chain for the op with
    pinned token streams."""
    d, V = 128, 512
    x, scale, _, heads = _tail_inputs(16, d=d, V=V)
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    xn = (y * scale.astype(jnp.float32)).astype(x.dtype)
    # untied: x_f32 @ w_f32 (lm_logits expression)
    legacy = jnp.argmax(xn.astype(jnp.float32)
                        @ heads[0].astype(jnp.float32), -1).astype(jnp.int32)
    got = ops.decode_tail_op(x, scale, None, heads[:1])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))
    # tied: einsum("bsd,vd->bsv") against the embedding table
    table = jax.random.normal(jax.random.PRNGKey(11), (V, d), jnp.bfloat16)
    legacy_t = jnp.argmax(
        jnp.einsum("bsd,vd->bsv", xn.astype(jnp.float32),
                   table.astype(jnp.float32)), -1).astype(jnp.int32)
    got_t = ops.decode_tail_op(x, scale, None, table[None], tied=True)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(legacy_t))
    # and the interpret-mode kernel path picks the same tokens
    got_tk = ops.decode_tail_op(x, scale, None, table[None], tied=True,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(got_tk), np.asarray(legacy_t))


def test_decode_tail_after_boundary_all_bit_widths():
    """The full fused tick pipeline (boundary kernel -> tail kernel) vs the
    full reference chain, with heterogeneous modes covering bits
    {8, 4, 1, 0} and raw passthrough in ONE pool: tokens must agree
    position-for-position."""
    stacked = _stacked_bank(HET_BANK)
    rng = np.random.default_rng(12)
    B = 16
    x = jnp.asarray(rng.normal(size=(B, 1, 128)), jnp.bfloat16)
    modes = jnp.asarray(np.r_[rng.integers(0, 5, B - 5), [0, 1, 2, 3, 4]],
                        jnp.int32)
    _, scale, _, heads = _tail_inputs(B, seed=13)
    y_k = ops.boundary_mixed_op(stacked, x, modes, interpret=True)
    t_k = ops.decode_tail_op(y_k, scale, None, heads, interpret=True)
    y_r = ref.boundary_mixed_ref(stacked, x, modes)
    t_r = ref.decode_tail_ref(y_r, scale, None, heads)
    # boundary outputs differ by blocked-vs-gather GEMM rounding (allclose,
    # not bitwise), so compare tokens through the SAME boundary output too
    t_same = ops.decode_tail_op(y_k, scale, None, heads)
    np.testing.assert_array_equal(np.asarray(t_k), np.asarray(t_same))
    assert (np.asarray(t_k) == np.asarray(t_r)).mean() > 0.9


def test_decode_tail_unaligned_vocab_falls_back():
    """A non-128-aligned vocab (or model width) cannot tile the kernel; the
    dispatcher must route to the jnp reference and agree exactly."""
    x, scale, _, _ = _tail_inputs(6)
    heads = jax.random.normal(jax.random.PRNGKey(14), (1, 128, 1000),
                              jnp.bfloat16)
    got = ops.decode_tail_op(x, scale, None, heads, interpret=True)
    want = ref.decode_tail_ref(x, scale, None, heads)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.max(got)) < 1000


def test_decode_tail_argmax_tie_break_matches_jnp():
    """Duplicate maxima across vocab chunks: the kernel's two-stage lane
    argmax must keep the FIRST occurrence, like jnp.argmax."""
    d, V = 128, 512
    x = jnp.ones((4, 1, d), jnp.bfloat16)
    scale = jnp.ones((d,), jnp.bfloat16)
    # identical columns -> every logit equal -> argmax must be 0
    heads = jnp.ones((1, d, V), jnp.bfloat16)
    got = ops.decode_tail_op(x, scale, None, heads, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0)
    # duplicate the true max into a later chunk: first index must win
    w = jax.random.normal(jax.random.PRNGKey(15), (1, d, V), jnp.bfloat16)
    w = w.at[:, :, 300].set(w[:, :, 37])
    w = w.at[:, :, 37].set(w[:, :, 37] * 0 + 3.0)   # big, equal col at 37
    w = w.at[:, :, 300].set(3.0)                    # same big col later
    got = np.asarray(ops.decode_tail_op(x, scale, None, w, interpret=True))
    ref_tok = np.asarray(ref.decode_tail_ref(x, scale, None, w))
    np.testing.assert_array_equal(got, ref_tok)
    np.testing.assert_array_equal(got, 37)


# ---------------------------------------------------------------------------
# rglru scan op dispatch (h0 absorption + CPU/unaligned fallback)
# ---------------------------------------------------------------------------

def test_rglru_scan_op_h0_paths_agree():
    """The op must honor a non-zero initial carry on every path: the CPU
    reference scans from h0 directly; the kernel path absorbs it into the
    first step (b1 += a1*h0, bit-identical in f32)."""
    B, S, D = 2, 16, 128
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D)))
    b = jax.random.normal(jax.random.PRNGKey(16), (B, S, D))
    h0 = jax.random.normal(jax.random.PRNGKey(17), (B, D))
    want = ref.rglru_scan_ref(a, b, h0)
    got_cpu = ops.rglru_scan_op(a, b, h0=h0)
    np.testing.assert_array_equal(np.asarray(got_cpu), np.asarray(want))
    got_k = ops.rglru_scan_op(a, b, h0=h0, interpret=True)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_op_unaligned_falls_back():
    """Non-block-multiple S/D must take the reference even when the kernel
    is requested."""
    B, S, D = 3, 13, 96
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D)))
    b = jax.random.normal(jax.random.PRNGKey(18), (B, S, D))
    for h0 in (None, jax.random.normal(jax.random.PRNGKey(19), (B, D))):
        got = ops.rglru_scan_op(a, b, h0=h0, interpret=True)
        want = ref.rglru_scan_ref(a, b, h0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_fallback_on_odd_shapes():
    """Non-tileable shapes must route to the reference implementation."""
    x = jax.random.normal(KEY, (13, 100))
    w = jax.random.normal(jax.random.PRNGKey(4), (100, 60))
    codes, scales = ops.bottleneck_quant_op(x, w)
    c_ref, s_ref = ref.bottleneck_quant_ref(x, w)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c_ref))


def test_ops_batched_leading_dims():
    x = jax.random.normal(KEY, (2, 64, 512))
    w = 0.02 * jax.random.normal(jax.random.PRNGKey(5), (512, 128))
    codes, scales = ops.bottleneck_quant_op(x, w)
    assert codes.shape == (2, 64, 128)
    assert scales.shape == (2, 64, 1)
    c_ref, s_ref = ref.bottleneck_quant_ref(x.reshape(128, 512), w)
    np.testing.assert_array_equal(
        np.asarray(codes).reshape(128, 128), np.asarray(c_ref))
