"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles
across shape/dtype sweeps (per-kernel allclose, per the deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bottleneck_quant import bottleneck_quant
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.rglru_scan import rglru_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("M,K,N", [
    (128, 512, 128), (256, 1024, 256), (384, 512, 128), (128, 2048, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_quant_sweep(M, K, N, dtype):
    x = (0.5 * jax.random.normal(KEY, (M, K))).astype(dtype)
    w = (0.02 * jax.random.normal(jax.random.PRNGKey(1), (K, N))).astype(dtype)
    codes, scales = bottleneck_quant(x, w, block_m=128, block_k=512,
                                     interpret=True)
    c_ref, s_ref = ref.bottleneck_quant_ref(x, w)
    # int8 codes may differ by 1 ulp where round() ties differ across orders
    diff = np.abs(codes.astype(np.int32) - np.asarray(c_ref, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(scales), np.asarray(s_ref),
                               rtol=1e-3)


@pytest.mark.parametrize("M,N,D", [
    (128, 128, 512), (256, 256, 1024), (128, 512, 512),
])
def test_dequant_matmul_sweep(M, N, D):
    x = jax.random.normal(KEY, (M, N))
    codes, scales = ref.bottleneck_quant_ref(x, jnp.eye(N))
    w = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (N, D))
    y = dequant_matmul(codes, scales, w, block_m=128, block_d=min(D, 512),
                       interpret=True)
    y_ref = ref.dequant_matmul_ref(codes, scales, w)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("B,S,D,bs,bd", [
    (1, 512, 128, 256, 128), (2, 1024, 256, 256, 128), (2, 512, 512, 128, 256),
])
def test_rglru_scan_sweep(B, S, D, bs, bd):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D)))
    b = jax.random.normal(jax.random.PRNGKey(3), (B, S, D))
    h = rglru_scan(a, b, block_s=bs, block_d=bd, interpret=True)
    h_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_carry_across_time_blocks():
    """The VMEM carry must persist across sequential grid steps: compare a
    2-block run to the oracle on a signal where state matters."""
    B, S, D = 1, 512, 128
    a = jnp.full((B, S, D), 0.999)          # long memory
    b = jnp.zeros((B, S, D)).at[:, 0, :].set(1.0)
    h = rglru_scan(a, b, block_s=256, block_d=128, interpret=True)
    h_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5)
    # state visibly decays across the block boundary
    assert float(h[0, 257, 0]) == pytest.approx(0.999 ** 257, rel=1e-3)


def test_ops_fallback_on_odd_shapes():
    """Non-tileable shapes must route to the reference implementation."""
    x = jax.random.normal(KEY, (13, 100))
    w = jax.random.normal(jax.random.PRNGKey(4), (100, 60))
    codes, scales = ops.bottleneck_quant_op(x, w)
    c_ref, s_ref = ref.bottleneck_quant_ref(x, w)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c_ref))


def test_ops_batched_leading_dims():
    x = jax.random.normal(KEY, (2, 64, 512))
    w = 0.02 * jax.random.normal(jax.random.PRNGKey(5), (512, 128))
    codes, scales = ops.bottleneck_quant_op(x, w)
    assert codes.shape == (2, 64, 128)
    assert scales.shape == (2, 64, 1)
    c_ref, s_ref = ref.bottleneck_quant_ref(x.reshape(128, 512), w)
    np.testing.assert_array_equal(
        np.asarray(codes).reshape(128, 128), np.asarray(c_ref))
