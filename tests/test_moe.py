"""MoE dispatch/combine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_apply, moe_init


def _dense_moe_reference(p, x, k, act="silu"):
    """Route each token by top-k with renormalized gates, computing every
    expert densely (no capacity drops)."""
    B, S, d = x.shape
    E = p["w_gate"].shape[0]
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    xf = x.astype(jnp.float32)
    for e in range(E):
        h = jax.nn.silu(xf @ p["w_gate"][e].astype(jnp.float32)) * \
            (xf @ p["w_up"][e].astype(jnp.float32))
        outs.append(h @ p["w_down"][e].astype(jnp.float32))
    dense = jnp.stack(outs, axis=2)                  # [B,S,E,d]
    sel = jnp.take_along_axis(dense, idx[..., None], axis=2)
    return jnp.sum(sel * gates[..., None], axis=2)


def test_moe_matches_dense_reference_with_ample_capacity():
    key = jax.random.PRNGKey(0)
    B, S, d, d_ff, E, k = 2, 16, 32, 64, 4, 2
    p = moe_init(key, d, d_ff, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
    y, aux = moe_apply(p, x, k=k, capacity_factor=8.0)   # no drops
    ref = _dense_moe_reference(p, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(0)
    B, S, d, d_ff, E, k = 1, 32, 16, 32, 4, 2
    p = moe_init(key, d, d_ff, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d), jnp.float32)
    y_ample, _ = moe_apply(p, x, k=k, capacity_factor=8.0)
    y_tight, _ = moe_apply(p, x, k=k, capacity_factor=0.25)
    # tight capacity must actually change (drop) some outputs
    assert float(jnp.max(jnp.abs(y_ample - y_tight))) > 1e-6
    # dropped tokens produce zeros, not NaNs
    assert bool(jnp.all(jnp.isfinite(y_tight)))


def test_moe_grouping_invariance():
    """Splitting rows into smaller routing groups changes capacity locality
    but with ample capacity the output is identical."""
    key = jax.random.PRNGKey(0)
    B, S, d, d_ff, E, k = 2, 32, 16, 32, 4, 2
    p = moe_init(key, d, d_ff, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, d), jnp.float32)
    y1, _ = moe_apply(p, x, k=k, capacity_factor=8.0)
    y2, _ = moe_apply(p, x, k=k, capacity_factor=8.0, group_size=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)


def test_moe_aux_loss_balanced_is_lower():
    """Uniform routing yields aux ~1; collapsed routing yields aux -> E."""
    key = jax.random.PRNGKey(0)
    B, S, d, d_ff, E = 1, 64, 16, 16, 4
    p = moe_init(key, d, d_ff, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, d))
    # positive inputs so a one-column router reliably saturates expert 0
    x_pos = 3.0 + x
    p_collapsed = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])
                                  .at[:, 0].set(10.0)})
    _, aux_rand = moe_apply(p, x, k=1)          # zero-mean: balanced routing
    _, aux_coll = moe_apply(p_collapsed, x_pos, k=1)
    assert float(aux_coll) > 2.0 * float(aux_rand)
    assert float(aux_coll) > 0.9 * E          # fully collapsed -> ~E
