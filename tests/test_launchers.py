"""End-to-end smoke of the production launchers (train / serve) on the
single host device with reduced configs — the same entry points a real
deployment calls with the full configs."""
import json
import os

import pytest

from repro.launch import serve as serve_launch
from repro.launch import train as train_launch


def test_train_launcher_monolithic(tmp_path):
    hist = train_launch.main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "4",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path)])
    assert "phase1" in hist
    losses = [h["loss"] for h in hist["phase1"]]
    assert all(l == l for l in losses)            # no NaNs
    assert os.path.exists(tmp_path / "xlstm-125m.npz")


def test_train_launcher_cascade_dpi(tmp_path):
    hist = train_launch.main([
        "--arch", "qwen2.5-3b", "--reduced", "--steps", "6",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--cascade"])
    ens = hist["cascade"]
    assert len(ens["losses"]) >= 2
    # Algorithm 1's Ensure line: later modes at most as good
    assert ens["losses"][0] <= ens["losses"][1] + 0.5   # smoke-scale slack


def test_serve_launcher_policies(tmp_path):
    dyn = serve_launch.main([
        "--arch", "qwen2.5-3b", "--reduced", "--requests", "2",
        "--prompt-len", "4", "--gen", "6", "--cache-len", "32",
        "--json-out", str(tmp_path / "dyn.json")])
    assert dyn["tokens"] == 12
    assert dyn["wire_bytes_per_token"] >= 0
    st1 = serve_launch.main([
        "--arch", "qwen2.5-3b", "--reduced", "--requests", "2",
        "--prompt-len", "4", "--gen", "6", "--cache-len", "32",
        "--policy", "static1"])
    st0 = serve_launch.main([
        "--arch", "qwen2.5-3b", "--reduced", "--requests", "2",
        "--prompt-len", "4", "--gen", "6", "--cache-len", "32",
        "--policy", "static0"])
    # the bottleneck mode must be strictly cheaper on the wire than raw
    assert st1["wire_bytes"] < st0["wire_bytes"]
    assert json.load(open(tmp_path / "dyn.json"))["policy"] == "orchestrator"
