"""Paged decode-state pool: block-table slot memory end to end.

The tentpole pins, in dependency order:

1. the Pallas gather-attention kernel is **bit-for-bit** the blocked jnp
   oracle in interpret mode (same page walk, same f32 online softmax with
   ``q.dtype`` rounding barriers);
2. ``PagedPool`` accounting never leaks or double-frees pages — a seeded
   fuzz (and a hypothesis property when available) drives random
   admit→alloc→release lifecycles against the free-list invariants;
3. the paged engine decodes **token-identical** streams to the dense
   ``SlotPool`` engine on every dense-fit workload — host loop and
   device windowed loop — while admitting prompts longer than the dense
   per-slot cache (page-budget admission + parking backpressure);
4. paged migration snapshots (allocated pages only, in block-table order)
   resume bit-identically on the target replica, and injection applies
   the same worst-case page budgeting as admission.

Paging applies to homogeneous full-attention archs only (qwen here);
recurrent/mixed archs must keep the dense pool and refuse ``paged=True``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import split as SP
from repro.core.channel import MobilityChannel
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.serving import (ContinuousBatchingEngine, PagedPool, Request,
                           SlotPool, default_orchestrator, extract_session,
                           inject_session)

DENSE_ARCHS = ["recurrentgemma-2b", "xlstm-125m"]


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen2.5-3b")
    return cfg, SP.init_split_params(jax.random.PRNGKey(0), cfg)


def _prompt(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


def _mobility(cross_at, *, n_ticks=64):
    cells = [0] * cross_at + [1] * n_ticks
    return MobilityChannel(cells, [2e6, 2e6], detach_factor=1.0)


# ---------------------------------------------------------------------------
# kernel: interpret-mode bit parity vs the blocked oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,nb,plen,n_kv,g,hd", [
    (1, 2, 8, 1, 2, 16),
    (3, 4, 8, 2, 3, 32),
    (2, 3, 16, 2, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_parity(B, nb, plen, n_kv, g, hd, dtype):
    """pallas interpret vs the blocked jnp oracle, incl. junk in the
    scratch page (id 0) and in rows past each sequence's position:
    bit-for-bit in bf16 (the ``q.dtype`` rounding barriers quantize away
    fusion noise); a few ulp in f32, where the barriers are no-op casts
    and XLA may rematerialize the interpreted body with different FMA
    fusion than the oracle's eager op-by-op execution."""
    nq = n_kv * g
    n_pages = B * nb + 1
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (B, nq, hd)).astype(dtype)
    kp = jax.random.normal(keys[1], (n_pages, plen, n_kv, hd)).astype(dtype)
    vp = jax.random.normal(keys[2], (n_pages, plen, n_kv, hd)).astype(dtype)
    rng = np.random.default_rng(11)
    pos = rng.integers(0, nb * plen, size=B).astype(np.int32)
    bt = np.zeros((B, nb), np.int32)
    free = list(rng.permutation(np.arange(1, n_pages)))
    for b in range(B):
        for j in range(pos[b] // plen + 1):      # allocated prefix only
            bt[b, j] = free.pop()
    out_k = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos),
                            interpret=True)
    out_r = ref.paged_attention_ref(q, kp, vp, jnp.asarray(bt), pos)
    assert out_k.dtype == dtype
    if dtype == jnp.bfloat16:
        assert (np.asarray(out_k) == np.asarray(out_r)).all()
    else:
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# pool accounting: free-list guards + leak/double-free invariants
# ---------------------------------------------------------------------------

def test_slotpool_release_guards(qwen):
    cfg, _ = qwen
    pool = SlotPool(cfg, 2, 16)
    s = pool.acquire()
    pool.release(s)
    with pytest.raises(ValueError, match=f"double release of slot {s}"):
        pool.release(s)
    with pytest.raises(ValueError, match="out of range"):
        pool.release(7)


def test_pagedpool_release_guards_and_geometry(qwen):
    cfg, _ = qwen
    pool = PagedPool(cfg, 2, 16, page_len=8)
    assert pool.n_pages == 4 and pool.capacity == 32
    s = pool.acquire()
    pool.alloc_pages(s, 9)                     # 2 pages
    assert pool.pages_in_use == 2
    pool.release(s)
    assert pool.pages_in_use == 0
    with pytest.raises(ValueError, match="double release"):
        pool.release(s)
    with pytest.raises(ValueError, match="out of range"):
        pool.release(-1)


def test_pagedpool_exhaustion_raises(qwen):
    cfg, _ = qwen
    pool = PagedPool(cfg, 1, 16, page_len=8)   # 2 pages total
    s = pool.acquire()
    with pytest.raises(RuntimeError):
        pool.alloc_pages(s, pool.capacity + 1)


def _check_invariants(pool):
    used = int(pool.pages_used.sum())
    assert pool.pages_in_use == used
    assert used + len(pool._free_pages) == pool.n_pages
    assert len(set(pool._free_pages)) == len(pool._free_pages)
    seen = set()
    for slot in range(pool.n_slots):
        ids = [int(p) for p in pool.block_np[slot, :pool.pages_used[slot]]]
        assert 0 not in ids                     # scratch page never owned
        assert all(1 <= p <= pool.n_pages for p in ids)
        assert not (seen & set(ids))            # disjoint across slots
        seen |= set(ids)
    assert not (seen & set(pool._free_pages))   # owned ∩ free == ∅
    assert pool.pages_available >= 0


def _fuzz_lifecycle(pool, seed, n_ops=200):
    """Random admit→commit→incremental-alloc→release sequences under the
    engine's admission discipline; every step re-checks the invariants."""
    rng = np.random.default_rng(seed)
    live = {}                                   # slot -> (worst, rows)
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        if op == 0 and pool.n_free:
            rows_total = int(rng.integers(1, pool.capacity + 1))
            worst = -(-rows_total // pool.page_len)
            if worst <= pool.pages_available:   # the admission rule
                slot = pool.acquire()
                pool.commit_pages(slot, worst)
                rows0 = int(rng.integers(1, rows_total + 1))
                pool.alloc_pages(slot, rows0)
                live[slot] = (rows_total, rows0)
        elif op == 1 and live:
            slot = int(rng.choice(list(live)))
            total, rows = live[slot]
            rows = min(rows + int(rng.integers(1, pool.page_len + 1)), total)
            pool.alloc_pages(slot, rows)        # idempotent past total
            live[slot] = (total, rows)
        elif op == 2 and live:
            slot = int(rng.choice(list(live)))
            pool.release(slot)
            del live[slot]
        _check_invariants(pool)
    for slot in list(live):
        pool.release(slot)
    _check_invariants(pool)
    assert pool.pages_in_use == 0
    assert sorted(pool._free_pages) == list(range(1, pool.n_pages + 1))


def test_pagedpool_never_leaks_seeded_fuzz(qwen):
    cfg, _ = qwen
    for seed in range(5):
        _fuzz_lifecycle(PagedPool(cfg, 3, 24, page_len=4), seed)


def test_pagedpool_never_leaks_property(qwen):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    cfg, _ = qwen

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def prop(seed):
        _fuzz_lifecycle(PagedPool(cfg, 3, 24, page_len=4), seed, n_ops=60)

    prop()


def test_write_read_rows_round_trip(qwen):
    """``write_rows(read_rows(s), s, pos)`` is a bit-exact identity on the
    paged pool (the migration/admission scatter is the gather's inverse)."""
    cfg, params = qwen
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   host_loop=True)
    assert eng.paged
    eng.submit(Request(rid=0, prompt=_prompt(cfg, seed=3), max_new_tokens=6))
    for _ in range(4):
        eng.step()
    pool, slot = eng.pool, 0
    before = jax.tree.map(np.asarray, pool.states)
    rows = pool.read_rows([slot])
    pool.write_rows(rows, [slot], [int(pool.positions[slot])])
    after = jax.tree.map(np.asarray, pool.states)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert (a == b).all()
    eng.close()


# ---------------------------------------------------------------------------
# engine: paged == dense token identity; long prompts; arch gating
# ---------------------------------------------------------------------------

def _run_engine(params, cfg, *, host_loop, paged, n=6):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, cache_len=32,
                                   orchestrator=default_orchestrator(cfg),
                                   host_loop=host_loop, paged=paged)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=_prompt(cfg, seed=i),
                    max_new_tokens=int(rng.integers(2, 8)),
                    arrival_tick=i // 2) for i in range(n)]
    done = eng.run(reqs)
    st = eng.stats()
    assert eng.pool.n_free == eng.pool.n_slots
    if paged:
        assert eng.pool.pages_in_use == 0
    eng.close()
    return {s.request.rid: s for s in done}, st


def test_paged_token_identity_both_loops(qwen):
    """Paged and dense engines emit identical tokens / modes / accounting
    for every dense-fit request, on the host loop and the device loop."""
    cfg, params = qwen
    base, base_st = _run_engine(params, cfg, host_loop=True, paged=False)
    for host_loop in (True, False):
        cur, st = _run_engine(params, cfg, host_loop=host_loop, paged=True)
        assert st["paged"] is True and base_st["paged"] is False
        assert cur.keys() == base.keys()
        for rid in base:
            for attr in ("tokens", "mode_counts", "wire_bytes",
                         "admitted_tick", "finished_tick"):
                assert getattr(cur[rid], attr) == getattr(base[rid], attr), \
                    (host_loop, rid, attr)
        for k in ("decode_ticks", "wire_bytes", "prefill_calls",
                  "generated_tokens", "deadline_misses"):
            assert st[k] == base_st[k], (host_loop, k)


@pytest.mark.parametrize("host_loop", [True, False])
def test_long_prompt_beyond_dense_cache(qwen, host_loop):
    """Page-budget admission serves a prompt LONGER than the dense per-slot
    cache (the dense engine rejects it), and parks excess long prompts
    until pages free up instead of rejecting them."""
    cfg, params = qwen
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, cache_len=32,
                                   host_loop=host_loop)
    assert eng.max_context == 96                 # 12 pages * 8 rows
    rng = np.random.default_rng(0)
    reqs = [Request(rid=0, prompt=rng.integers(
                1, cfg.vocab_size, 50).astype(np.int32), max_new_tokens=8)]
    reqs += [Request(rid=i, prompt=rng.integers(
                1, cfg.vocab_size, 40).astype(np.int32), max_new_tokens=6)
             for i in (1, 2)]
    done = eng.run(reqs)
    st = eng.stats()
    assert len(done) == 3
    assert all(len(s.tokens) == s.request.max_new_tokens for s in done)
    assert st["requests_over_capacity"] == 0
    assert st["requests_truncated"] == 0
    assert st["requests_parked"] >= 1            # 3 * 57 rows > 96 rows
    assert eng.pool.pages_in_use == 0
    eng.close()

    dense = ContinuousBatchingEngine(params, cfg, n_slots=3, cache_len=32,
                                     paged=False)
    assert len(dense.run([reqs[0]])) == 0
    assert dense.stats()["requests_over_capacity"] == 1
    dense.close()


@pytest.mark.parametrize("arch", DENSE_ARCHS)
def test_recurrent_archs_stay_dense(arch):
    """Paging is a full-attention concept: recurrent / mixed archs keep the
    dense pool by default and refuse ``paged=True`` loudly."""
    cfg = get_reduced(arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=16)
    assert not eng.paged and isinstance(eng.pool, SlotPool)
    assert eng.stats()["paged"] is False
    eng.close()
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=16,
                                 paged=True)


# ---------------------------------------------------------------------------
# migration: pages-only snapshots, bit-exact resume, budgeted injection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("host_loop", [True, False])
def test_paged_migration_bit_identity(qwen, host_loop):
    """A raw paged snapshot (allocated pages only) resumes bit-identically
    on the target — including extraction mid-window on the device loop."""
    cfg, params = qwen

    def _req():
        return Request(rid=0, prompt=_prompt(cfg, seed=2), max_new_tokens=12,
                       channel=_mobility(60))

    base_eng = ContinuousBatchingEngine(
        params, cfg, n_slots=2, cache_len=32,
        orchestrator=default_orchestrator(cfg), host_loop=host_loop)
    base = base_eng.run([_req()])[0].tokens
    base_eng.close()

    src = ContinuousBatchingEngine(
        params, cfg, n_slots=2, cache_len=32,
        orchestrator=default_orchestrator(cfg), host_loop=host_loop,
        max_window=2)
    dst = ContinuousBatchingEngine(
        params, cfg, n_slots=2, cache_len=32,
        orchestrator=default_orchestrator(cfg), host_loop=host_loop)
    src.submit(_req())
    for _ in range(3):
        src.step()
    snap = extract_session(src, rid=0)
    assert snap.paged and snap.page_len == src.pool.page_len
    assert src.pool.pages_in_use == 0            # extraction freed them
    nbu = snap.wire[0][1].shape[1]
    assert nbu * snap.page_len <= 32             # pages-only payload
    assert inject_session(dst, snap)
    mig = dst.run()[0].tokens
    assert dst.pool.pages_in_use == 0
    src.close(), dst.close()
    assert mig == base


def test_paged_inject_budget_refusal(qwen):
    """Injection is admission-equivalent: a free slot is NOT enough — the
    target must also cover the session's worst-case remaining pages, else
    inject returns False (park-and-retry) without touching the pool."""
    cfg, params = qwen
    src = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   orchestrator=default_orchestrator(cfg),
                                   host_loop=True)
    dst = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   orchestrator=default_orchestrator(cfg),
                                   n_pages=2)    # 16 rows < 4+20-1 worst
    src.submit(Request(rid=0, prompt=_prompt(cfg, seed=2),
                       max_new_tokens=20, channel=_mobility(60)))
    for _ in range(3):
        src.step()
    snap = extract_session(src, rid=0)
    assert not inject_session(dst, snap)
    assert dst.pool.pages_in_use == 0 and dst.pool.n_free == 2
    src.close(), dst.close()


def test_pool_kind_mismatch_raises(qwen):
    """Paged↔dense migration is a config error, not backpressure."""
    cfg, params = qwen
    src = ContinuousBatchingEngine(params, cfg, n_slots=2, cache_len=32,
                                   orchestrator=default_orchestrator(cfg),
                                   host_loop=True)
    dense_dst = ContinuousBatchingEngine(params, cfg, n_slots=2,
                                         cache_len=32, paged=False)
    src.submit(Request(rid=0, prompt=_prompt(cfg, seed=2), max_new_tokens=8,
                       channel=_mobility(60)))
    for _ in range(3):
        src.step()
    snap = extract_session(src, rid=0)
    with pytest.raises(ValueError, match="pool"):
        inject_session(dense_dst, snap)
    src.close(), dense_dst.close()
