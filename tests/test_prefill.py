"""Batched full-sequence prefill vs the token-at-a-time decode loop.

The prefill subsystem (``T.prefill`` / ``SP.split_prefill`` /
``SP.split_prefill_mixed``) must reproduce, in ONE forward pass, exactly the
decode state and last-position logits that feeding the prompt through
``decode_step`` token by token produces — for attention KV caches (incl.
rolling local-attention windows) and recurrent carries alike, and for
right-padded prompt buckets with per-row true lengths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import SplitConfig
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.models import transformer as T

ATOL = 3e-4

# attention (GQA + qkv bias), Griffin (rglru + rolling local-attn window),
# and xLSTM (mlstm + slstm) cover every decode-state family
ARCHS = ["qwen2.5-3b", "recurrentgemma-2b", "xlstm-125m"]


def _loop_prefill(params, cfg, prompt_row, cache_len):
    """Reference batch-1 admission: one decode step per prompt token."""
    states = T.init_decode_state(cfg, 1, cache_len)
    logits = None
    for t in range(prompt_row.shape[-1]):
        logits, states = T.decode_step(params, jnp.asarray(
            prompt_row[None, ..., t:t + 1]), states, jnp.int32(t), cfg)
    return np.asarray(logits), states


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_tokenwise_loop(arch):
    """Padded batched prefill == per-row decode-step loop: last logits AND
    the decode state (verified through a follow-up decode step)."""
    cfg = get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S, cache_len = 2, 8, 32
    lens = np.array([6, 3], np.int32)         # right-padded, ragged lengths
    toks = np.zeros((B, S), np.int32)
    for b in range(B):
        toks[b, :lens[b]] = rng.integers(1, cfg.vocab_size, lens[b])

    base = [_loop_prefill(params, cfg, toks[b, :lens[b]], cache_len)
            for b in range(B)]
    pf_logits, pf_states = T.prefill(
        params, jnp.asarray(toks), cfg,
        T.init_decode_state(cfg, B, cache_len), lengths=jnp.asarray(lens))
    for b in range(B):
        np.testing.assert_allclose(np.asarray(pf_logits)[b], base[b][0][0],
                                   atol=ATOL, rtol=ATOL)

    # the states must agree too: one more decode step from each
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, 1)).astype(np.int32))
    lg_pf, _ = T.decode_step(params, nxt, pf_states, jnp.asarray(lens), cfg)
    for b in range(B):
        lg_b, _ = T.decode_step(params, nxt[b:b + 1], base[b][1],
                                jnp.int32(int(lens[b])), cfg)
        np.testing.assert_allclose(np.asarray(lg_pf)[b], np.asarray(lg_b)[0],
                                   atol=ATOL, rtol=ATOL)


def _het_cfg():
    """qwen reduced with a heterogeneous mode bank: widths 32/16/24/8 and
    bit widths 8/4/1/0 — exercises the padded-bank gather, the ternary
    bits=1 wire (NaN before the qmax floor fix) and the unquantized
    bits=0 wire."""
    cfg = get_reduced("qwen2.5-3b")
    return dataclasses.replace(cfg, split=SplitConfig(
        split_at=1, d_bottleneck=32, quant_bits=8,
        extra_modes=((16, 4), (24, 1), (8, 0))))


@pytest.fixture(scope="module")
def het_setup():
    cfg = _het_cfg()
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_split_prefill_every_mode_matches_loop(het_setup):
    """split_prefill(mode=m) == looping split_decode_step(mode=m) over the
    prompt, for every calibrated mode."""
    cfg, params = het_setup
    rng = np.random.default_rng(1)
    B, S, cache_len = 2, 8, 32
    lens = np.array([7, 4], np.int32)
    toks = np.zeros((B, S), np.int32)
    for b in range(B):
        toks[b, :lens[b]] = rng.integers(1, cfg.vocab_size, lens[b])

    for m in range(cfg.split.n_modes):
        base = []
        for b in range(B):
            st = T.init_decode_state(cfg, 1, cache_len)
            lg = None
            for t in range(int(lens[b])):
                lg, st, _ = SP.split_decode_step(
                    params, jnp.asarray(toks[b:b + 1, t:t + 1]), st,
                    jnp.int32(t), cfg, mode=m)
            base.append(np.asarray(lg))
        lg_p, _, _ = SP.split_prefill(
            params, jnp.asarray(toks), cfg,
            T.init_decode_state(cfg, B, cache_len), mode=m,
            lengths=jnp.asarray(lens))
        for b in range(B):
            np.testing.assert_allclose(np.asarray(lg_p)[b], base[b][0],
                                       atol=ATOL, rtol=ATOL)


def test_split_prefill_mixed_uniform_matches_per_mode(het_setup):
    """split_prefill_mixed with uniform mode_idx=m == split_prefill(mode=m)
    for every calibrated mode (the admission analogue of the decode-step
    parity pin)."""
    cfg, params = het_setup
    stacked = BN.bank_stack(params["bneck_modes"], cfg.split)
    rng = np.random.default_rng(2)
    B, S, cache_len = 2, 8, 32
    lens = jnp.asarray([5, 8], jnp.int32)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                    size=(B, S)).astype(np.int32))
    for m in range(cfg.split.n_modes):
        ref, _, _ = SP.split_prefill(
            params, toks, cfg, T.init_decode_state(cfg, B, cache_len),
            mode=m, lengths=lens)
        mix, _ = SP.split_prefill_mixed(
            params, stacked, toks, T.init_decode_state(cfg, B, cache_len),
            cfg, jnp.full((B,), m, jnp.int32), lengths=lens)
        np.testing.assert_allclose(np.asarray(mix), np.asarray(ref),
                                   atol=ATOL, rtol=ATOL)


def test_mixed_decode_step_every_calibrated_mode(het_setup):
    """split_decode_step(mode=m) == split_decode_step_mixed with uniform
    mode_idx=m for EVERY calibrated mode of the heterogeneous bank — pins
    the exact-equivalence claim of the padded-bank gather
    (bottleneck.bank_stack / boundary_mixed) across widths and bit
    widths 8/4/1/0."""
    cfg, params = het_setup
    stacked = BN.bank_stack(params["bneck_modes"], cfg.split)
    B = 3
    states = T.init_decode_state(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), 5, jnp.int32)
    for m in range(cfg.split.n_modes):
        ref, _, _ = SP.split_decode_step(params, tok, states, jnp.int32(5),
                                         cfg, mode=m)
        mix, _ = SP.split_decode_step_mixed(params, stacked, tok, states,
                                            pos, cfg,
                                            jnp.full((B,), m, jnp.int32))
        assert np.isfinite(np.asarray(mix)).all()
        np.testing.assert_allclose(np.asarray(ref), np.asarray(mix),
                                   atol=1e-5, rtol=1e-5)
