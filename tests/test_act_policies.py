"""Activation-sharding policies and tp_scope param-rule variants (the §Perf
hillclimb knobs) — spec-level invariants that need no devices."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.core import split as SP
from repro.models import sharding


@pytest.fixture(scope="module")
def mesh():
    try:
        return jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    except TypeError:   # older signature: (shape, axis_names)
        return jax.sharding.AbstractMesh((2, 2), ("data", "model"))


def test_batch_pspec_policies(mesh):
    assert sharding.batch_pspec(mesh, 2, 8) == P(("data",), None)
    assert sharding.batch_pspec(mesh, 2, 8, "batch2d") == \
        P(("data", "model"), None)
    # batch 2 divides data(2) but not chips(4): batch2d degrades gracefully
    assert sharding.batch_pspec(mesh, 2, 2, "batch2d") == P(("data",), None)
    # batch 1 (long_500k): fully replicated
    assert sharding.batch_pspec(mesh, 2, 1) == P(None, None)


def test_activation_rules_policies(mesh):
    seq = sharding.default_activation_rules(mesh, act_policy="seq")
    assert seq["resid"] == P(("data",), "model", None)
    batch = sharding.default_activation_rules(mesh, act_policy="batch")
    assert batch["resid"] == P(("data",), None, None)
    b2 = sharding.default_activation_rules(mesh, act_policy="batch2d")
    assert b2["resid"] == P(("data", "model"), None, None)
    with pytest.raises(ValueError):
        sharding.default_activation_rules(mesh, act_policy="nope")
    ep = sharding.default_activation_rules(mesh, act_policy="batch2d",
                                           moe_ep=True)
    assert ep["moe_ep"] is True


def _leaf_specs(specs):
    return {sharding._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(specs)[0]}


def test_tp_scope_ffn_strips_model_from_attention(mesh):
    cfg = get_reduced("stablelm-3b")
    shapes = jax.eval_shape(
        lambda k: SP.init_split_params(k, cfg), jax.random.PRNGKey(0))
    full = _leaf_specs(sharding.param_pspecs(
        shapes, mesh, stacked_layers=cfg.homogeneous))
    ffn = _leaf_specs(sharding.param_pspecs(
        shapes, mesh, stacked_layers=cfg.homogeneous, tp_scope="ffn"))
    saw_attn = saw_mlp = False
    for name, spec in ffn.items():
        if "mix/" in name:
            assert "model" not in jax.tree.leaves(tuple(spec)), name
            saw_attn = True
        if "mlp/" in name:
            assert spec == full[name]
            saw_mlp = True
    assert saw_attn and saw_mlp


def test_ctx_flag_roundtrip(mesh):
    assert sharding.ctx_mesh() is None
    assert not sharding.ctx_flag("moe_ep")
    with sharding.activation_rules(mesh, {"moe_ep": True}):
        assert sharding.ctx_mesh() is mesh
        assert sharding.ctx_flag("moe_ep")
    assert sharding.ctx_mesh() is None
