"""Smoke/regression coverage for the synthetic Lumos5G twin.

Shapes, units, determinism, the per-call config default (a shared mutable
dataclass default in ``generate``'s signature was a latent bug), and the
channel-tick resampler that feeds ``FleetChannel`` trace mode.
"""
import numpy as np
import pytest

from repro.data.lumos5g import (Lumos5GConfig, N_FEATURES, batch_iterator,
                                capacity_traces_bps, generate,
                                throughput_series_mbps, train_test_split)

SMALL = Lumos5GConfig(n_samples=256, seq_len=20, n_classes=3, seed=0)


def test_generate_shapes_dtypes_and_units():
    data = generate(SMALL)
    n, t = SMALL.n_samples, SMALL.seq_len
    assert data["x"].shape == (n, t, N_FEATURES)
    assert data["y"].shape == (n, t)
    assert data["tput"].shape == (n, t)
    assert data["x"].dtype == np.float32
    assert data["y"].dtype == np.int32
    assert data["tput"].dtype == np.float32
    # throughput is Mbps, clipped to the dataset's published range
    assert float(data["tput"].min()) >= 1.0
    assert float(data["tput"].max()) <= 2200.0
    # labels are valid class ids and every class appears
    assert set(np.unique(data["y"])) == set(range(SMALL.n_classes))
    # features are normalized
    flat = data["x"].reshape(-1, N_FEATURES).astype(np.float64)
    assert np.abs(flat.mean(0)).max() < 0.5
    assert np.abs(flat.std(0) - 1.0).max() < 0.5


def test_generate_windows_are_consecutive_slices():
    data = generate(SMALL)
    # window i+1 is window i shifted by one sample
    assert np.array_equal(data["tput"][1:, :-1], data["tput"][:-1, 1:])
    assert np.array_equal(data["y"][1:, :-1], data["y"][:-1, 1:])


def test_generate_deterministic_and_default_cfg_not_shared():
    a = generate(SMALL)
    b = generate(SMALL)
    for k in a:
        assert np.array_equal(a[k], b[k])
    # default-config calls construct a fresh config each time: equal
    # results, and a caller mutating its own config can't poison others
    small = Lumos5GConfig(n_samples=64)
    c = generate(small)
    small.n_samples = 3          # mutate caller copy after the fact
    d = generate(Lumos5GConfig(n_samples=64))
    for k in c:
        assert np.array_equal(c[k], d[k])


def test_train_test_split_partitions():
    data = generate(SMALL)
    tr, te = train_test_split(data, SMALL)
    n = SMALL.n_samples
    assert te["x"].shape[0] == int(n * SMALL.test_frac)
    assert tr["x"].shape[0] + te["x"].shape[0] == n
    for k in data:
        assert tr[k].shape[1:] == data[k].shape[1:]
        assert te[k].shape[1:] == data[k].shape[1:]


def test_batch_iterator_shapes():
    data = generate(SMALL)
    it = batch_iterator(data, batch_size=8, seed=1)
    batch = next(it)
    assert batch["x"].shape == (8, SMALL.seq_len, N_FEATURES)
    assert batch["y"].shape == (8, SMALL.seq_len)


def test_throughput_series_units_and_length():
    s = throughput_series_mbps(300, seed=2)
    assert s.shape == (300,)
    assert s.min() >= 1.0 and s.max() <= 2200.0
    assert s.std() > 0.0                       # it actually varies
    with pytest.raises(ValueError):
        throughput_series_mbps(0)


def test_capacity_traces_resample_to_channel_ticks():
    n_ues, n_ticks, tick_s = 16, 120, 0.1
    traces = capacity_traces_bps(n_ues, n_ticks, tick_seconds=tick_s, seed=3)
    assert traces.shape == (n_ues, n_ticks)
    # Mbps -> bytes/s: the clip range [1, 2200] Mbps maps to
    # [1.25e5, 2.75e8] bytes/s; interpolation cannot exceed sample bounds
    assert traces.min() >= 1.0 * 1e6 / 8.0
    assert traces.max() <= 2200.0 * 1e6 / 8.0
    # deterministic, and UEs get distinct windows of the walk
    again = capacity_traces_bps(n_ues, n_ticks, tick_seconds=tick_s, seed=3)
    assert np.array_equal(traces, again)
    assert not np.array_equal(traces[0], traces[1])
    # sub-second ticks interpolate smoothly: adjacent ticks (0.1 s apart)
    # move far less than the full dynamic range
    step = np.abs(np.diff(traces, axis=1)).max()
    assert step < (traces.max() - traces.min())


def test_capacity_traces_validation():
    with pytest.raises(ValueError):
        capacity_traces_bps(0, 10)
    with pytest.raises(ValueError):
        capacity_traces_bps(2, 0)
    with pytest.raises(ValueError):
        capacity_traces_bps(2, 10, tick_seconds=0.0)


def test_capacity_traces_feed_fleet_channel():
    from repro.core.channel import FleetChannel
    traces = capacity_traces_bps(8, 50, seed=4)
    fleet = FleetChannel(8, traces_bps=traces, cycle=True)
    got = np.stack([fleet.step_all() for _ in range(50)]).T
    assert np.array_equal(got, traces)
