"""Quantized tensor-parallel prefill (manual Megatron-SP schedule) vs the
monolithic forward. Subprocess with 8 forced host devices."""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs import get_reduced
from repro.core import split as S, qtp as QTP
from repro.launch.mesh import mesh_context
from repro.models import transformer as T

mesh = jax.make_mesh((2, 4), ('data', 'model'))

for arch in ('stablelm-3b', 'granite-8b'):
    cfg = get_reduced(arch)
    if not QTP.qtp_supported(cfg, mesh, 32):
        continue
    params = S.init_split_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                             cfg.vocab_size)
    ref, _ = T.forward(params, tok, cfg)
    with mesh_context(mesh):
        lg0 = jax.jit(lambda p, t: QTP.qtp_forward(
            p, t, cfg, mesh=mesh, bits=0))(params, tok)
        lg8 = jax.jit(lambda p, t: QTP.qtp_forward(
            p, t, cfg, mesh=mesh, bits=8))(params, tok)
    err0 = float(jnp.max(jnp.abs(lg0 - ref)))
    assert err0 < 0.1, f'{arch} bits=0 err {err0}'   # bf16 resid tolerance
    rel8 = float(jnp.linalg.norm((lg8 - ref).astype(jnp.float32))
                 / jnp.linalg.norm(ref.astype(jnp.float32)))
    assert rel8 < 0.05, f'{arch} bits=8 rel err {rel8}'
    # int8 must actually perturb (guards against bits being ignored)
    assert float(jnp.max(jnp.abs(lg8 - lg0))) > 1e-6
    print(arch, 'err0', err0, 'rel8', rel8)

# guard: unsupported shapes refuse the fast path
cfg = get_reduced('qwen2.5-3b')    # n_kv=2 on 4-wide model axis
assert not QTP.qtp_supported(cfg, mesh, 32)
cfg = get_reduced('mixtral-8x7b')  # MoE
assert not QTP.qtp_supported(cfg, mesh, 32)
print('QTP OK')
"""


def test_qtp_matches_monolithic_forward():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "QTP OK" in r.stdout
