"""Continuous-batching split-serving throughput vs offered load.

Sweeps the request arrival rate into ``ContinuousBatchingEngine`` and
reports, per offered-load level: decode tokens/s (engine wall clock),
uplink wire-bytes/token, slot occupancy, and how often the decode batch was
genuinely *mixed-mode* (>= 2 distinct bottleneck modes in the same jitted
step) — the per-request-selection property that static-batch serving can't
express.

    PYTHONPATH=src python benchmarks/bench_serving.py [--arch qwen2.5-3b]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import ChannelConfig, channel_fleet
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)
from repro.serving import ContinuousBatchingEngine, Request


def make_requests(cfg, n: int, *, prompt_len: int, gen: int,
                  arrival_every: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    chans = channel_fleet(
        n, ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                         recovery_prob=0.15),
        seed=11 + seed, mean_spread=0.95)
    shape = ((cfg.n_codebooks, prompt_len)
             if cfg.frontend == "audio" and cfg.n_codebooks > 1
             else (prompt_len,))
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=shape).astype(np.int32),
                    max_new_tokens=gen, channel=chans[i],
                    arrival_tick=i * arrival_every)
            for i in range(n)]


def run_level(params, cfg, *, n_requests: int, arrival_every: int,
              n_slots: int, prompt_len: int, gen: int) -> dict:
    orch = Orchestrator(
        [ModeProfile(m, BN.mode_payload_bytes(cfg, 1, 1, m), float(m))
         for m in range(cfg.split.n_modes)],
        AppRequirement(latency_budget_s=0.006), ema=0.5, hysteresis=1.0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                   cache_len=max(64, prompt_len + gen + 8),
                                   orchestrator=orch)
    reqs = make_requests(cfg, n_requests, prompt_len=prompt_len, gen=gen,
                         arrival_every=arrival_every)
    # warm the compiled paths so the throughput number measures the steady
    # state, not tracing
    eng.run(make_requests(cfg, 1, prompt_len=prompt_len, gen=2,
                          arrival_every=1, seed=99))
    eng.finished.clear()
    eng.decode_ticks = eng.mode_mix_ticks = 0
    eng.tick = 0                      # keep the measured arrival schedule
    eng.queue.submitted = eng.queue.rejected = 0

    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0
    st = eng.stats()
    occupancy = st["decode_tokens"] / max(st["decode_ticks"] * n_slots, 1)
    return {
        "offered_load_req_per_tick": round(1.0 / arrival_every, 3),
        "requests": n_requests,
        "finished": st["requests_finished"],
        "rejected": st["requests_rejected"],
        "decode_tok_per_s": round(st["decode_tokens"] / max(wall, 1e-9), 1),
        "wire_bytes_per_token": round(st["wire_bytes_per_token"], 1),
        "mode_counts": st["mode_counts"],
        "mixed_mode_ticks": st["mixed_mode_ticks"],
        "decode_ticks": st["decode_ticks"],
        "slot_occupancy": round(occupancy, 3),
        "mean_transfer_ms_per_token": round(
            1e3 * float(np.mean([s.transfer_s / max(len(s.tokens), 1)
                                 for s in done])), 3) if done else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--loads", default="8,2,1",
                    help="comma list of arrival spacings (ticks/request); "
                         "smaller = heavier offered load")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    print(f"== bench_serving {args.arch} slots={args.n_slots} "
          f"requests={args.requests} gen={args.gen} ==")

    levels = []
    for spacing in [int(s) for s in args.loads.split(",")]:
        r = run_level(params, cfg, n_requests=args.requests,
                      arrival_every=spacing, n_slots=args.n_slots,
                      prompt_len=args.prompt_len, gen=args.gen)
        levels.append(r)
        print(f"serving,load={r['offered_load_req_per_tick']},"
              f"tok/s={r['decode_tok_per_s']} "
              f"wireB/tok={r['wire_bytes_per_token']} "
              f"occ={r['slot_occupancy']} "
              f"mixed={r['mixed_mode_ticks']}/{r['decode_ticks']} "
              f"modes={r['mode_counts']}")

    mixed_any = any(r["mixed_mode_ticks"] > 0 for r in levels)
    print(f"serving_summary,mixed_mode_batches={'yes' if mixed_any else 'no'},"
          f"levels={len(levels)}")
    out = {"arch": args.arch, "n_slots": args.n_slots, "levels": levels}
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
