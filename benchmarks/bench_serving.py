"""Continuous-batching split-serving throughput vs offered load.

Sweeps the request arrival rate into ``ContinuousBatchingEngine`` and
reports, per offered-load level: decode tokens/s (engine wall clock),
uplink prefill wire bytes and decode wire-bytes/token (reported separately
so mode comparisons aren't skewed by prompt length), mean time-to-first-
token, slot occupancy, and how often the decode batch was genuinely
*mixed-mode* (>= 2 distinct bottleneck modes in the same jitted step) — the
per-request-selection property that static-batch serving can't express.

Also times the admission hot path head to head: batched full-sequence
prefill (one jitted call) vs the legacy token-at-a-time decode-step loop —
and the decode hot path head to head: the device-resident tick (argmax +
token feedback + position increment fused into the jitted step, donated
pool buffers, one-tick-lagged host sync) vs the legacy host loop
(``host_loop=True``), on identical workloads that decode token-identical
streams. The speedup lands in ``--json`` as ``engine_comparison`` and CI
gates on it.

On homogeneous full-attention archs (paged pool by default) the bench also
runs the long-prompt scenario: every prompt exceeds the dense per-slot
cache, the dense control engine rejects them all over capacity, and the
paged engine must finish every one — zero rejections, zero truncation —
reporting decode tok/s, page-arena occupancy, and how many sessions were
parked by page-budget backpressure. The ``long_prompt`` JSON section is
gated by ``tools/check_bench.py``.

``--slot-scaling 1,2,4,8`` adds the mesh-sharded scenario: the slot pool
grows with the dp mesh factor (``repro.models.sharding.serving_mesh``)
under a saturating workload, reporting decode tok/s per dp level. dp=1 is
the unsharded baseline; the ``slot_scaling`` JSON section is gated by
``tools/check_bench.py`` (all requests finish, sharded tok/s above a
floor fraction of the baseline).

``--channel-trace {static,fade,burst}`` adds the paper's dynamic-adaptation
A/B: every session rides the *same* scripted capacity trace
(``TraceChannel``) under two mode policies — the in-flight adaptive
controller (``ModeController``: per-tick re-selection with dwell +
deadline escalation) vs admission-frozen modes — and reports decode
wire-bytes/token and deadline-miss rate for both. On ``fade`` (admitted on
a good link that then degrades) the adaptive controller must spend fewer
wire bytes/token at an equal-or-better miss rate; the comparison lands in
the ``--json`` artifact so CI tracks it.

    PYTHONPATH=src python benchmarks/bench_serving.py [--arch qwen2.5-3b] \
        [--channel-trace fade] [--json BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import (RTT_SECONDS, ChannelConfig, TraceChannel,
                                channel_fleet)
from repro.models import transformer as T
from repro.serving import (ContinuousBatchingEngine, ControllerConfig,
                           ModeController, Request, Telemetry,
                           default_orchestrator)
from repro.serving.telemetry import Stopwatch, best_of


def make_requests(cfg, n: int, *, prompt_len: int, gen: int,
                  arrival_every: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    chans = channel_fleet(
        n, ChannelConfig(mean_mbps=8.0, std_mbps=3.0, blockage_prob=0.08,
                         recovery_prob=0.15),
        seed=11 + seed, mean_spread=0.95)
    shape = ((cfg.n_codebooks, prompt_len)
             if cfg.frontend == "audio" and cfg.n_codebooks > 1
             else (prompt_len,))
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=shape).astype(np.int32),
                    max_new_tokens=gen, channel=chans[i],
                    arrival_tick=i * arrival_every)
            for i in range(n)]


def run_level(params, cfg, *, n_requests: int, arrival_every: int,
              n_slots: int, prompt_len: int, gen: int,
              host_loop: bool = False) -> dict:
    # every level runs instrumented: the per-level ``latency`` section
    # (p50/p90/p99 TTFT + inter-token) is a mandatory gated artifact, and
    # the telemetry_overhead A/B separately pins the instrumentation cost
    tel = Telemetry()
    eng = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                   cache_len=max(64, prompt_len + gen + 8),
                                   orchestrator=default_orchestrator(cfg),
                                   host_loop=host_loop, telemetry=tel)
    reqs = make_requests(cfg, n_requests, prompt_len=prompt_len, gen=gen,
                         arrival_every=arrival_every)
    # warm every compiled path the measured run can hit (decode + each
    # prefill batch bucket) so the throughput numbers measure the steady
    # state, not tracing
    eng.warm(reqs[0].prompt)

    with Stopwatch() as sw:
        done = eng.run(reqs)
    wall = sw.seconds
    st = eng.stats()
    eng.close()
    occupancy = st["decode_tokens"] / max(st["decode_ticks"] * n_slots, 1)
    paged = {}
    if st["paged"]:
        paged = {
            "page_len": st["page_len"],
            "n_pages": st["n_pages"],
            "peak_pages_in_use": st["peak_pages_in_use"],
            "page_occupancy": st["page_occupancy"],
            "requests_parked": st["requests_parked"],
        }
    return {
        "paged": st["paged"],
        **paged,
        "offered_load_req_per_tick": round(1.0 / arrival_every, 3),
        "requests": n_requests,
        "finished": st["requests_finished"],
        "rejected": st["requests_rejected"],
        "over_capacity": st["requests_over_capacity"],
        "truncated": st["requests_truncated"],
        "decode_tok_per_s": round(st["decode_tokens"] / max(wall, 1e-9), 1),
        "prefill_wire_bytes": st["prefill_wire_bytes"],
        "decode_wire_bytes_per_token": round(
            st["decode_wire_bytes_per_token"], 1),
        "mean_ttft_ms": round(1e3 * st["mean_ttft_s"], 2),
        "prefill_calls": st["prefill_calls"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_tok_per_s": round(st["prefill_tokens"] / max(wall, 1e-9), 1),
        "mode_counts": st["mode_counts"],
        "mixed_mode_ticks": st["mixed_mode_ticks"],
        "decode_ticks": st["decode_ticks"],
        "slot_occupancy": round(occupancy, 3),
        "mean_transfer_ms_per_token": round(
            1e3 * float(np.mean([s.transfer_s / max(len(s.tokens), 1)
                                 for s in done])), 3) if done else 0.0,
        # gated artifact: ms p50/p90/p99/max per latency histogram
        "latency": tel.registry.latency_summary(
            "engine.ttft_s", "engine.intertoken_s",
            "engine.admit_to_first_token_s"),
    }


def run_long_prompt(params, cfg, *, n_slots: int, gen: int,
                    cache_len: int = 24, n_requests: int = 4) -> dict:
    """The paged pool's headline scenario: every prompt is LONGER than the
    dense per-slot cache, so the legacy ``SlotPool`` engine rejects all of
    them over capacity — the paged engine must admit and FINISH every one
    with zero capacity rejections and zero truncation, parking excess
    sessions until page-budget admission can cover their worst case.

    Reports the paged engine's decode throughput and page-arena occupancy
    plus the dense control's rejection count; ``tools/check_bench.py``
    gates on zero rejections and the paged tok/s floor."""
    prompt_len = cache_len + 8                 # > dense per-slot capacity
    eng = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                   cache_len=cache_len,
                                   orchestrator=default_orchestrator(cfg))
    assert eng.paged, "long-prompt scenario needs the paged pool"
    reqs = make_requests(cfg, n_requests, prompt_len=prompt_len, gen=gen,
                         arrival_every=2)
    eng.warm(reqs[0].prompt)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    st = eng.stats()
    eng.close()

    dense = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                     cache_len=cache_len, paged=False)
    dense.run(make_requests(cfg, n_requests, prompt_len=prompt_len,
                            gen=gen, arrival_every=2))
    dense_st = dense.stats()
    dense.close()
    return {
        "prompt_len": prompt_len,
        "dense_cache_len": cache_len,
        "gen": gen,
        "requests": n_requests,
        "finished": st["requests_finished"],
        "over_capacity": st["requests_over_capacity"],
        "truncated": st["requests_truncated"],
        "requests_parked": st["requests_parked"],
        "decode_tok_per_s": round(st["decode_tokens"] / max(wall, 1e-9), 1),
        "page_len": st["page_len"],
        "n_pages": st["n_pages"],
        "peak_pages_in_use": st["peak_pages_in_use"],
        "page_occupancy": st["page_occupancy"],
        "dense_over_capacity": dense_st["requests_over_capacity"],
        "dense_finished": dense_st["requests_finished"],
    }


def compare_engine_loops(params, cfg, *, n_slots: int, prompt_len: int,
                         gen: int, n_requests: int, repeats: int = 4) -> dict:
    """Decode throughput of the device-resident windowed decode loop vs the
    legacy host loop (``host_loop=True`` — the pre-device-loop engine
    preserved verbatim) on an identical saturating workload. The two decode
    token-identical streams (pinned by tests/test_device_loop.py), so the
    speedup is pure hot-path overhead removal: whole decode windows
    dispatched as one jitted scan (fused argmax + token feedback + position
    increments), donated pool buffers, and the one-window-lagged host sync.

    Runs are interleaved host/device/host/device and each side reports its
    best repeat, so machine-load drift hits both engines symmetrically."""
    engines = {}
    for key, host_loop in [("host_loop", True), ("device_loop", False)]:
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=n_slots,
            cache_len=max(64, prompt_len + gen + 8),
            orchestrator=default_orchestrator(cfg), host_loop=host_loop)
        # decode-dominated workload: every request present at tick 0 with
        # short prompts and a long generation, so wall clock measures the
        # per-tick loop, not admission
        eng.warm(make_requests(cfg, 1, prompt_len=prompt_len, gen=gen,
                               arrival_every=0)[0].prompt)
        engines[key] = eng
    out = {k: {"decode_tok_per_s": 0.0} for k in engines}
    for _ in range(repeats):
        for key, eng in engines.items():
            eng.reset_counters()
            reqs = make_requests(cfg, n_requests, prompt_len=prompt_len,
                                 gen=gen, arrival_every=0)
            t0 = time.perf_counter()
            eng.run(reqs)
            wall = time.perf_counter() - t0
            st = eng.stats()
            rate = round(st["decode_tokens"] / max(wall, 1e-9), 1)
            if rate > out[key]["decode_tok_per_s"]:
                out[key] = {
                    "decode_tok_per_s": rate,
                    "decode_ticks": st["decode_ticks"],
                    "slot_occupancy": round(
                        st["decode_tokens"]
                        / max(st["decode_ticks"] * n_slots, 1), 3),
                }
    for eng in engines.values():
        eng.close()
    out["n_slots"] = n_slots
    out["gen"] = gen
    out["requests"] = n_requests
    out["repeats"] = repeats
    out["decode_speedup"] = round(
        out["device_loop"]["decode_tok_per_s"]
        / max(out["host_loop"]["decode_tok_per_s"], 1e-9), 2)
    return out


def run_telemetry_overhead(params, cfg, *, n_slots: int, prompt_len: int,
                           gen: int, n_requests: int,
                           repeats: int = 4) -> dict:
    """Decode throughput with the telemetry subsystem attached vs a plain
    engine on an identical saturating device-loop workload. The telemetry
    engine carries the full instrumentation: registry histograms, trace
    spans, and the per-tick int32 telemetry block riding the windowed
    scan. Token streams are bit-identical either way (pinned by
    tests/test_telemetry.py); this measures only the overhead, and
    ``tools/check_bench.py`` gates ``ratio >= TELEMETRY_FLOOR`` (0.95).

    Runs are interleaved plain/telemetry/plain/telemetry and each side
    keeps its best repeat, so machine-load drift hits both symmetrically
    (the same protocol as ``compare_engine_loops``)."""
    engines = {}
    for key in ("plain", "telemetry"):
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=n_slots,
            cache_len=max(64, prompt_len + gen + 8),
            orchestrator=default_orchestrator(cfg),
            telemetry=Telemetry() if key == "telemetry" else None)
        eng.warm(make_requests(cfg, 1, prompt_len=prompt_len, gen=gen,
                               arrival_every=0)[0].prompt)
        engines[key] = eng
    best = {k: 0.0 for k in engines}
    for _ in range(repeats):
        for key, eng in engines.items():
            eng.reset_counters()
            reqs = make_requests(cfg, n_requests, prompt_len=prompt_len,
                                 gen=gen, arrival_every=0)
            t0 = time.perf_counter()
            eng.run(reqs)
            wall = time.perf_counter() - t0
            st = eng.stats()
            best[key] = max(best[key],
                            st["decode_tokens"] / max(wall, 1e-9))
    for eng in engines.values():
        eng.close()
    return {
        "n_slots": n_slots,
        "gen": gen,
        "requests": n_requests,
        "repeats": repeats,
        "plain_tok_per_s": round(best["plain"], 1),
        "telemetry_tok_per_s": round(best["telemetry"], 1),
        "ratio": round(best["telemetry"] / max(best["plain"], 1e-9), 3),
    }


def run_slot_scaling(params, cfg, *, dps, n_slots_base: int = 2,
                     prompt_len: int = 4, gen: int = 16) -> dict:
    """Slot scaling over the ``('dp','mp')`` serving mesh: at each dp the
    slot pool grows to ``n_slots_base * dp`` (each dp shard hosts the base
    slot count) and a saturating workload (every request present at tick 0,
    2x oversubscribed) measures decode tok/s. dp=1 is the unsharded
    ``mesh=None`` engine — the baseline the gate in
    ``tools/check_bench.py`` compares the sharded rows against.

    dp values that exceed the visible device count are skipped and listed
    in ``skipped_dps`` (no silent truncation). On a forced multi-device
    CPU host the sharded rows mainly pin *correct completion at scale* —
    the gate floor is intentionally loose; real dp speedups need real
    accelerators."""
    from repro.models.sharding import serving_mesh
    n_dev = len(jax.devices())
    rows, skipped = [], []
    for dp in dps:
        if dp > n_dev:
            skipped.append(dp)
            continue
        n_slots = n_slots_base * dp
        mesh = serving_mesh(dp, 1) if dp > 1 else None
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=n_slots,
            cache_len=max(64, prompt_len + gen + 8),
            orchestrator=default_orchestrator(cfg), mesh=mesh)
        reqs = make_requests(cfg, 2 * n_slots, prompt_len=prompt_len,
                             gen=gen, arrival_every=0)
        eng.warm(reqs[0].prompt)
        # one untimed throwaway round: warm() traces pow2 windows, but an
        # oversubscribed run also hits mixed-step shapes keyed on
        # (window length x block-table width) combos only the real
        # admission pattern produces — without this, the first measured
        # row is compile time, not decode rate
        eng.run(make_requests(cfg, 2 * n_slots, prompt_len=prompt_len,
                              gen=gen, arrival_every=0))
        eng.reset_counters()
        t0 = time.perf_counter()
        eng.run(reqs)
        wall = time.perf_counter() - t0
        st = eng.stats()
        eng.close()
        rows.append({
            "dp": dp,
            "n_slots": n_slots,
            "requests": 2 * n_slots,
            "finished": st["requests_finished"],
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(wall, 1e-9), 1),
            "decode_ticks": st["decode_ticks"],
            "slot_occupancy": round(
                st["decode_tokens"]
                / max(st["decode_ticks"] * n_slots, 1), 3),
        })
    if skipped:
        print(f"slot_scaling: skipped dp={skipped} "
              f"(only {n_dev} devices visible)")
    return {"n_slots_base": n_slots_base, "gen": gen,
            "n_devices": n_dev, "rows": rows, "skipped_dps": skipped}


def export_cluster_trace(params, cfg, path: str, *, n_requests: int = 5,
                         gen: int = 10) -> dict:
    """Run a small cluster exercising every control-plane event source —
    SLO admission, a scripted mid-generation handover (live migration),
    and the autoscaler — with telemetry attached, and export the merged
    per-replica-lane Chrome trace to ``path`` (loadable in Perfetto).
    Returns event counts so the artifact's coverage is auditable."""
    from repro.core.channel import MobilityChannel
    from repro.serving import (Autoscaler, AutoscalerConfig, EdgeCluster,
                               SLOAdmission)
    tel = Telemetry()
    rng = np.random.default_rng(0)

    def mobility(cross_at):
        cells = [0] * cross_at + [1] * (gen + 60)
        return MobilityChannel(cells, [2e6, 2e6], detach_factor=1.0)

    cluster = EdgeCluster(
        params, cfg, n_replicas=2, n_slots=2, cache_len=gen + 24,
        placement="best-channel", handover="migrate",
        admission=SLOAdmission(min_payload_bytes=64),
        autoscaler=Autoscaler(AutoscalerConfig(
            min_replicas=1, max_replicas=4, high_occupancy=0.5,
            sustain_ticks=1, cooldown_ticks=2)),
        telemetry=tel)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=4).astype(np.int32),
                    max_new_tokens=gen,
                    channel=mobility(5 if i == 0 else gen + 50),
                    slo_ticks=400)
            for i in range(n_requests)]
    cluster.run(reqs)
    cluster.stats()
    cluster.close()
    tel.trace.export(path)
    counts = {}
    for ev in tel.trace.events():
        counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    lanes = sorted({ev["pid"] for ev in tel.trace.events()})
    return {"path": path, "events": len(tel.trace.events()),
            "dropped": tel.trace.dropped, "lanes": lanes,
            "event_counts": counts}


def build_capacity_trace(kind: str, n_ticks: int, hi_bps: float,
                         lo_bps: float, period: int = 8) -> np.ndarray:
    """Scripted capacity traces (bytes/s per tick) for the adaptive-vs-frozen
    A/B. ``static``: constant good link (sanity — the policies must tie).
    ``fade``: good link at admission, smooth mmWave fade to ``lo``, stays
    low (the motivating scenario: a session admitted on a good link whose
    beam then degrades). ``burst``: LoS/NLoS blockage bursts alternating
    ``hi``/``lo`` every ``period/2`` ticks."""
    if kind == "static":
        return np.full(n_ticks, hi_bps)
    if kind == "fade":
        head = np.full(max(n_ticks // 8, 2), hi_bps)
        ramp = np.linspace(hi_bps, lo_bps, max(n_ticks // 4, 2))
        tail = np.full(max(n_ticks - head.size - ramp.size, 1), lo_bps)
        return np.concatenate([head, ramp, tail])[:n_ticks]
    if kind == "burst":
        t = np.arange(n_ticks)
        return np.where((t % period) < period // 2, hi_bps, lo_bps)
    raise ValueError(f"unknown trace kind {kind!r}")


def run_channel_trace(params, cfg, kind: str, *, n_slots: int, gen: int,
                      prompt_len: int, latency_budget_s: float = 0.006,
                      seed: int = 0) -> dict:
    """Adaptive (ModeController) vs admission-frozen modes on IDENTICAL
    scripted channels: same prompts, same capacity at every channel tick —
    the only degree of freedom is the per-tick mode policy."""
    pay = {m: BN.mode_payload_bytes(cfg, 1, 1, m)
           for m in range(cfg.split.n_modes)}
    # capacity levels derived from the calibrated payloads so the scenario
    # transfers across archs: hi = every mode comfortably feasible,
    # lo = only the cheapest mode fits the per-token transmit budget
    transmit = max(latency_budget_s - RTT_SECONDS, 1e-4)
    hi = 4.0 * max(pay.values()) / transmit
    lo = 1.3 * min(pay.values()) / transmit
    trace = build_capacity_trace(kind, gen + 8, hi, lo)
    rng = np.random.default_rng(seed)
    shape = ((cfg.n_codebooks, prompt_len)
             if cfg.frontend == "audio" and cfg.n_codebooks > 1
             else (prompt_len,))
    prompts = [rng.integers(1, cfg.vocab_size, size=shape).astype(np.int32)
               for _ in range(n_slots)]

    def run(policy: str) -> dict:
        orch = default_orchestrator(cfg, latency_budget_s, hysteresis=0.9)
        kw = ({"controller": ModeController(orch,
                                            ControllerConfig(dwell_ticks=2))}
              if policy == "adaptive"
              else {"orchestrator": orch, "freeze_modes": True})
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=n_slots,
            cache_len=max(64, prompt_len + gen + 8), **kw)
        # all sessions admitted at tick 0 on the trace's opening capacity —
        # the frozen baseline locks in whatever that admission capacity buys
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen,
                        channel=TraceChannel(trace))
                for i in range(n_slots)]
        eng.warm(prompts[0], gen=2)
        done = eng.run(reqs)
        st = eng.stats()
        eng.close()
        assert len(done) == n_slots
        return {
            "decode_wire_bytes_per_token": round(
                st["decode_wire_bytes_per_token"], 2),
            "deadline_miss_rate": round(st["deadline_miss_rate"], 4),
            "deadline_misses": st["deadline_misses"],
            "mode_switches": st["mode_switches"],
            "mode_escalations": st["mode_escalations"],
            "mode_counts": st["mode_counts"],
        }

    adaptive, frozen = run("adaptive"), run("frozen")
    saved = 1.0 - (adaptive["decode_wire_bytes_per_token"]
                   / max(frozen["decode_wire_bytes_per_token"], 1e-9))
    return {
        "trace": kind,
        "n_slots": n_slots,
        "gen": gen,
        "capacity_hi_bps": round(hi, 1),
        "capacity_lo_bps": round(lo, 1),
        "adaptive": adaptive,
        "frozen": frozen,
        "wire_savings_pct": round(100.0 * saved, 1),
        # the acceptance claim: fewer wire bytes/token at an equal-or-better
        # deadline-miss rate (ties allowed — `static` should tie exactly)
        "adaptive_wins": bool(
            adaptive["decode_wire_bytes_per_token"]
            <= frozen["decode_wire_bytes_per_token"]
            and adaptive["deadline_miss_rate"]
            <= frozen["deadline_miss_rate"]),
    }


def time_prefill_paths(params, cfg, *, prompt_len: int, cache_len: int,
                       repeats: int = 3) -> dict:
    """Time-to-first-token, batched full-sequence prefill vs the legacy
    token-at-a-time decode-step loop (both jitted and warmed)."""
    rng = np.random.default_rng(0)
    shape = ((1, cfg.n_codebooks, prompt_len)
             if cfg.frontend == "audio" and cfg.n_codebooks > 1
             else (1, prompt_len))
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                      size=shape).astype(np.int32))
    lens = jnp.asarray([prompt_len], jnp.int32)

    step = jax.jit(lambda p, t, s, pos: T.decode_step(p, t, s, pos, cfg))
    pre = jax.jit(lambda p, t, s, l: T.prefill(p, t, cfg, s, lengths=l))

    def loop_once():
        states = T.init_decode_state(cfg, 1, cache_len)
        logits = None
        for t in range(prompt_len):
            logits, states = step(params, prompt[..., t:t + 1], states,
                                  jnp.int32(t))
        return jax.block_until_ready(jnp.argmax(logits, -1))

    def batched_once():
        states = T.init_decode_state(cfg, 1, cache_len)
        logits, _ = pre(params, prompt, states, lens)
        return jax.block_until_ready(jnp.argmax(logits, -1))

    loop_once(), batched_once()            # warm / trace
    t_loop, _ = best_of(loop_once, repeats=repeats)
    t_batched, _ = best_of(batched_once, repeats=repeats)
    return {
        "prompt_len": prompt_len,
        "ttft_loop_ms": round(1e3 * t_loop, 3),
        "ttft_batched_ms": round(1e3 * t_batched, 3),
        "ttft_speedup": round(t_loop / max(t_batched, 1e-9), 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--loads", default="8,2,1",
                    help="comma list of arrival spacings (ticks/request); "
                         "smaller = heavier offered load")
    ap.add_argument("--prefill-prompt-len", type=int, default=64,
                    help="prompt length for the batched-vs-loop TTFT "
                         "comparison")
    ap.add_argument("--compare-slots", type=int, default=8,
                    help="slot-pool size for the device-loop vs host-loop "
                         "decode throughput A/B (0 disables it)")
    ap.add_argument("--compare-gen", type=int, default=24,
                    help="decode tokens per request in the loop A/B")
    ap.add_argument("--slot-scaling", default=None, metavar="DPS",
                    help="comma list of dp mesh factors (e.g. 1,2,4,8): "
                         "run the slot-scaling scenario — tok/s vs "
                         "n_slots with the pool sharded over dp (needs "
                         "enough devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--channel-trace", default=None,
                    choices=["static", "fade", "burst"],
                    help="run the adaptive-vs-frozen mode-policy A/B on a "
                         "scripted capacity trace")
    ap.add_argument("--trace-gen", type=int, default=24,
                    help="decode tokens per session in the --channel-trace "
                         "scenario (long enough to span the fade)")
    ap.add_argument("--overhead-repeats", type=int, default=4,
                    help="repeats for the telemetry-on vs -off decode "
                         "throughput A/B (0 disables the section)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto-loadable Chrome trace from a "
                         "small cluster run (admission + migration + "
                         "autoscale events on per-replica lanes)")
    ap.add_argument("--json", "--json-out", dest="json_out", default=None,
                    metavar="PATH", help="write the full result dict as JSON")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    print(f"== bench_serving {args.arch} slots={args.n_slots} "
          f"requests={args.requests} gen={args.gen} ==")

    pf = time_prefill_paths(params, cfg,
                            prompt_len=args.prefill_prompt_len,
                            cache_len=max(128, args.prefill_prompt_len + 8))
    print(f"prefill,prompt={pf['prompt_len']},"
          f"ttft_loop_ms={pf['ttft_loop_ms']} "
          f"ttft_batched_ms={pf['ttft_batched_ms']} "
          f"speedup={pf['ttft_speedup']}x")

    levels = []
    for spacing in [int(s) for s in args.loads.split(",")]:
        r = run_level(params, cfg, n_requests=args.requests,
                      arrival_every=spacing, n_slots=args.n_slots,
                      prompt_len=args.prompt_len, gen=args.gen)
        levels.append(r)
        print(f"serving,load={r['offered_load_req_per_tick']},"
              f"tok/s={r['decode_tok_per_s']} "
              f"decode_wireB/tok={r['decode_wire_bytes_per_token']} "
              f"prefill_wireB={r['prefill_wire_bytes']} "
              f"ttft_ms={r['mean_ttft_ms']} "
              f"prefills={r['prefill_calls']} "
              f"occ={r['slot_occupancy']} "
              f"mixed={r['mixed_mode_ticks']}/{r['decode_ticks']} "
              f"modes={r['mode_counts']}")
        lat = r["latency"]
        for name, p in lat.items():
            print(f"  latency,{name}: p50={p['p50']}ms p90={p['p90']}ms "
                  f"p99={p['p99']}ms max={p['max']}ms n={p['count']}")

    lp = None
    if T.full_attention_arch(cfg) and cfg.homogeneous:
        lp = run_long_prompt(params, cfg, n_slots=args.n_slots, gen=args.gen)
        print(f"long_prompt,prompt={lp['prompt_len']}"
              f">{lp['dense_cache_len']}=dense_cache,"
              f"finished={lp['finished']}/{lp['requests']} "
              f"over_capacity={lp['over_capacity']} "
              f"parked={lp['requests_parked']} "
              f"tok/s={lp['decode_tok_per_s']} "
              f"pages={lp['peak_pages_in_use']}/{lp['n_pages']} "
              f"dense_rejects={lp['dense_over_capacity']}/{lp['requests']}")

    mixed_any = any(r["mixed_mode_ticks"] > 0 for r in levels)
    print(f"serving_summary,mixed_mode_batches={'yes' if mixed_any else 'no'},"
          f"levels={len(levels)},prefill_speedup={pf['ttft_speedup']}x")
    out = {"arch": args.arch, "n_slots": args.n_slots,
           "prefill_comparison": pf, "levels": levels}
    if lp is not None:
        out["long_prompt"] = lp

    if args.compare_slots:
        ec = compare_engine_loops(
            params, cfg, n_slots=args.compare_slots,
            prompt_len=args.prompt_len, gen=args.compare_gen,
            n_requests=max(args.requests, 2 * args.compare_slots))
        out["engine_comparison"] = ec
        print(f"engine_comparison,slots={ec['n_slots']},"
              f"device_tok/s={ec['device_loop']['decode_tok_per_s']} "
              f"host_tok/s={ec['host_loop']['decode_tok_per_s']} "
              f"decode_speedup={ec['decode_speedup']}x")

    if args.overhead_repeats:
        ov = run_telemetry_overhead(
            params, cfg, n_slots=args.n_slots, prompt_len=args.prompt_len,
            gen=args.compare_gen,
            n_requests=max(args.requests, 2 * args.n_slots),
            repeats=args.overhead_repeats)
        out["telemetry_overhead"] = ov
        print(f"telemetry_overhead,plain_tok/s={ov['plain_tok_per_s']} "
              f"telemetry_tok/s={ov['telemetry_tok_per_s']} "
              f"ratio={ov['ratio']}")

    if args.trace_out:
        ct = export_cluster_trace(params, cfg, args.trace_out)
        out["cluster_trace_export"] = ct
        print(f"cluster_trace,events={ct['events']} "
              f"lanes={ct['lanes']} -> {ct['path']}")

    if args.slot_scaling:
        sc = run_slot_scaling(
            params, cfg, dps=[int(s) for s in args.slot_scaling.split(",")],
            prompt_len=args.prompt_len)
        out["slot_scaling"] = sc
        for row in sc["rows"]:
            print(f"slot_scaling,dp={row['dp']},slots={row['n_slots']},"
                  f"tok/s={row['decode_tok_per_s']} "
                  f"finished={row['finished']}/{row['requests']} "
                  f"occ={row['slot_occupancy']}")

    if args.channel_trace:
        tr = run_channel_trace(params, cfg, args.channel_trace,
                               n_slots=args.n_slots, gen=args.trace_gen,
                               prompt_len=args.prompt_len)
        out["channel_trace"] = tr
        print(f"channel_trace,{tr['trace']},"
              f"adaptive_wireB/tok={tr['adaptive']['decode_wire_bytes_per_token']} "
              f"frozen_wireB/tok={tr['frozen']['decode_wire_bytes_per_token']} "
              f"saved={tr['wire_savings_pct']}% "
              f"miss_adaptive={tr['adaptive']['deadline_miss_rate']} "
              f"miss_frozen={tr['frozen']['deadline_miss_rate']} "
              f"switches={tr['adaptive']['mode_switches']} "
              f"adaptive_wins={'yes' if tr['adaptive_wins'] else 'no'}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
