"""Fig. 3/5 narrative as numbers: dynamic mode switching under a time-varying
mmWave channel — transmission bytes, deadline violations, and accuracy cost
for static-z, static-z', and the orchestrated dynamic policy."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.configs import get_reduced
from repro.core import bottleneck as BN
from repro.core.channel import Channel, ChannelConfig, tx_seconds
from repro.core.orchestrator import (AppRequirement, ModeProfile,
                                     Orchestrator)


def run(n_queries: int = 2000, tokens_per_query: int = 256,
        budget_s: float = 0.010) -> Dict:
    cfg = get_reduced("granite-8b")
    payload = {m: BN.mode_payload_bytes(cfg, 1, tokens_per_query, m)
               for m in (0, 1)}
    # relevance calibration from the cascade bench (mode 1 slightly worse)
    acc = {0: 0.86, 1: 0.81}

    ch = Channel(ChannelConfig(mean_mbps=120, std_mbps=60,
                               blockage_prob=0.04, seed=7))
    caps = ch.trace(n_queries)

    def simulate(policy) -> Dict:
        bytes_total, violations, acc_sum = 0, 0, 0.0
        orch = Orchestrator(
            [ModeProfile(m, payload[m], 1.0 - acc[m], acc[m])
             for m in (0, 1)],
            AppRequirement(latency_budget_s=budget_s))
        modes = []
        for c in caps:
            if policy == "dynamic":
                orch.observe_capacity(c)
                m = orch.choose_mode()
            else:
                m = policy
            modes.append(m)
            bytes_total += payload[m]
            if tx_seconds(payload[m], c) > budget_s:
                violations += 1
            acc_sum += acc[m]
        return {"bytes": bytes_total, "violations": violations,
                "mean_acc": acc_sum / n_queries,
                "frac_mode1": float(np.mean(np.array(modes) == 1))}

    return {"static_z": simulate(0), "static_zp": simulate(1),
            "dynamic": simulate("dynamic"), "payload": payload}


def main():
    out = run()
    p = out["payload"]
    print(f"modes_payload,0,z={p[0]}B zprime={p[1]}B "
          f"ratio={p[1]/p[0]:.3f}")
    for name in ("static_z", "static_zp", "dynamic"):
        r = out[name]
        print(f"modes_{name},0,MB={r['bytes']/1e6:.2f} "
              f"viol={r['violations']} acc={r['mean_acc']:.3f} "
              f"frac_z'={r['frac_mode1']:.2f}")
    d, z, zp = out["dynamic"], out["static_z"], out["static_zp"]
    print(f"modes_summary,0,dynamic_saves_"
          f"{100 * (1 - d['bytes']/z['bytes']):.0f}%_bytes_"
          f"cuts_viol_{z['violations']}->{d['violations']}_"
          f"acc_cost_{z['mean_acc'] - d['mean_acc']:.3f}")


if __name__ == "__main__":
    main()
