"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU (the
Pallas kernels themselves target TPU; interpret mode is a correctness tool,
not a timing tool) + derived wire-compression ratios of the fused
bottleneck-quant payload."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=20) -> float:
    fn(*args)                          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run() -> Dict:
    key = jax.random.PRNGKey(0)
    M, K, N, D = 512, 2048, 512, 2048
    x = jax.random.normal(key, (M, K), jnp.float32)
    w_down = 0.02 * jax.random.normal(key, (K, N), jnp.float32)
    w_up = 0.02 * jax.random.normal(key, (N, D), jnp.float32)

    bq_ref = jax.jit(lambda x, w: ref.bottleneck_quant_ref(x, w))
    us_bq = _time(bq_ref, x, w_down)
    codes, scales = bq_ref(x, w_down)
    dq_ref = jax.jit(lambda c, s, w: ref.dequant_matmul_ref(c, s, w))
    us_dq = _time(dq_ref, codes, scales, w_up)

    a = jax.nn.sigmoid(jax.random.normal(key, (4, 1024, 512)))
    b = jax.random.normal(key, (4, 1024, 512))
    rs_ref = jax.jit(ref.rglru_scan_ref)
    us_rs = _time(rs_ref, a, b, iters=5)

    raw_bytes = M * K * 2                          # boundary bf16
    wire_bytes = M * N * 1 + M * 2                 # int8 + scales
    return {
        "bottleneck_quant_us": us_bq, "dequant_matmul_us": us_dq,
        "rglru_scan_us": us_rs,
        "wire_compression": wire_bytes / raw_bytes,
    }


def main():
    out = run()
    print(f"kernel_bottleneck_quant,{out['bottleneck_quant_us']:.0f},"
          f"wire_ratio={out['wire_compression']:.4f}")
    print(f"kernel_dequant_matmul,{out['dequant_matmul_us']:.0f},decoder_side")
    print(f"kernel_rglru_scan,{out['rglru_scan_us']:.0f},B4xS1024xD512")


if __name__ == "__main__":
    main()
