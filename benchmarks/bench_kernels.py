"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU (the
Pallas kernels themselves target TPU; interpret mode is a correctness tool,
not a timing tool) + derived wire-compression ratios of the fused
bottleneck-quant payload + the fused mixed-mode boundary (the op the
serving engine executes on every decode tick for every slot).

Runs in CI as a smoke test:

    PYTHONPATH=src python benchmarks/bench_kernels.py
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
# the shared best-of timing helper (one warmup/compile call, then the
# minimum over iters with per-call block_until_ready)
from repro.serving.telemetry import time_us as _time


def run() -> Dict:
    key = jax.random.PRNGKey(0)
    M, K, N, D = 512, 2048, 512, 2048
    x = jax.random.normal(key, (M, K), jnp.float32)
    w_down = 0.02 * jax.random.normal(key, (K, N), jnp.float32)
    w_up = 0.02 * jax.random.normal(key, (N, D), jnp.float32)

    bq_ref = jax.jit(lambda x, w: ref.bottleneck_quant_ref(x, w))
    us_bq = _time(bq_ref, x, w_down)
    codes, scales = bq_ref(x, w_down)
    dq_ref = jax.jit(lambda c, s, w: ref.dequant_matmul_ref(c, s, w))
    us_dq = _time(dq_ref, codes, scales, w_up)

    a = jax.nn.sigmoid(jax.random.normal(key, (4, 1024, 512)))
    b = jax.random.normal(key, (4, 1024, 512))
    rs_ref = jax.jit(ref.rglru_scan_ref)
    us_rs = _time(rs_ref, a, b, iters=5)

    # fused mixed-mode boundary: a 32-slot decode pool, every slot on its
    # own mode (this is the per-tick serving op). On CPU the dispatcher
    # runs the jnp reference — what bench_serving actually pays per tick;
    # the interpret-mode kernel is exercised once for correctness.
    d, B = 512, 32
    widths_bits = [(128, 8), (256, 4), (128, 1), (512, 0)]
    wmax = max(w for w, _ in widths_bits)
    stacked = {
        "down_w": jnp.stack([
            jnp.pad(0.05 * jax.random.normal(key, (d, w)),
                    ((0, 0), (0, wmax - w))).astype(jnp.bfloat16)
            for w, _ in widths_bits]),
        "up_w": jnp.stack([
            jnp.pad(0.05 * jax.random.normal(key, (w, d)),
                    ((0, wmax - w), (0, 0))).astype(jnp.bfloat16)
            for w, _ in widths_bits]),
        "norm_scale": jnp.ones((len(widths_bits), d), jnp.bfloat16),
        "width": jnp.asarray([w for w, _ in widths_bits], jnp.int32),
        "bits": jnp.asarray([b_ for _, b_ in widths_bits], jnp.int32),
    }
    xb = jax.random.normal(key, (B, 1, d)).astype(jnp.bfloat16)
    modes = jnp.arange(B, dtype=jnp.int32) % (len(widths_bits) + 1)
    bm = jax.jit(lambda s, x, m: ops.boundary_mixed_op(s, x, m))
    us_bm = _time(bm, stacked, xb, modes)
    y_i = ops.boundary_mixed_op(stacked, xb, modes, interpret=True)
    y_r = ref.boundary_mixed_ref(stacked, xb, modes)
    bm_ok = bool(jnp.isfinite(y_i.astype(jnp.float32)).all()
                 and jnp.max(jnp.abs(y_i.astype(jnp.float32)
                                     - y_r.astype(jnp.float32))) < 0.05)

    # fused decode tail vs the unfused per-tick chain it replaced. The
    # legacy window body ran the boundary op, then final-norm + LM-head
    # logits, then argmax as separately dispatched computations with the
    # full [B,1,V] f32 logits materialized between them; the megakernel
    # path runs boundary + tail (norm, head gather, argmax) as one
    # dispatch emitting only int32 tokens. On CPU both sides time the jnp
    # reference expressions — the delta is dispatch + logits-buffer
    # traffic, which is exactly what the serving tick pays per window.
    V = 4096
    heads = (0.05 * jax.random.normal(key, (1, d, V))).astype(jnp.bfloat16)
    nscale = jnp.ones((d,), jnp.bfloat16)

    def _norm_logits(x, scale, h):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(
            jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = (y * scale.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("bsd,dv->bsv", y.astype(jnp.float32),
                          h[0].astype(jnp.float32))

    chain_a = jax.jit(lambda s, x, m: ops.boundary_mixed_op(s, x, m))
    chain_b = jax.jit(_norm_logits)
    chain_c = jax.jit(lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))

    def unfused(s, x, m):
        y = chain_a(s, x, m)
        jax.block_until_ready(y)         # separate dispatch boundary
        lg = chain_b(y, nscale, heads)
        jax.block_until_ready(lg)        # full logits materialized
        return chain_c(lg)

    fused = jax.jit(lambda s, x, m: ops.decode_tail_op(
        ops.boundary_mixed_op(s, x, m), nscale, None, heads))
    us_unfused = _time(unfused, stacked, xb, modes)
    us_fused = _time(fused, stacked, xb, modes)
    mega_ok = bool(jnp.array_equal(unfused(stacked, xb, modes),
                                   fused(stacked, xb, modes)))

    raw_bytes = M * K * 2                          # boundary bf16
    wire_bytes = M * N * 1 + M * 2                 # int8 + scales
    return {
        "bottleneck_quant_us": us_bq, "dequant_matmul_us": us_dq,
        "rglru_scan_us": us_rs,
        "boundary_mixed_us": us_bm, "boundary_mixed_parity_ok": bm_ok,
        "mega_fused_tick_us": us_fused,
        "mega_unfused_chain_us": us_unfused,
        "mega_speedup": us_unfused / us_fused,
        "mega_parity_ok": mega_ok,
        "wire_compression": wire_bytes / raw_bytes,
    }


def main(argv=None):
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the raw result dict as JSON "
                         "(a {'kernels': ...} artifact for check_bench)")
    args = ap.parse_args(argv)

    out = run()
    print(f"kernel_bottleneck_quant,{out['bottleneck_quant_us']:.0f},"
          f"wire_ratio={out['wire_compression']:.4f}")
    print(f"kernel_dequant_matmul,{out['dequant_matmul_us']:.0f},decoder_side")
    print(f"kernel_rglru_scan,{out['rglru_scan_us']:.0f},B4xS1024xD512")
    print(f"kernel_boundary_mixed,{out['boundary_mixed_us']:.0f},"
          f"B32x5modes,parity_ok={out['boundary_mixed_parity_ok']}")
    print(f"kernel_mega_tick,{out['mega_fused_tick_us']:.0f},"
          f"unfused={out['mega_unfused_chain_us']:.0f},"
          f"speedup={out['mega_speedup']:.2f},"
          f"parity_ok={out['mega_parity_ok']}")
    assert out["boundary_mixed_parity_ok"], \
        "interpret-mode boundary kernel diverged from the jnp reference"
    assert out["mega_parity_ok"], \
        "fused decode tail diverged from the unfused boundary+head+argmax chain"
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"kernels": out}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
