"""Paper Figs. 7-8 + Sec. VI conditional-MI numbers: temporal information
curves I(H_t; y_tau) and I(x_1..t; H_1..t) over training, and the
conditional-MI redundancy ladder that justifies truncating H^(1) to its last
few temporal states (Eq. 3). The headline finding reproduced here: compression
occurs across the TEMPORAL dimension, not just across epochs."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import TrainConfig
from repro.core import cascade as C
from repro.core.ib import info_plane
from repro.data import lumos5g
from repro.models import lstm as LSTM
from repro.training import optimizer as opt


def run(n_probes: int = 4, steps: int = 120, n_eval: int = 1000) -> Dict:
    lcfg = get_reduced("lumos5g-lstm")
    dcfg = lumos5g.Lumos5GConfig(n_samples=5_000, seq_len=lcfg.seq_len)
    data = lumos5g.generate(dcfg)
    train, test = lumos5g.train_test_split(data, dcfg)
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)
    it = lumos5g.batch_iterator(train, 128)

    xe = jnp.asarray(test["x"][:n_eval])
    x_np = np.asarray(xe)
    tau = lcfg.seq_len // 2             # probe label timestep (paper tau=5)
    y_tau = test["y"][:n_eval, tau]

    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=steps,
                       weight_decay=0.0)
    step_fn = C.make_train_step(
        lambda p, b, m: LSTM.loss_fn(p, b, lcfg, m), tcfg)
    state = opt.init(params)
    mask = LSTM.phase_mask(params, 1)

    h1_by_epoch = []
    probe_every = max(steps // n_probes, 1)
    t0 = time.time()
    for s in range(steps):
        b = next(it)
        batch = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
        params, state, _ = step_fn(params, state, batch, mask, mode=0)
        if s % probe_every == 0 or s == steps - 1:
            _, acts = LSTM.forward(params, xe, lcfg, 0)
            h1_by_epoch.append(np.asarray(acts["H1"]))

    curves = info_plane.temporal_curves(h1_by_epoch, x_np, y_tau,
                                        lcfg.n_classes)
    ladder = info_plane.temporal_redundancy(h1_by_epoch[-1], x_np,
                                            max_condition=3)
    return {"I_HtY": curves["I_HtY"], "I_XH": curves["I_XH"],
            "cond_mi_ladder": ladder, "wall_s": time.time() - t0}


def main():
    out = run()
    i_hty, i_xh = out["I_HtY"], out["I_XH"]
    T = i_hty.shape[1]
    # Fig. 7 claim: I(H_t; y_tau) increases monotonically-ish with t
    print(f"temporal_IHtY,0,first {i_hty[-1,0]:.2f} mid "
          f"{i_hty[-1,T//2]:.2f} last {i_hty[-1,-1]:.2f} "
          f"increasing={bool(i_hty[-1,-1] >= i_hty[-1,0])}")
    # Fig. 8 claim: temporal compression — late-timestep I(X;H) per added
    # state flattens (redundancy across hidden temporal states)
    gaps = np.diff(i_xh[-1])
    print(f"temporal_IXH,0,early_gap {gaps[0]:.2f} late_gap {gaps[-1]:.2f} "
          f"temporal_compression={bool(gaps[-1] < gaps[0])}")
    # Sec. VI ladder: conditional MI decreases as we condition on more states
    l = out["cond_mi_ladder"]
    print(f"temporal_condMI,0,{l[0]:.2f} {l[1]:.2f} {l[2]:.2f} "
          f"decreasing={bool(l[0] >= l[1] >= l[2] - 0.05)}")


if __name__ == "__main__":
    main()
