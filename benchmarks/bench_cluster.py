"""Edge-cluster serving benchmark: handover policies + replica scaling.

Two experiments over ``EdgeCluster`` (multi-replica split serving with
mmWave cell handover, see docs/cluster.md):

1. **Handover A/B** — every session rides the *identical* scripted
   cell-crossing ``MobilityChannel`` (same cells, same capacities, same
   crossing tick) under three policies: ``migrate`` (live state migration
   over the simulated backhaul), ``stay`` (keep decoding on the old cell's
   replica at ``detach_factor`` capacity), and ``drop`` (drop-and-replay
   the full context on the new replica). Capacity levels derive from the
   calibrated mode payloads so the scenario transfers across archs: in-cell
   capacity makes every mode comfortably feasible, detached capacity makes
   even the cheapest mode blow the latency budget — staying *must* miss
   deadlines, which is exactly what migration buys back. The headline
   ``migration_wins`` (migrate beats stay on deadline-miss rate) lands in
   ``--json`` and CI gates on it, alongside wire bytes/token, migration
   backhaul bytes (raw vs quantized snapshots), and handover latency.

2. **Replica scaling** — a fixed offered load served by 1, 2, ... replica
   clusters (per-engine decode pipelines run concurrently); reports
   aggregate decode tokens/s per replica count. CI asserts the sanity
   floor: adding replicas must not crater throughput below
   ``SCALE_FLOOR`` x the single-replica figure.

    PYTHONPATH=src python benchmarks/bench_cluster.py [--arch qwen2.5-3b] \
        [--replicas 1,2] [--json BENCH_cluster.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import RTT_SECONDS, MobilityChannel
from repro.serving import EdgeCluster, Request


def _capacities(cfg, latency_budget_s: float):
    """(in-cell, detached) capacity in bytes/s, derived from the calibrated
    mode payloads: in-cell fits every mode in the per-token transmit
    budget; detached does not fit even the cheapest."""
    pay = [BN.mode_payload_bytes(cfg, 1, 1, m)
           for m in range(cfg.split.n_modes)]
    transmit = max(latency_budget_s - RTT_SECONDS, 1e-4)
    hi = 4.0 * max(pay) / transmit
    lo = 0.5 * min(pay) / transmit
    return hi, lo


def make_mobility_requests(cfg, n: int, *, n_cells: int, prompt_len: int,
                           gen: int, cap_bps: float, detach_factor: float,
                           seed: int = 0):
    """Sessions that each cross from their home cell into the next one
    partway through generation — the same scripted crossing per rid no
    matter which policy replays it."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        home = i % n_cells
        cross = int(rng.integers(max(gen // 4, 2), max(gen // 2, 3)))
        cells = [home] * cross + [(home + 1) % n_cells] * (gen + 8)
        ch = MobilityChannel(cells, [cap_bps] * n_cells,
                             detach_factor=detach_factor)
        prompt = rng.integers(1, cfg.vocab_size,
                              size=prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gen,
                            channel=ch, arrival_tick=(i // n_cells) * 2))
    return reqs


def run_handover_ab(params, cfg, *, n_replicas: int, n_slots: int,
                    prompt_len: int, gen: int,
                    latency_budget_s: float = 0.006,
                    snapshot_bits: int = 0, seed: int = 0) -> dict:
    """stay vs drop vs migrate on identical mobility scripts."""
    hi, lo = _capacities(cfg, latency_budget_s)
    detach = lo / hi

    def run(policy: str, bits: int = 0) -> dict:
        cluster = EdgeCluster(
            params, cfg, n_replicas=n_replicas, n_slots=n_slots,
            cache_len=max(64, 2 * (prompt_len + gen) + 8),
            placement="best-channel", handover=policy, snapshot_bits=bits,
            latency_budget_s=latency_budget_s, max_window=4)
        reqs = make_mobility_requests(
            cfg, 2 * n_replicas * n_slots, n_cells=n_replicas,
            prompt_len=prompt_len, gen=gen, cap_bps=hi,
            detach_factor=detach, seed=seed)
        cluster.warm(reqs[0].prompt)
        t0 = time.perf_counter()
        done = cluster.run(reqs)
        wall = time.perf_counter() - t0
        st = cluster.stats()
        cluster.close()
        assert st["requests_finished"] == len(reqs), (policy, st)
        assert all(len(s.tokens) >= 1 for s in done)
        return {
            "deadline_miss_rate": round(st["deadline_miss_rate"], 4),
            "deadline_misses": st["deadline_misses"],
            "decode_wire_bytes_per_token": round(
                st["decode_wire_bytes_per_token"], 1),
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(wall, 1e-9), 1),
            "handovers": st["handovers"],
            "migrations": st["migrations"],
            "migration_bytes": st["migration_bytes"],
            "replays": st["replays"],
            "replayed_tokens": st["replayed_tokens"],
            "mean_handover_latency_ticks": round(
                st["mean_handover_latency_ticks"], 2),
        }

    out = {
        "n_replicas": n_replicas,
        "n_slots": n_slots,
        "gen": gen,
        "capacity_in_cell_bps": round(hi, 1),
        "capacity_detached_bps": round(lo, 1),
        "stay": run("stay"),
        "drop": run("drop"),
        "migrate": run("migrate"),
    }
    if snapshot_bits:
        out["migrate_quantized"] = run("migrate", bits=snapshot_bits)
        out["snapshot_bits"] = snapshot_bits
        raw, q = out["migrate"], out["migrate_quantized"]
        if raw["migrations"] and q["migrations"]:
            out["snapshot_compression"] = round(
                (raw["migration_bytes"] / raw["migrations"])
                / max(q["migration_bytes"] / q["migrations"], 1e-9), 2)
    # the acceptance claim: live migration beats staying on a detached
    # link on deadline-miss rate (the reason the subsystem exists)
    out["migration_wins"] = bool(
        out["migrate"]["deadline_miss_rate"]
        < out["stay"]["deadline_miss_rate"])
    return out


def run_scaling(params, cfg, replica_counts, *, n_slots: int,
                prompt_len: int, gen: int, seed: int = 0) -> list:
    """Aggregate decode tokens/s vs replica count on a fixed offered load
    (no mobility — pure router + concurrent replica pipelines)."""
    out = []
    n_requests = 2 * max(replica_counts) * n_slots
    for n_rep in replica_counts:
        cluster = EdgeCluster(
            params, cfg, n_replicas=n_rep, n_slots=n_slots,
            cache_len=max(64, prompt_len + gen + 8),
            placement="least-loaded", handover="stay", max_window=4)
        rng = np.random.default_rng(seed)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            size=prompt_len).astype(np.int32),
                        max_new_tokens=gen)
                for i in range(n_requests)]
        cluster.warm(reqs[0].prompt)
        t0 = time.perf_counter()
        cluster.run(reqs)
        wall = time.perf_counter() - t0
        st = cluster.stats()
        cluster.close()
        assert st["requests_finished"] == n_requests
        out.append({
            "replicas": n_rep,
            "total_slots": n_rep * n_slots,
            "requests": n_requests,
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(wall, 1e-9), 1),
            "per_replica_finished": [r["finished"]
                                     for r in st["per_replica"]],
        })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--ab-replicas", type=int, default=2,
                    help="replica count for the handover A/B")
    ap.add_argument("--replicas", default="1,2",
                    help="comma list of replica counts for the scaling "
                         "sweep")
    ap.add_argument("--snapshot-bits", type=int, default=8,
                    help="also run migrate with quantized snapshots at "
                         "this bit width (0 disables)")
    ap.add_argument("--latency-budget-ms", type=float, default=6.0)
    ap.add_argument("--json", "--json-out", dest="json_out", default=None,
                    metavar="PATH", help="write the full result dict as "
                    "JSON")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    print(f"== bench_cluster {args.arch} slots={args.n_slots} "
          f"gen={args.gen} ==")

    ab = run_handover_ab(params, cfg, n_replicas=args.ab_replicas,
                         n_slots=args.n_slots, prompt_len=args.prompt_len,
                         gen=args.gen, snapshot_bits=args.snapshot_bits,
                         latency_budget_s=args.latency_budget_ms / 1e3)
    for pol in ("stay", "drop", "migrate"):
        r = ab[pol]
        print(f"handover,{pol},miss_rate={r['deadline_miss_rate']} "
              f"wireB/tok={r['decode_wire_bytes_per_token']} "
              f"tok/s={r['decode_tok_per_s']} "
              f"migrations={r['migrations']} replays={r['replays']} "
              f"backhaulB={r['migration_bytes']}")
    if "migrate_quantized" in ab:
        q = ab["migrate_quantized"]
        print(f"handover,migrate_q{ab['snapshot_bits']},"
              f"miss_rate={q['deadline_miss_rate']} "
              f"backhaulB={q['migration_bytes']} "
              f"compression={ab.get('snapshot_compression')}x")
    print(f"handover_summary,migration_wins="
          f"{'yes' if ab['migration_wins'] else 'no'}")

    counts = [int(s) for s in args.replicas.split(",")]
    scaling = run_scaling(params, cfg, counts, n_slots=args.n_slots,
                          prompt_len=args.prompt_len, gen=args.gen)
    for s in scaling:
        print(f"scaling,replicas={s['replicas']},"
              f"tok/s={s['decode_tok_per_s']} "
              f"finished={s['per_replica_finished']}")

    out = {"arch": args.arch, "n_slots": args.n_slots,
           "handover_ab": ab, "scaling": scaling}
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
