"""Paper Fig. 9: information-plane trajectories of the encoder layers across
the two cascade phases (I(X;H) via GCMI, I(H;Y) via Kolchinsky KDE)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import TrainConfig
from repro.core import cascade as C
from repro.core.ib import info_plane
from repro.data import lumos5g
from repro.models import lstm as LSTM


def run(n_epoch_probes: int = 5, steps_per_phase: int = 100,
        n_eval: int = 1200) -> Dict:
    lcfg = get_reduced("lumos5g-lstm")
    dcfg = lumos5g.Lumos5GConfig(n_samples=5_000, seq_len=lcfg.seq_len)
    data = lumos5g.generate(dcfg)
    train, test = lumos5g.train_test_split(data, dcfg)
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)

    it = lumos5g.batch_iterator(train, 128)
    batches = [next(it) for _ in range(steps_per_phase * 2)]
    xe = jnp.asarray(test["x"][:n_eval])
    ye = test["y"][:n_eval]
    y_tau = ye[:, -1]

    probe_every = max(steps_per_phase // n_epoch_probes, 1)
    acts_p1: List[Dict[str, np.ndarray]] = []
    acts_p2: List[Dict[str, np.ndarray]] = []

    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=5,
                       total_steps=steps_per_phase * 2, weight_decay=0.0)
    step_fn = C.make_train_step(
        lambda p, b, m: LSTM.loss_fn(p, b, lcfg, m), tcfg)
    from repro.training import optimizer as opt
    state = opt.init(params)
    t0 = time.time()
    for phase in (1, 2):
        mode = phase - 1
        mask = LSTM.phase_mask(params, phase)
        for s in range(steps_per_phase):
            b = batches[(phase - 1) * steps_per_phase + s]
            batch = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
            params, state, _ = step_fn(params, state, batch, mask, mode=mode)
            if s % probe_every == 0:
                _, acts = LSTM.forward(params, xe, lcfg, mode)
                rec = {k: np.asarray(v) for k, v in acts.items()
                       if k.startswith("H")}
                # the decoder sees the FINAL temporal state of H2/H3
                (acts_p1 if phase == 1 else acts_p2).append(rec)

    # information plane per probe: layer H1 truncated per paper Eq. (3),
    # H2 final state, (phase 2: H3 final state)
    def points(acts_list, names):
        out = {n: [] for n in names}
        for acts in acts_list:
            for n in names:
                h = acts[n]
                h_in = h[:, -4:, :] if n == "H1" else h[:, -1, :]
                out[n].append(info_plane.layer_point(
                    h_in, np.asarray(xe), y_tau, lcfg.n_classes))
        return out

    plane1 = points(acts_p1, ["H1", "H2"])
    plane2 = points(acts_p2, ["H1", "H2", "H3"])
    return {"phase1": plane1, "phase2": plane2,
            "wall_s": time.time() - t0}


def main():
    out = run()
    for phase, plane in (("p1", out["phase1"]), ("p2", out["phase2"])):
        for layer, pts in plane.items():
            first, last = pts[0], pts[-1]
            print(f"infoplane_{phase}_{layer},0,"
                  f"IXH {first['I_XH']:.2f}->{last['I_XH']:.2f} "
                  f"IHY {first['I_HY']:.2f}->{last['I_HY']:.2f}")
    # the paper's headline ordering: the added bottleneck layer carries less
    # information about X than the layer it compresses
    h2 = out["phase2"]["H2"][-1]
    h3 = out["phase2"]["H3"][-1]
    print(f"infoplane_dpi,0,I(X;H3) {h3['I_XH']:.2f} <= "
          f"I(X;H2) {h2['I_XH']:.2f} = {h3['I_XH'] <= h2['I_XH'] + 0.2}")


if __name__ == "__main__":
    main()
