"""City-scale fleet serving benchmark: UE scaling curve + autoscaler A/B.

Two experiments over the elastic ``EdgeCluster`` (see docs/fleet.md):

1. **UE scaling curve** — the same fixed cluster serves fleets of
   growing size (default 100 / 1k / 10k UEs). Every fleet rides ONE
   vectorized :class:`~repro.core.channel.FleetChannel` replaying
   Lumos5G-shaped capacity traces (no per-UE Python channel objects on
   the hot path), arrivals follow a heavy-tail renewal process packed
   into a fixed ~512-tick span — so offered load grows linearly with the
   fleet and the curve shows throughput saturating while the
   SLO-admission gate sheds the hopeless tail. CI gates a scaling floor:
   decode tokens/s at every level must stay above ``FLEET_FLOOR`` x the
   smallest fleet's figure (more offered load must never crater the
   served rate).

2. **Autoscaler A/B** — identical flash-crowd arrival waves served by
   (a) an autoscaled cluster growing from 1 replica and (b) a fixed
   cluster provisioned at the autoscaler's time-averaged replica count
   (equal aggregate slots). The headline ``autoscaler_wins`` — the
   elastic cluster must beat the equally-provisioned static one on
   ``session_slo_miss_rate`` — lands in ``--json`` and CI gates on it.

    PYTHONPATH=src python benchmarks/bench_fleet.py [--arch qwen2.5-3b] \
        [--ues 100,1000,10000] [--json BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core import bottleneck as BN
from repro.core import split as SP
from repro.core.channel import FleetChannel
from repro.data.lumos5g import capacity_traces_bps
from repro.serving import (Autoscaler, AutoscalerConfig, EdgeCluster,
                           FleetLoadConfig, SLOAdmission,
                           SLOAdmissionConfig, Telemetry, fleet_requests)
from repro.serving.telemetry import Stopwatch

#: arrival span for the scaling sweep — offered load = n_ues / SPAN_TICKS
SPAN_TICKS = 512


def _min_payload(cfg) -> int:
    return min(BN.mode_payload_bytes(cfg, 1, 1, m)
               for m in range(cfg.split.n_modes))


def _make_fleet(n: int, *, n_ticks: int, seed: int) -> FleetChannel:
    traces = capacity_traces_bps(n, n_ticks, seed=seed)
    return FleetChannel(n, traces_bps=traces, cycle=True)


def _assert_conserved(st: dict):
    c = st["conservation"]
    terminals = (c["finished"] + c["queue_rejected_router"]
                 + c["queue_rejected_engine"] + c["over_capacity"]
                 + c["slo_rejected"])
    assert c["submitted"] == terminals and c["in_flight"] == 0, c


def mean_live_replicas(n0: int, scale_events, clock: int) -> float:
    """Time-averaged live replica count over the cluster clock — the
    autoscaled run's aggregate provisioning, which the fixed baseline
    must match (equal aggregate slots)."""
    n, last, area = n0, 0, 0.0
    for tick, kind, _ in scale_events:
        area += n * (tick - last)
        last = tick
        n += 1 if kind == "up" else -1
    area += n * (max(clock, last) - last)
    return area / max(clock, 1)


# ---------------------------------------------------------------------------
# experiment 1: UE scaling curve
# ---------------------------------------------------------------------------

def run_scaling(params, cfg, ue_counts, *, n_replicas: int, n_slots: int,
                prompt_len: int, gen: int, slo_ticks: int,
                seed: int = 0) -> list:
    rows = []
    min_pay = _min_payload(cfg)
    for n in ue_counts:
        fleet = _make_fleet(n, n_ticks=256, seed=seed)
        load = FleetLoadConfig(
            arrival="heavy-tail",
            mean_interarrival_ticks=SPAN_TICKS / n,
            prompt_len=prompt_len, max_new_tokens=gen,
            vocab=cfg.vocab_size, slo_ticks=slo_ticks, seed=seed)
        reqs = fleet_requests(fleet, load)
        gate = SLOAdmission(min_pay, SLOAdmissionConfig())
        tel = Telemetry()
        cluster = EdgeCluster(
            params, cfg, n_replicas=n_replicas, n_slots=n_slots,
            cache_len=max(32, 2 * (prompt_len + gen)),
            admission=gate, max_pending=max(256, 8 * n_slots),
            telemetry=tel)
        cluster.warm(reqs[0].prompt)
        with Stopwatch() as sw:
            cluster.run_paced(reqs)
        wall = sw.seconds
        st = cluster.stats()
        cluster.close()
        _assert_conserved(st)
        rows.append({
            "ues": n,
            "offered_req_per_tick": round(n / SPAN_TICKS, 3),
            "total_slots": n_replicas * n_slots,
            "finished": st["requests_finished"],
            "rejected": (st["requests_rejected"] + st["slo_rejected"]),
            "admission": gate.stats(),
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(wall, 1e-9), 1),
            "session_slo_miss_rate": round(
                st["session_slo_miss_rate"], 4),
            "wall_s": round(wall, 2),
            "latency": tel.registry.latency_summary(
                "engine.ttft_s", "engine.intertoken_s",
                "engine.admit_to_first_token_s"),
        })
    return rows


# ---------------------------------------------------------------------------
# experiment 2: autoscaler vs fixed provisioning (equal aggregate slots)
# ---------------------------------------------------------------------------

def _wave_arrival_ticks(n: int, *, n_waves: int, period: int,
                        burst_len: int, bg_frac: float,
                        seed: int) -> np.ndarray:
    """Flash-crowd script: ``n_waves`` bursts ``period`` ticks apart, each
    spread over ``burst_len`` ticks, over a thin Poisson-ish background
    (the background keeps engines ticking between waves so the cluster
    clock tracks engine time and the autoscaler sees the lulls)."""
    rng = np.random.default_rng(seed)
    n_bg = int(n * bg_frac)
    n_wave, ticks = n - n_bg, []
    per = n_wave // n_waves
    for w in range(n_waves):
        c = per if w < n_waves - 1 else n_wave - per * (n_waves - 1)
        ticks.append(rng.integers(w * period, w * period + burst_len,
                                  size=c))
    ticks.append(rng.integers(0, n_waves * period, size=n_bg))
    return np.sort(np.concatenate(ticks)).astype(np.int64)


def run_autoscale_ab(params, cfg, *, n_ues: int, n_slots: int,
                     max_replicas: int, prompt_len: int, gen: int,
                     slo_ticks: int, seed: int = 0) -> dict:
    waves = _wave_arrival_ticks(n_ues, n_waves=3, period=160,
                                burst_len=64, bg_frac=0.2, seed=seed + 7)

    def _run(n_replicas: int, autoscale: bool) -> dict:
        fleet = _make_fleet(n_ues, n_ticks=256, seed=seed)
        load = FleetLoadConfig(arrival="burst", prompt_len=prompt_len,
                               max_new_tokens=gen, vocab=cfg.vocab_size,
                               slo_ticks=slo_ticks, seed=seed)
        reqs = fleet_requests(fleet, load)
        for r, t in zip(reqs, waves):    # identical wave script both arms
            r.arrival_tick = int(t)
        auto = Autoscaler(AutoscalerConfig(
            max_replicas=max_replicas, sustain_ticks=2, cooldown_ticks=4,
            high_occupancy=0.8)) if autoscale else None
        tel = Telemetry()
        cluster = EdgeCluster(params, cfg, n_replicas=n_replicas,
                              n_slots=n_slots,
                              cache_len=max(32, 2 * (prompt_len + gen)),
                              autoscaler=auto, max_pending=n_ues,
                              telemetry=tel)
        cluster.warm(reqs[0].prompt)
        with Stopwatch() as sw:
            cluster.run_paced(reqs)
        wall = sw.seconds
        st = cluster.stats()
        cluster.close()
        _assert_conserved(st)
        mean_live = mean_live_replicas(n_replicas, st["scale_events"],
                                       cluster.clock)
        return {
            "start_replicas": n_replicas,
            "mean_live_replicas": round(mean_live, 2),
            "aggregate_slots": round(mean_live * n_slots, 1),
            "scale_ups": st["scale_ups"],
            "scale_downs": st["scale_downs"],
            "finished": st["requests_finished"],
            "session_slo_late": st["session_slo_late"],
            "session_slo_miss_rate": round(
                st["session_slo_miss_rate"], 4),
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(wall, 1e-9), 1),
            "latency": tel.registry.latency_summary(
                "engine.ttft_s", "engine.intertoken_s"),
        }

    auto = _run(1, autoscale=True)
    fixed_n = max(1, round(auto["mean_live_replicas"]))
    fixed = _run(fixed_n, autoscale=False)
    return {
        "ues": n_ues,
        "n_slots": n_slots,
        "max_replicas": max_replicas,
        "fixed_replicas": fixed_n,
        "autoscaled": auto,
        "fixed": fixed,
        # the acceptance claim: at equal aggregate slots, spending them
        # WHEN the flash crowd hits beats spreading them evenly
        "autoscaler_wins": bool(auto["session_slo_miss_rate"]
                                < fixed["session_slo_miss_rate"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--ues", default="100,1000,10000",
                    help="comma list of fleet sizes for the scaling curve")
    ap.add_argument("--ab-ues", type=int, default=2000,
                    help="fleet size for the autoscaler A/B")
    ap.add_argument("--n-replicas", type=int, default=2,
                    help="fixed cluster size for the scaling curve")
    ap.add_argument("--n-slots", type=int, default=16)
    ap.add_argument("--max-replicas", type=int, default=6,
                    help="autoscaler ceiling in the A/B")
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--slo-ticks", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", "--json-out", dest="json_out", default=None,
                    metavar="PATH", help="write the full result dict as "
                    "JSON")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    params = SP.init_split_params(jax.random.PRNGKey(0), cfg)
    ue_counts = [int(s) for s in args.ues.split(",")]
    print(f"== bench_fleet {args.arch} slots={args.n_slots} "
          f"gen={args.gen} ==")

    scaling = run_scaling(params, cfg, ue_counts,
                          n_replicas=args.n_replicas,
                          n_slots=args.n_slots,
                          prompt_len=args.prompt_len, gen=args.gen,
                          slo_ticks=args.slo_ticks, seed=args.seed)
    for r in scaling:
        print(f"scaling,ues={r['ues']},offered={r['offered_req_per_tick']}"
              f"/tick,finished={r['finished']},rejected={r['rejected']},"
              f"tok/s={r['decode_tok_per_s']},"
              f"miss_rate={r['session_slo_miss_rate']},"
              f"wall={r['wall_s']}s")
        ttft = r["latency"].get("engine.ttft_s")
        itl = r["latency"].get("engine.intertoken_s")
        if ttft and itl:
            print(f"  latency,ues={r['ues']},"
                  f"ttft_ms=p50:{ttft['p50']}/p99:{ttft['p99']},"
                  f"intertoken_ms=p50:{itl['p50']}/p99:{itl['p99']}")

    ab = run_autoscale_ab(params, cfg, n_ues=args.ab_ues,
                          n_slots=args.n_slots,
                          max_replicas=args.max_replicas,
                          prompt_len=args.prompt_len, gen=args.gen,
                          slo_ticks=args.slo_ticks, seed=args.seed)
    for arm in ("autoscaled", "fixed"):
        r = ab[arm]
        print(f"ab,{arm},mean_live={r['mean_live_replicas']},"
              f"slots={r['aggregate_slots']},"
              f"miss_rate={r['session_slo_miss_rate']},"
              f"late={r['session_slo_late']},"
              f"tok/s={r['decode_tok_per_s']}")
    print(f"ab_summary,autoscaler_wins="
          f"{'yes' if ab['autoscaler_wins'] else 'no'}")

    out = {"arch": args.arch, "n_replicas": args.n_replicas,
           "n_slots": args.n_slots, "gen": args.gen,
           "slo_ticks": args.slo_ticks, "scaling": scaling,
           "autoscale_ab": ab}
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
