"""Roofline table: reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and prints the per-(arch x shape x mesh)
three-term roofline with the dominant bottleneck and useful-FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_all() -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def main():
    rows = load_all()
    if not rows:
        print("roofline_table,0,no dryrun results — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    for d in rows:
        r = d["roofline"]
        name = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        if d.get("variant", "baseline") != "baseline":
            name += f"|{d['variant']}"
        if not d.get("seq_shard", True):
            name += "|noseqshard"
        print(f"roofline_{name},{r['bound_s']*1e6:.0f},"
              f"c={r['compute_s']*1e3:.1f}ms "
              f"m={r['memory_s']*1e3:.1f}ms "
              f"coll={r['collective_s']*1e3:.1f}ms "
              f"dom={r['dominant'][:-2]} "
              f"useful={d['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
