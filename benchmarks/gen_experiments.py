"""Regenerate the EXPERIMENTS.md §Roofline markdown table from
results/dryrun/*.json. Prints to stdout; EXPERIMENTS.md embeds the output.

    PYTHONPATH=src python -m benchmarks.gen_experiments [--mesh 16_16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")

ARCH_ORDER = ["musicgen-large", "stablelm-3b", "llava-next-34b", "qwen2.5-3b",
              "phi3.5-moe-42b-a6.6b", "mixtral-8x7b", "internlm2-20b",
              "recurrentgemma-2b", "granite-8b", "xlstm-125m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def load(mesh: str, variants: bool = False):
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            continue
        is_variant = (d.get("variant", "baseline") != "baseline"
                      or not d.get("seq_shard", True)
                      or d.get("tp_scope", "all") != "all"
                      or bool(d.get("moe_ep"))
                      or bool(d.get("kv_bits")))
        if is_variant != variants:
            continue
        if d["mesh"].replace("x", "_") != mesh:
            continue
        rows.append(d)
    key = lambda d: (ARCH_ORDER.index(d["arch"]),      # noqa: E731
                     SHAPE_ORDER.index(d["shape"]))
    return sorted(rows, key=key)


def table(mesh: str, variants: bool = False) -> str:
    rows = load(mesh, variants)
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "useful | argGiB/dev | tempGiB/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for d in rows:
        r = d["roofline"]
        ma = d.get("memory_analysis", {})
        tag = d["arch"]
        mods = []
        if d.get("variant", "baseline") != "baseline":
            mods.append(d["variant"])
        pol = d.get("act_policy", "seq" if d.get("seq_shard", True)
                    else "batch")
        if pol != "seq":
            mods.append(pol)
        if d.get("tp_scope", "all") != "all":
            mods.append(f"tp={d['tp_scope']}")
        if d.get("moe_ep"):
            mods.append("ep")
        if d.get("kv_bits"):
            mods.append(f"kv{d['kv_bits']}")
        if mods:
            tag += f" ({', '.join(mods)})"
        out.append(
            f"| {tag} | {d['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'][:-2]} | {d['useful_ratio']:.2f} | "
            f"{ma.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{ma.get('temp_size_in_bytes', 0)/2**30:.2f} |")
    return "\n".join(out)


def skipped_pairs() -> str:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            out.append(f"- {d['arch']} x {d['shape']}: {d['reason']}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16_16")
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    print(table(args.mesh, args.variants))


if __name__ == "__main__":
    main()
