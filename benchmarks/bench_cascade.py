"""Paper 'table': complexity-relevance tradeoff of the cascaded modes
(Alg. 1 / Fig. 9 quantities) on the synthetic Lumos5G twin.

Columns: mode, payload bytes/query, val loss, val acc, I(z;X) proxy width.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import TrainConfig
from repro.core import cascade as C
from repro.data import lumos5g
from repro.models import lstm as LSTM


def run(full: bool = False, steps_per_phase: int = 150,
        verbose: bool = False) -> Dict:
    lcfg = get_config("lumos5g-lstm") if full else get_reduced("lumos5g-lstm")
    dcfg = lumos5g.Lumos5GConfig(
        n_samples=70_000 if full else 6_000, seq_len=lcfg.seq_len)
    data = lumos5g.generate(dcfg)
    train, test = lumos5g.train_test_split(data, dcfg)
    params = LSTM.init_params(jax.random.PRNGKey(0), lcfg)

    it = lumos5g.batch_iterator(train, lcfg.batch_size if full else 128)
    batches = [next(it) for _ in range(steps_per_phase * 2)]

    def data_iter(step):
        b = batches[step % len(batches)]
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    test_b = {"x": jnp.asarray(test["x"][:1024]),
              "y": jnp.asarray(test["y"][:1024])}

    def eval_fn(params, mode):
        loss, m = LSTM.loss_fn(params, test_b, lcfg, mode)
        return {"loss": loss, "acc": m["acc"]}

    tcfg = TrainConfig(
        learning_rate=lcfg.learning_rate if full else 5e-3,
        warmup_steps=10, total_steps=steps_per_phase * 2, weight_decay=0.0)
    t0 = time.time()
    params, hist = C.train_cascade(
        params, lambda p, b, m: LSTM.loss_fn(p, b, lcfg, m), data_iter,
        tcfg, n_modes=2, steps_per_phase=steps_per_phase,
        phase_mask_fn=lambda p, ph: LSTM.phase_mask(p, ph),
        eval_fn=eval_fn, verbose=verbose)
    wall = time.time() - t0

    z_bytes = lcfg.enc_cells[-1] * 4            # z: fp32 final state
    zp_bytes = lcfg.bottleneck_cells * 1 + 2    # z': int8 + scale
    rows = []
    for mode in (0, 1):
        e = hist["phases"][mode]["eval"]
        rows.append({
            "mode": mode,
            "payload_bytes": z_bytes if mode == 0 else zp_bytes,
            "val_loss": round(e["loss"], 4),
            "val_acc": round(e["acc"], 4),
            "code_width": lcfg.enc_cells[-1] if mode == 0
            else lcfg.bottleneck_cells,
        })
    return {"rows": rows, "ensure_ordered": hist["ensure"]["ordered"],
            "wall_s": wall}


def main():
    out = run()
    for r in out["rows"]:
        print(f"cascade_mode{r['mode']},"
              f"{out['wall_s'] * 1e6 / 300:.0f},"
              f"bytes={r['payload_bytes']} loss={r['val_loss']} "
              f"acc={r['val_acc']}")
    print(f"cascade_ensure,0,ordered={out['ensure_ordered']}")


if __name__ == "__main__":
    main()
