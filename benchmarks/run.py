# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one entry per paper table/figure:

  bench_cascade      Alg. 1 / Fig. 9: per-mode payload vs predictive quality
  bench_infoplane    Fig. 9: information-plane trajectories, both phases
  bench_temporal_mi  Figs. 7-8 + Sec. VI: temporal MI + conditional ladder
  bench_modes        Fig. 3/5: dynamic switching vs static policies
  bench_kernels      kernel layer micro-bench + wire compression
  bench_roofline     deliverable (g): roofline table from dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_cascade, bench_infoplane, bench_kernels,
                            bench_modes, bench_roofline, bench_temporal_mi)
    suites = [
        ("cascade", bench_cascade.main),
        ("modes", bench_modes.main),
        ("kernels", bench_kernels.main),
        ("temporal_mi", bench_temporal_mi.main),
        ("infoplane", bench_infoplane.main),
        ("roofline", bench_roofline.main),
    ]
    failed = []
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:                       # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == '__main__':
    main()
